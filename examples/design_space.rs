//! Design-space exploration with the §4.3 throughput optimizer.
//!
//! Sweeps clock frequency and LUT headroom for the Table-2 network,
//! prints the (UF, P) plan the optimizer chooses at each point and where
//! the paper's 90 MHz / 79%-LUT design sits; then optimizes the two
//! smaller configs to show the model generalizes beyond Table 2.
//!
//! Run: cargo run --release --example design_space

use repro::benchkit::Table;
use repro::fpga::power::power;
use repro::model::NetConfig;
use repro::optimizer::{optimize, OptimizeOptions};

fn main() -> anyhow::Result<()> {
    println!("=== frequency / headroom sweep (Table-2 network, XC7VX690) ===");
    let mut t = Table::new(&[
        "freq MHz",
        "LUT headroom",
        "bottleneck_est",
        "FPS(model)",
        "LUT%",
        "W(model)",
        "GOPS/W",
    ]);
    let cfg = NetConfig::table2();
    for &mhz in &[90.0f64, 150.0, 200.0] {
        for &headroom in &[0.7f64, 0.82, 0.95] {
            let opts = OptimizeOptions {
                freq_hz: mhz * 1e6,
                lut_headroom: headroom,
                ..OptimizeOptions::default()
            };
            let plan = optimize(&cfg, &opts)?;
            let w = power(&plan.resources, opts.freq_hz).total_w();
            let gops = cfg.ops_per_image() as f64 * plan.fps / 1e9;
            t.row(&[
                format!("{mhz:.0}"),
                format!("{headroom:.2}"),
                plan.bottleneck_est.to_string(),
                format!("{:.0}", plan.fps),
                format!("{:.1}", 100.0 * plan.resources.total.luts as f64 / 433_200.0),
                format!("{w:.1}"),
                format!("{:.0}", gops / w),
            ]);
        }
    }
    t.print();
    println!("\npaper design point: 90 MHz, 79% LUTs, 6218 FPS, 8.2 W, 935 GOPS/W\n");

    println!("=== optimizer plans for the smaller configs ===");
    for name in ["small", "tiny"] {
        let cfg = NetConfig::by_name(name).unwrap();
        let plan = optimize(&cfg, &OptimizeOptions::default())?;
        println!(
            "{name}: bottleneck_est={} FPS(model)={:.0} LUTs={} BRAMs={} DSPs={}",
            plan.bottleneck_est,
            plan.fps,
            plan.resources.total.luts,
            plan.resources.total.brams,
            plan.resources.total.dsps
        );
        let mut t = Table::new(&["layer", "UF", "P", "Cycle_est", "Cycle_r(model)"]);
        for l in &plan.layers {
            t.row(&[
                l.geom.name.clone(),
                l.params.uf.to_string(),
                l.params.p.to_string(),
                l.cycle_est.to_string(),
                l.cycle_real.to_string(),
            ]);
        }
        t.print();
        println!();
    }
    Ok(())
}
