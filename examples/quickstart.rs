//! Quickstart: load a trained `.bcnn` model, classify a few images three
//! ways (native engine, PJRT AOT executable, FPGA-architecture simulator)
//! and check they agree.
//!
//! Run after `make artifacts`:
//!     cargo run --release --example quickstart

use repro::bcnn::Engine;
use repro::coordinator::workload::random_images;
use repro::coordinator::{Backend, FpgaSimBackend};
use repro::model::BcnnModel;
use repro::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. load the trained small model exported by python/compile/train.py
    //    (falls back to deterministic synthetic weights without artifacts)
    let model = BcnnModel::load_or_synthetic("small", "artifacts", 0xB_C0DE)?;
    println!("loaded {:?}: {} layers, {} classes", model.name, model.layers.len(), model.classes);

    // 2. native packed-u64 engine (the serving hot path)
    let engine = Engine::new(model.clone())?;
    let images = random_images(&model.config(), 4, 2024);
    let native: Vec<Vec<f32>> = engine.infer_batch(&images)?;
    for (i, s) in native.iter().enumerate() {
        let pred = s.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        println!("image {i}: class {pred} (score {:+.2})", s[pred]);
    }

    // 3. same images through the AOT-compiled JAX/Pallas graph via PJRT
    //    (skipped when the runtime or artifacts are unavailable)
    match Runtime::new("artifacts") {
        Ok(mut rt) => {
            let loaded = rt.load_model("small", 1, "artifacts/model_small.bcnn")?;
            for (i, img) in images.iter().enumerate() {
                let pjrt = loaded.infer_batch(img)?;
                let max_delta = pjrt
                    .iter()
                    .zip(&native[i])
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(max_delta < 1e-3, "PJRT diverged: {max_delta}");
            }
            println!("PJRT (AOT Pallas/JAX HLO) matches the native engine ✓");
        }
        Err(e) => println!("PJRT check skipped: {e:#}"),
    }

    // 4. same images through the paper's streaming FPGA architecture
    let mut fpga = FpgaSimBackend::new(model)?;
    let out = fpga.infer_owned(&images)?;
    assert_eq!(out.scores, native, "FPGA simulator must be bit-exact");
    let t = out.modeled_device_time.unwrap();
    println!(
        "FPGA simulator matches bit-exactly ✓  (modeled device time {:.3} ms for {} images)",
        t.as_secs_f64() * 1e3,
        images.len()
    );
    Ok(())
}
