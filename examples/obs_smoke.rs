//! Observability smoke: serve a pipeline-backed model over TCP, trace a
//! handful of requests end-to-end, validate the Chrome trace export, and
//! write it to disk.  CI runs this after the tier-1 tests and uploads
//! the resulting `trace.json` as an artifact — the file loads directly
//! in Perfetto (https://ui.perfetto.dev) or `chrome://tracing`.
//!
//! Run: `cargo run --release --example obs_smoke -- [--out trace.json]`
//! Exits nonzero if any expected span is missing.

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use repro::coordinator::workload::random_images;
use repro::model::{BcnnModel, NetConfig};
use repro::serving::{serve_registry, BackendSpec, ControlClient, DeploySpec, ModelRegistry};
use repro::util::json::Json;

const REQUESTS: usize = 16;

fn main() -> Result<()> {
    let mut out_path = "trace.json".to_string();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--out" => out_path = args.next().context("--out needs a path")?,
            other => bail!("unknown argument {other:?} (usage: obs_smoke [--out <path>])"),
        }
    }

    // deploy a pipeline-backed model so the trace has stage tracks, and
    // serve it on a loopback port like production would
    let cfg = NetConfig::tiny();
    let model = BcnnModel::synthetic(&cfg, 0x0B5);
    let n_layers = model.layers.len();
    let registry = Arc::new(ModelRegistry::new());
    registry.deploy(
        "m",
        DeploySpec::new(model)
            .with_backend(BackendSpec::Pipeline { inflight: 4, stage_threads: 0 }),
    )?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || serve_registry(listener, registry, stop))
    };

    let mut client = ControlClient::connect(&addr)?;
    let mut trace_ids = Vec::new();
    for img in &random_images(&cfg, REQUESTS, 7) {
        let reply = client.infer("m", img)?;
        if reply.trace_id == 0 {
            bail!("reply carried no trace id");
        }
        trace_ids.push(reply.trace_id);
    }
    // the last stage span lands on its ring just after the last reply;
    // one settle poll is plenty at this request count
    std::thread::sleep(std::time::Duration::from_millis(50));
    let trace = client.trace()?;
    client.close()?;
    stop.store(true, Ordering::Relaxed);
    server.join().expect("server thread").expect("server exit");

    // validate: every pipeline stage contributed at least one complete
    // span, and the traced requests appear on the shard track
    let events = trace.get("traceEvents")?.as_arr()?;
    let mut track_names: BTreeMap<u64, String> = BTreeMap::new();
    let mut spans = 0usize;
    let mut per_kind: BTreeMap<String, usize> = BTreeMap::new();
    let mut per_stage: BTreeMap<usize, usize> = BTreeMap::new();
    let mut traced_hits = 0usize;
    for e in events {
        match e.get("ph")?.as_str()? {
            "M" => {
                let tid = e.get("tid")?.as_f64()? as u64;
                track_names.insert(tid, e.get("args")?.get("name")?.as_str()?.to_string());
            }
            "X" => {
                spans += 1;
                if e.get("dur")?.as_f64()? < 0.0 {
                    bail!("span with negative duration: {e:?}");
                }
                let cat = e.get("cat")?.as_str()?.to_string();
                if cat == "stage" {
                    let layer = e.get("args")?.get("layer")?.as_f64()? as usize;
                    *per_stage.entry(layer).or_insert(0) += 1;
                }
                *per_kind.entry(cat).or_insert(0) += 1;
                let id = e.get("args")?.get("trace_id")?.as_f64()? as u64;
                if trace_ids.contains(&id) {
                    traced_hits += 1;
                }
            }
            _ => {}
        }
    }
    for kind in ["admission", "queue", "batch", "reply"] {
        if per_kind.get(kind).copied().unwrap_or(0) == 0 {
            bail!("no {kind} span in the trace");
        }
    }
    for layer in 0..n_layers {
        if per_stage.get(&layer).copied().unwrap_or(0) == 0 {
            bail!("stage {layer} recorded no spans (layers 0..{n_layers} expected)");
        }
    }
    if traced_hits < REQUESTS {
        bail!("only {traced_hits} spans match the {REQUESTS} reply trace ids");
    }

    std::fs::write(&out_path, trace.to_string())?;
    println!(
        "obs smoke OK: {spans} spans over {} tracks ({} stage layers), \
         {traced_hits} correlated with this client's {REQUESTS} requests",
        track_names.len(),
        n_layers,
    );
    println!("wrote {out_path} -- load it at https://ui.perfetto.dev");
    Ok(())
}
