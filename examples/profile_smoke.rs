//! Performance-accounting smoke: serve a deliberately skewed pipeline
//! model over TCP, drive traffic, fetch the OP_PROFILE report, and check
//! the accounting invariants end-to-end:
//!
//! * every stage that saw traffic reports a utilization in (0, 1];
//! * every layer is classified against the roofline balance point
//!   (the skew puts conv layers compute-bound and the FC layer
//!   memory-bound, so both classes must appear);
//! * the measured bottleneck (max busy per image) agrees with the
//!   eq.-12 prediction (max estimated cycles) — the skew gives the
//!   middle conv ~85x the work of its neighbour, so a miss means the
//!   accounting is wrong, not that the machine was noisy.
//!
//! Writes the report as `BENCH_profile.json` in the shared benchkit
//! envelope.  CI runs this after the tier-1 tests and uploads the
//! artifact.
//!
//! Run: `cargo run --release --example profile_smoke -- [--out <path>]`
//! Exits nonzero if any invariant fails.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use repro::coordinator::workload::random_images;
use repro::model::{BcnnModel, ConvSpec, NetConfig};
use repro::serving::{serve_registry, BackendSpec, ControlClient, DeploySpec, ModelRegistry};
use repro::util::json::Json;

const REQUESTS: usize = 64;

fn main() -> Result<()> {
    let mut out_path = String::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--out" => out_path = args.next().context("--out needs a path")?,
            other => bail!("unknown argument {other:?} (usage: profile_smoke [--out <path>])"),
        }
    }

    // the fig7 stage-balance config: conv2 (8 -> 256 channels) carries
    // ~85x conv1's estimated cycles and ~7x the FC layer's, so both the
    // predicted and the measured bottleneck land on stage 1 regardless
    // of host noise
    let cfg = NetConfig {
        name: "skewed".into(),
        conv: vec![
            ConvSpec { out_channels: 8, pool: false },
            ConvSpec { out_channels: 256, pool: false },
        ],
        fc: vec![],
        classes: 10,
        input_hw: 8,
        input_channels: 3,
        input_bits: 6,
    };
    let model = BcnnModel::synthetic(&cfg, 0x0B5);
    let n_layers = model.layers.len();
    let registry = Arc::new(ModelRegistry::new());
    registry.deploy(
        "m",
        DeploySpec::new(model)
            .with_backend(BackendSpec::Pipeline { inflight: 4, stage_threads: 0 }),
    )?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || serve_registry(listener, registry, stop))
    };

    let mut client = ControlClient::connect(&addr)?;
    for img in &random_images(&cfg, REQUESTS, 7) {
        client.infer("m", img)?;
    }
    // the final image's last-stage counters land just after the reply
    std::thread::sleep(std::time::Duration::from_millis(50));
    let profile = client.profile()?;
    client.close()?;
    stop.store(true, Ordering::Relaxed);
    server.join().expect("server thread").expect("server exit");

    // -- invariants --------------------------------------------------------
    let models = profile.get("models")?.as_arr()?;
    if models.len() != 1 {
        bail!("expected 1 profiled model, got {}", models.len());
    }
    let report = models[0].get("report")?;
    if let Ok(err) = report.get("error") {
        bail!("accounting failed server-side: {}", err.as_str().unwrap_or("?"));
    }
    let layers = report.get("layers")?.as_arr()?;
    if layers.len() != n_layers {
        bail!("report has {} layers, model has {n_layers}", layers.len());
    }
    let mut bounds = std::collections::BTreeSet::new();
    for layer in layers {
        let name = layer.get("name")?.as_str()?;
        let images = layer.get("images")?.as_f64()?;
        if images < REQUESTS as f64 {
            bail!("{name}: only {images} of {REQUESTS} images flushed through");
        }
        let util = layer.get("utilization")?.as_f64().with_context(|| {
            format!("{name}: utilization must be a number once the stage saw traffic")
        })?;
        if !(util > 0.0 && util <= 1.0) {
            bail!("{name}: utilization {util} outside (0, 1]");
        }
        let bound = layer.get("bound")?.as_str()?;
        if bound != "compute" && bound != "memory" {
            bail!("{name}: unknown roofline class {bound:?}");
        }
        bounds.insert(bound.to_string());
        for key in ["xor_words", "popcounts", "bytes_moved", "cycles_est", "cycles_real"] {
            if layer.get(key)?.as_f64()? <= 0.0 {
                bail!("{name}: ledger column {key} is not positive");
            }
        }
    }
    if bounds.len() < 2 {
        bail!("skewed config must produce both roofline classes, got {bounds:?}");
    }
    let predicted = report.get("predicted_bottleneck")?.as_usize()?;
    if predicted != 1 {
        bail!("eq.-12 prediction should pick the skewed conv (stage 1), got {predicted}");
    }
    let measured = report.get("measured_bottleneck")?.as_usize()?;
    if !report.get("bottleneck_match")?.as_bool()? {
        bail!("measured bottleneck stage {measured} disagrees with predicted {predicted}");
    }

    // -- artifact ----------------------------------------------------------
    let mut obj = std::collections::BTreeMap::new();
    obj.insert(
        "schema_version".to_string(),
        Json::Num(repro::benchkit::BENCH_SCHEMA_VERSION as f64),
    );
    obj.insert("bench".to_string(), Json::Str("profile".to_string()));
    obj.insert("git_commit".to_string(), Json::Str(repro::benchkit::git_commit()));
    obj.insert(
        "config_fingerprint".to_string(),
        Json::Str("skewed;pipeline-inflight4".to_string()),
    );
    obj.insert("profile".to_string(), profile);
    let text = Json::Obj(obj).to_string();
    if out_path.is_empty() {
        // examples run from the repo root; keep the artifact next to the
        // cargo-bench ones, falling back to the cwd outside the checkout
        out_path = "rust/BENCH_profile.json".to_string();
        if std::fs::write(&out_path, &text).is_err() {
            out_path = "BENCH_profile.json".to_string();
            std::fs::write(&out_path, &text)?;
        }
    } else {
        std::fs::write(&out_path, &text)?;
    }

    println!(
        "profile smoke OK: {n_layers} stages, utilization in (0,1], roofline classes \
         {bounds:?}, bottleneck measured == predicted == stage {predicted}"
    );
    println!("wrote {out_path}");
    Ok(())
}
