//! Multi-model serving with zero-downtime hot-swap (the control plane the
//! ROADMAP's "millions of users" north star needs on top of the paper's
//! batch-insensitive dataplane).
//!
//! The scenario: a server starts with one production model, takes
//! continuous client traffic over protocol v2, and — while the load loop
//! never pauses — deploys a retrained candidate over the same name,
//! rolls it back, repeats, and runs a second model side by side.  The
//! example asserts the control plane's contract the whole way:
//!
//! * zero dropped replies: every submitted request is answered;
//! * bit-exact versioning: every reply's scores equal a direct
//!   `Engine::infer` of exactly the model *version* the reply claims
//!   served it;
//! * conserved accounting: protocol-v2 `STATS` per-model requests sum to
//!   the number of client submissions.
//!
//! Run:  cargo run --release --example serve_multimodel
//! CI:   BENCH_SMOKE=1 shortens the load loop; the run always writes a
//!       `BENCH_hotswap.json` artifact (path override: BENCH_OUT).

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::bcnn::Engine;
use repro::coordinator::workload::random_images;
use repro::model::{BcnnModel, NetConfig};
use repro::serving::{serve_registry, ControlClient, DeploySpec, ModelRegistry};
use repro::util::json::Json;

const PROD_SEED: u64 = 11;
const CANDIDATE_SEED: u64 = 22;
const SWAP_CYCLES: usize = 3;
const CLIENT_THREADS: usize = 3;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let dwell = if smoke { Duration::from_millis(40) } else { Duration::from_millis(150) };

    let cfg = NetConfig::tiny();
    let prod = BcnnModel::synthetic(&cfg, PROD_SEED);
    let candidate = BcnnModel::synthetic(&cfg, CANDIDATE_SEED);
    let engine_prod = Engine::new(prod.clone())?;
    let engine_cand = Engine::new(candidate.clone())?;

    // -- control plane + TCP front-end -----------------------------------
    let registry = Arc::new(ModelRegistry::new());
    let v1 = registry.deploy("prod", DeploySpec::new(prod).with_workers(2))?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let registry = Arc::clone(&registry);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || serve_registry(listener, registry, stop))
    };
    println!("serving on {addr}; model prod v{v1} (seed {PROD_SEED})");

    // versions -> which engine must have produced the reply's scores
    // (v1 = prod weights; wire deploys/rollbacks extend this map below)
    let mut version_seed: BTreeMap<u64, u64> = BTreeMap::new();
    version_seed.insert(v1, PROD_SEED);

    // -- continuous client load over protocol v2 -------------------------
    let images = random_images(&cfg, 8, 77);
    let submitted = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for t in 0..CLIENT_THREADS {
        let addr = addr.clone();
        let images = images.clone();
        let stop = Arc::clone(&stop);
        let submitted = Arc::clone(&submitted);
        clients.push(std::thread::spawn(move || -> anyhow::Result<Vec<(usize, u64, Vec<f32>)>> {
            let mut conn = ControlClient::connect(&addr)?;
            let mut got = Vec::new();
            let mut i = t; // stagger the image cycle per thread
            while !stop.load(Ordering::Relaxed) {
                let idx = i % images.len();
                submitted.fetch_add(1, Ordering::Relaxed);
                let reply = conn.infer("prod", &images[idx])?; // any error = a drop
                got.push((idx, reply.version, reply.scores));
                i += 1;
            }
            conn.close()?;
            Ok(got)
        }));
    }

    // -- hot-swap cycles under load, over the wire -----------------------
    let mut admin = ControlClient::connect(&addr)?;
    let t0 = Instant::now();
    std::thread::sleep(dwell);
    for cycle in 1..=SWAP_CYCLES {
        let v = admin.deploy(
            "prod",
            &format!("synthetic:tiny:{CANDIDATE_SEED}"),
            "engine",
            2,
            0,
        )?;
        version_seed.insert(v, CANDIDATE_SEED);
        println!("cycle {cycle}: deployed candidate as prod v{v}");
        std::thread::sleep(dwell);
        let v = admin.rollback("prod")?;
        version_seed.insert(v, PROD_SEED);
        println!("cycle {cycle}: rolled back to prod weights as v{v}");
        std::thread::sleep(dwell);
    }

    // a second model running side by side, then retired
    let v = admin.deploy("canary", "synthetic:tiny:33", "engine", 1, 0)?;
    println!("deployed canary v{v}");
    let canary_scores = admin.infer("canary", &images[0])?;
    assert_eq!(canary_scores.version, v, "canary reply tagged with wrong version");
    let retired = admin.undeploy("canary")?;
    assert_eq!(retired, v);

    stop.store(true, Ordering::Relaxed);
    let mut replies: Vec<(usize, u64, Vec<f32>)> = Vec::new();
    for c in clients {
        replies.extend(c.join().expect("client thread panicked")?);
    }
    let wall = t0.elapsed();

    // -- the contract -----------------------------------------------------
    let submitted = submitted.load(Ordering::Relaxed);
    assert_eq!(
        replies.len() as u64,
        submitted,
        "dropped replies: {} submitted, {} answered",
        submitted,
        replies.len()
    );
    for (idx, version, scores) in &replies {
        let seed = version_seed
            .get(version)
            .unwrap_or_else(|| panic!("reply claims unknown version {version}"));
        let engine = if *seed == PROD_SEED { &engine_prod } else { &engine_cand };
        let want = engine.infer(&images[*idx])?;
        assert_eq!(&want, scores, "v{version} reply diverged from its engine");
    }

    let stats = admin.stats()?;
    admin.close()?;
    let mut stats_requests = 0u64;
    for m in stats.get("models")?.as_arr()? {
        stats_requests += m.get("metrics")?.get("requests")?.as_f64()? as u64;
    }
    assert_eq!(
        stats_requests,
        submitted + 1,
        "STATS per-model counts must sum to submissions"
    );

    println!(
        "\nhot-swap under load: {} requests over {:.2}s across {} version flips — \
         zero drops, all replies bit-exact for their serving version",
        submitted + 1,
        wall.as_secs_f64(),
        2 * SWAP_CYCLES
    );

    // -- artifact ---------------------------------------------------------
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert(
        "schema_version".into(),
        Json::Num(repro::benchkit::BENCH_SCHEMA_VERSION as f64),
    );
    obj.insert("bench".into(), Json::Str("hotswap".into()));
    obj.insert("git_commit".into(), Json::Str(repro::benchkit::git_commit()));
    obj.insert("config_fingerprint".into(), Json::Str("tiny;hot-swap-cycles".into()));
    obj.insert("requests".into(), Json::Num((submitted + 1) as f64));
    obj.insert("dropped".into(), Json::Num(0.0));
    obj.insert("swap_cycles".into(), Json::Num(SWAP_CYCLES as f64));
    obj.insert("version_flips".into(), Json::Num((2 * SWAP_CYCLES) as f64));
    obj.insert("wall_s".into(), Json::Num(wall.as_secs_f64()));
    obj.insert(
        "throughput_rps".into(),
        Json::Num((submitted + 1) as f64 / wall.as_secs_f64().max(1e-9)),
    );
    obj.insert("stats".into(), stats);
    let json = Json::Obj(obj);
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "rust/BENCH_hotswap.json".into());
    let text = json.to_string();
    if std::fs::write(&path, &text).is_err() {
        // running from inside rust/ (e.g. `cargo bench` cwd): fall back
        std::fs::write("BENCH_hotswap.json", &text)?;
        println!("wrote BENCH_hotswap.json");
    } else {
        println!("wrote {path}");
    }

    // a server-side accept-loop error must fail the smoke run
    server.join().expect("server thread panicked")?;
    Ok(())
}
