//! Online-serving scenario (paper §6.3): individual classification
//! requests arriving at modest rates — the Baidu batch-8..16 regime where
//! the paper's FPGA wins 8.3x over the GPU.
//!
//! Drives the sharded coordinator with an open-loop Poisson workload
//! against the FPGA-simulator backend and the GPU-model backend, prints
//! the serving comparison (throughput, latency, modeled energy), then
//! sweeps the pool's worker count on the native backend to show host-side
//! throughput scaling with engine replicas.
//!
//! Run (trained artifacts optional — synthetic weights otherwise):
//!     cargo run --release --example serve_online -- \
//!         [--backend engine|pipeline] [--inflight N] [--stage-threads T]
//!
//! `--backend pipeline` serves the final section from the row-streaming
//! layer-pipeline runtime (all layers concurrently active) instead of the
//! sequential engine; `--inflight` sets its per-replica admission window
//! and `--stage-threads` a total stage-lane budget that the calibrated
//! §4.3 balancing plan spreads across the layers (0 = one lane each).

use std::sync::Arc;
use std::time::Duration;

use repro::bcnn::Engine;
use repro::benchkit::Table;
use repro::coordinator::workload::{run_closed_loop, run_open_loop};
use repro::coordinator::{
    Backend, BackendFactory, BatchPolicy, Coordinator, CoordinatorConfig, FpgaSimBackend,
    GpuSimBackend, NativeBackend, PipelineBackend,
};
use repro::gpu::{GpuKernel, XNOR_POWER_W};
use repro::model::BcnnModel;
use repro::pipeline::StagePlan;

/// `--key value` lookup over the raw argv (the examples stay free of the
/// CLI parser on purpose: they document the library API, not the binary).
fn arg_value(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let backend_kind = arg_value("--backend").unwrap_or_else(|| "engine".into());
    let inflight: usize = match arg_value("--inflight") {
        Some(v) => v.parse()?,
        None => 8,
    };
    let stage_threads: usize = match arg_value("--stage-threads") {
        Some(v) => v.parse()?,
        None => 0,
    };
    if !matches!(backend_kind.as_str(), "engine" | "native" | "pipeline") {
        anyhow::bail!("--backend must be engine or pipeline, got {backend_kind:?}");
    }
    let model = BcnnModel::load_or_synthetic("tiny", "artifacts", 0xB_C0DE)?;
    let cfg = model.config();
    const REQUESTS: usize = 96;
    const RATE: f64 = 400.0; // requests/s — an "online" trickle

    let mut table = Table::new(&[
        "backend",
        "req/s",
        "mean latency ms",
        "mean batch",
        "modeled busy ms",
        "modeled J",
    ]);

    for which in ["fpga-sim", "gpu-sim-xnor"] {
        let backend: Box<dyn Backend + Send> = match which {
            "fpga-sim" => Box::new(FpgaSimBackend::new(model.clone())?),
            _ => Box::new(GpuSimBackend::new(model.clone(), GpuKernel::Xnor)?),
        };
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(2) },
                ..CoordinatorConfig::default()
            },
        );
        let report = run_open_loop(&coord.client(), &cfg, REQUESTS, RATE, 7)?;
        let metrics = coord.shutdown();
        let power = if which == "fpga-sim" { 8.2 } else { XNOR_POWER_W };
        table.row(&[
            which.to_string(),
            format!("{:.0}", report.throughput()),
            format!("{:.2}", report.mean_latency().as_secs_f64() * 1e3),
            format!("{:.1}", report.mean_batch()),
            format!("{:.2}", metrics.modeled_busy.as_secs_f64() * 1e3),
            format!("{:.4}", metrics.modeled_energy_j(power)),
        ]);
    }

    println!(
        "online serving: {REQUESTS} requests, Poisson {RATE}/s, max_batch 16, max_wait 2 ms\n"
    );
    table.print();
    println!(
        "\nreading: at online rates the batcher forms small batches; the\n\
         FPGA's modeled busy time (and energy) stays low and flat while the\n\
         GPU model pays its latency-hiding penalty — the paper's §6.3 claim\n\
         on the serving path."
    );

    // --- host-side scaling: the same pool, more backend replicas --------
    println!(
        "\nhost scaling ({backend_kind} backend, max_wait 0, closed loop, \
         inflight {inflight}):\n"
    );
    let mut table = Table::new(&["workers", "req/s", "speedup", "per-shard requests"]);
    let mut base = 0.0f64;
    // calibrate the stage plan ONCE (idle machine, no sibling replicas
    // skewing the timing) and share it across every replica of every
    // pool size — all shards run identical lane counts
    let stage_plan = if stage_threads > 0 {
        Some(StagePlan::balanced(&Engine::new(model.clone())?, stage_threads)?)
    } else {
        None
    };
    for workers in [1usize, 2, 4] {
        let m = model.clone();
        let kind = backend_kind.clone();
        let plan = stage_plan.clone();
        let factory: BackendFactory = Arc::new(move || -> anyhow::Result<Box<dyn Backend>> {
            Ok(match (kind.as_str(), &plan) {
                ("pipeline", Some(plan)) => {
                    Box::new(PipelineBackend::with_plan(m.clone(), inflight, plan.clone())?)
                }
                ("pipeline", None) => Box::new(PipelineBackend::new(m.clone(), inflight)?),
                _ => Box::new(NativeBackend::new(m.clone())?),
            })
        });
        let coord = Coordinator::start_sharded(
            factory,
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 16, max_wait: Duration::ZERO },
                workers,
                queue_depth: 64,
            },
        )?;
        let report = run_closed_loop(&coord.client(), &cfg, 256, 13)?;
        let per_shard: Vec<u64> = coord.shard_metrics().iter().map(|m| m.requests).collect();
        coord.shutdown();
        let rps = report.throughput();
        if workers == 1 {
            base = rps;
        }
        table.row(&[
            workers.to_string(),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / base.max(1e-9)),
            format!("{per_shard:?}"),
        ]);
    }
    table.print();
    println!(
        "\nreading: the bounded-queue sharded pool replicates the backend the\n\
         way the FPGA replicates PEs — host throughput scales with workers\n\
         instead of collapsing on a single serving thread.  With\n\
         `--backend pipeline` each replica is itself a layer pipeline (one\n\
         thread per layer), so batch-1 requests already use every stage —\n\
         the paper's batch-insensitive serving, measured head-to-head in\n\
         `cargo bench --bench fig7_batch_sweep`."
    );
    Ok(())
}
