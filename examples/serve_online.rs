//! Online-serving scenario (paper §6.3): individual classification
//! requests arriving at modest rates — the Baidu batch-8..16 regime where
//! the paper's FPGA wins 8.3x over the GPU.
//!
//! Drives the coordinator with an open-loop Poisson workload against the
//! FPGA-simulator backend and the GPU-model backend, then prints the
//! serving comparison (throughput, latency, modeled energy).
//!
//! Run after `make artifacts`:
//!     cargo run --release --example serve_online

use std::time::Duration;

use repro::benchkit::Table;
use repro::coordinator::workload::run_open_loop;
use repro::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, FpgaSimBackend, GpuSimBackend,
};
use repro::gpu::{GpuKernel, XNOR_POWER_W};
use repro::model::BcnnModel;

fn main() -> anyhow::Result<()> {
    let model = BcnnModel::load("artifacts/model_tiny.bcnn")?;
    let cfg = model.config();
    const REQUESTS: usize = 96;
    const RATE: f64 = 400.0; // requests/s — an "online" trickle

    let mut table = Table::new(&[
        "backend",
        "req/s",
        "mean latency ms",
        "mean batch",
        "modeled busy ms",
        "modeled J",
    ]);

    for which in ["fpga-sim", "gpu-sim-xnor"] {
        let backend: Box<dyn repro::coordinator::Backend + Send> = match which {
            "fpga-sim" => Box::new(FpgaSimBackend::new(model.clone())?),
            _ => Box::new(GpuSimBackend::new(model.clone(), GpuKernel::Xnor)),
        };
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(2) },
            },
        );
        let report = run_open_loop(&coord.client(), &cfg, REQUESTS, RATE, 7)?;
        let metrics = coord.shutdown();
        let power = if which == "fpga-sim" { 8.2 } else { XNOR_POWER_W };
        table.row(&[
            which.to_string(),
            format!("{:.0}", report.throughput()),
            format!("{:.2}", report.mean_latency().as_secs_f64() * 1e3),
            format!("{:.1}", report.mean_batch()),
            format!("{:.2}", metrics.modeled_busy.as_secs_f64() * 1e3),
            format!("{:.4}", metrics.modeled_energy_j(power)),
        ]);
    }

    println!(
        "online serving: {REQUESTS} requests, Poisson {RATE}/s, max_batch 16, max_wait 2 ms\n"
    );
    table.print();
    println!(
        "\nreading: at online rates the batcher forms small batches; the\n\
         FPGA's modeled busy time (and energy) stays low and flat while the\n\
         GPU model pays its latency-hiding penalty — the paper's §6.3 claim\n\
         on the serving path."
    );
    Ok(())
}
