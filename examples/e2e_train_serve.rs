//! End-to-end driver (DESIGN.md §5 headline experiment): the full system
//! on a real small workload, proving every layer composes.
//!
//! Pipeline exercised here:
//!   1. `make artifacts` trained the SMALL BCNN in JAX (straight-through
//!      estimator, ~250 steps on the synthetic 10-class dataset), folded
//!      the batch-norm into integer thresholds (paper §3.2), exported
//!      `.bcnn` weights + a held-out test set + AOT HLO text;
//!   2. this binary — pure rust, no python — loads those artifacts, runs
//!      the held-out set through the coordinator's serving path on the
//!      native engine, cross-checks a sample against the PJRT-compiled
//!      Pallas/JAX graph, and reports accuracy + serving metrics;
//!   3. the same images go through the FPGA-architecture simulator to
//!      report the paper-style modeled FPS at 90 MHz.
//!
//! Run after `make artifacts`:
//!     cargo run --release --example e2e_train_serve

use std::time::Duration;

use repro::bcnn::Engine;
use repro::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, NativeBackend};
use repro::fpga::stream::{simulate, StreamConfig};
use repro::fpga::timing::PipelineModel;
use repro::fpga::DEFAULT_FREQ_HZ;
use repro::model::{BcnnModel, TestSet};
use repro::optimizer::{optimize, OptimizeOptions};
use repro::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let model = BcnnModel::load("artifacts/model_small.bcnn")?;
    let testset = TestSet::load("artifacts/testset_small.bin")?;
    println!(
        "trained model {:?}; held-out synthetic test set: {} samples, {} classes",
        model.name,
        testset.len(),
        testset.classes
    );

    // --- serve the test set through the coordinator (native hot path) ---
    let coord = Coordinator::start(
        Box::new(NativeBackend::new(model.clone())?),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(1) },
            ..CoordinatorConfig::default()
        },
    );
    let client = coord.client();
    let pending = testset
        .images
        .iter()
        .map(|img| client.submit_blocking(img.clone()))
        .collect::<Result<Vec<_>, _>>()?;
    let mut correct = 0usize;
    let mut preds = Vec::with_capacity(testset.len());
    for (rx, &label) in pending.into_iter().zip(&testset.labels) {
        let reply = rx.recv()?;
        let pred = reply.argmax().ok_or_else(|| anyhow::anyhow!("error reply"))?;
        preds.push(pred);
        if pred == label as usize {
            correct += 1;
        }
    }
    let metrics = coord.shutdown();
    let accuracy = correct as f64 / testset.len() as f64;
    println!("\nserving results (native engine through the dynamic batcher):");
    println!("  accuracy     : {:.2}% ({} / {})", accuracy * 100.0, correct, testset.len());
    println!("  {}", metrics.summary());
    assert!(accuracy > 0.9, "trained model should be near-perfect on this task");

    // --- cross-check a sample against the AOT PJRT path ---
    let mut rt = Runtime::new("artifacts")?;
    let loaded = rt.load_model("small", 1, "artifacts/model_small.bcnn")?;
    let engine = Engine::new(model.clone())?;
    for (i, img) in testset.images.iter().take(8).enumerate() {
        let pjrt = loaded.infer_batch(img)?;
        let native = engine.infer(img)?;
        for (a, b) in pjrt.iter().zip(&native) {
            assert!((a - b).abs() < 1e-3, "sample {i}: PJRT {a} vs native {b}");
        }
    }
    println!("  PJRT (AOT JAX+Pallas) agrees with the native engine on 8 samples ✓");

    // --- modeled FPGA deployment of the same trained network ---
    let net = model.config();
    let plan = optimize(&net, &OptimizeOptions::default())?;
    let config = StreamConfig {
        freq_hz: DEFAULT_FREQ_HZ,
        params: plan.layers.iter().map(|l| l.params).collect(),
        pipeline: PipelineModel::default(),
        double_buffered: true,
    };
    let sample: Vec<Vec<i32>> = testset.images.iter().take(16).cloned().collect();
    let report = simulate(&engine, &config, &sample)?;
    for (img, s) in sample.iter().zip(&report.scores) {
        assert_eq!(&engine.infer(img)?, s);
    }
    println!("\nmodeled FPGA deployment (streaming architecture @ 90 MHz):");
    println!("  steady FPS      : {:.0}", report.fps);
    println!("  first latency   : {:.3} ms", report.first_latency_s * 1e3);
    println!("  phase cycles    : {}", report.phase_cycles);
    println!("  numerics        : bit-exact vs engine ✓");
    println!("\nE2E OK: train(JAX/Pallas) -> fold -> export -> rust serve/simulate");
    Ok(())
}
