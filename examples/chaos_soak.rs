//! Chaos soak — the supervision layer's acceptance artifact (DESIGN.md
//! §6): mixed-deadline load against a two-model registry while a
//! deterministic fault plan crashes shard workers, kills a pipeline
//! stage, injects latency storms, and fakes queue-full storms at submit.
//!
//! The run asserts the fault-model contract end to end:
//!
//! * request conservation — every submission is answered exactly once,
//!   with scores or a *typed* error; nothing hangs, nothing is silently
//!   dropped (`lost == 0`);
//! * bit-exactness under degradation — every successful reply equals the
//!   scalar `Engine::infer` oracle, including replies served after the
//!   pipeline model failed over to its sequential-engine path;
//! * availability — with one client-side retry, >= 99% of requests
//!   succeed while workers are being crashed and restarted under load;
//! * observable supervision — the merged pool metrics show `crashes`,
//!   `restarts`, and `requests_failed_over` all strictly positive (the
//!   faults actually fired and the supervisor actually healed them).
//!
//! Run:  cargo run --release --example chaos_soak
//! CI:   BENCH_SMOKE=1 shortens the soak; BCNN_FAULTS overrides the
//!       default plan; always writes `BENCH_chaos.json` (path override:
//!       BENCH_OUT).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::bcnn::Engine;
use repro::coordinator::workload::random_images;
use repro::coordinator::Metrics;
use repro::model::{BcnnModel, NetConfig};
use repro::serving::{BackendSpec, DeploySpec, ModelRegistry};
use repro::util::faults::{self, FaultPlan, FAULTS_ENV};
use repro::util::json::Json;

const MODEL_SEED: u64 = 5;
const IMAGE_POOL: usize = 64;
const CLIENT_THREADS: usize = 4;
/// Per-request submit budgets, cycled: tight deadlines exercise the
/// give-up path, loose ones the retry-until-admitted path.
const DEADLINES: [Duration; 3] =
    [Duration::from_millis(2), Duration::from_millis(20), Duration::from_millis(200)];
const RETRY_DEADLINE: Duration = Duration::from_millis(200);
const REPLY_TIMEOUT: Duration = Duration::from_secs(10);

/// Default fault plan: worker panics frequent enough to observe several
/// crash/restart cycles even in the smoke run (but never 5 in a row, so
/// the breaker stays closed and the pools stay serviceable), one stage
/// death to force the pipeline model onto its engine fallback, a small
/// latency storm, and a synthetic queue-full storm at submit.
const DEFAULT_PLAN: &str = "seed=1337;\
     backend_infer:panic@every=40;\
     backend_infer:delay=2ms@p=0.02;\
     stage_emit:panic@once=400;\
     submit:deny@every=97";

#[derive(Default, Clone, Copy)]
struct Counters {
    submitted: u64,
    succeeded: u64,
    /// Failed first attempt, succeeded on the single retry.
    retried: u64,
    /// Failed even after the retry (typed both times — still conserved).
    failed: u64,
    /// Conservation violations: a reply channel that never answered.
    lost: u64,
    /// Successful replies whose scores diverged from the scalar oracle.
    mismatches: u64,
}

enum Outcome {
    Scores(Vec<f32>),
    Failed(String),
    Lost(String),
}

/// One routed request: health-aware resolve, deadline-bounded submit,
/// then wait for the reply.  Every path yields a classified outcome.
fn attempt(registry: &ModelRegistry, name: &str, img: &[i32], deadline: Duration) -> Outcome {
    let entry = match registry.router().resolve_healthy(Some(name)) {
        Ok(e) => e,
        Err(e) => return Outcome::Failed(e.to_string()),
    };
    let rx = match entry.client().submit_deadline(img.to_vec(), deadline) {
        Ok(rx) => rx,
        Err(e) => return Outcome::Failed(e.to_string()),
    };
    match rx.recv_timeout(REPLY_TIMEOUT) {
        Ok(reply) => match reply.scores {
            Ok(s) => Outcome::Scores(s),
            Err(e) => Outcome::Failed(e.to_string()),
        },
        Err(e) => Outcome::Lost(format!("reply channel: {e}")),
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let soak = if smoke { Duration::from_millis(1300) } else { Duration::from_secs(6) };

    // BCNN_FAULTS overrides the default plan (CI pins its own seed).
    let spec = std::env::var(FAULTS_ENV).unwrap_or_else(|_| DEFAULT_PLAN.into());
    faults::install(FaultPlan::parse(&spec)?);
    println!("fault plan: {spec}");

    // Two models over the SAME weights: failover between them (and the
    // pipeline model's internal engine fallback) must stay bit-exact.
    let cfg = NetConfig::tiny();
    let model = BcnnModel::synthetic(&cfg, MODEL_SEED);
    let oracle_engine = Engine::new(model.clone())?;
    let images = Arc::new(random_images(&cfg, IMAGE_POOL, 77));
    let oracle: Arc<Vec<Vec<f32>>> = Arc::new(
        images.iter().map(|img| oracle_engine.infer(img)).collect::<anyhow::Result<_>>()?,
    );

    let registry = Arc::new(ModelRegistry::new());
    registry.deploy("alpha", DeploySpec::new(model.clone()).with_workers(2))?;
    registry.deploy(
        "beta",
        DeploySpec::new(model)
            .with_backend(BackendSpec::Pipeline { inflight: 4, stage_threads: 0 })
            .with_workers(1),
    )?;
    println!(
        "deployed alpha (engine, 2 shards) + beta (pipeline, 1 shard); \
         soaking for {:.1}s with {CLIENT_THREADS} clients",
        soak.as_secs_f64()
    );

    // -- mixed-deadline load until the soak window closes -----------------
    let stop = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let mut drivers = Vec::new();
    for t in 0..CLIENT_THREADS {
        let registry = Arc::clone(&registry);
        let images = Arc::clone(&images);
        let oracle = Arc::clone(&oracle);
        let stop = Arc::clone(&stop);
        drivers.push(std::thread::spawn(move || {
            let name = if t % 2 == 0 { "alpha" } else { "beta" };
            let mut c = Counters::default();
            let mut i = t; // stagger image/deadline cycles per thread
            while !stop.load(Ordering::Relaxed) {
                let idx = i % images.len();
                let deadline = DEADLINES[i % DEADLINES.len()];
                c.submitted += 1;
                let score = |c: &mut Counters, s: Vec<f32>, on_retry: bool| {
                    if s == oracle[idx] {
                        if on_retry {
                            c.retried += 1;
                        } else {
                            c.succeeded += 1;
                        }
                    } else {
                        c.mismatches += 1;
                    }
                };
                match attempt(&registry, name, &images[idx], deadline) {
                    Outcome::Scores(s) => score(&mut c, s, false),
                    Outcome::Lost(_) => c.lost += 1,
                    Outcome::Failed(_) => {
                        // typed failure: the request rode a crashed batch
                        // or was shed — one retry against a (possibly
                        // failed-over) healthy path
                        match attempt(&registry, name, &images[idx], RETRY_DEADLINE) {
                            Outcome::Scores(s) => score(&mut c, s, true),
                            Outcome::Lost(_) => c.lost += 1,
                            Outcome::Failed(_) => c.failed += 1,
                        }
                    }
                }
                i += 1;
            }
            c
        }));
    }
    std::thread::sleep(soak);
    stop.store(true, Ordering::Relaxed);
    let mut total = Counters::default();
    for d in drivers {
        let c = d.join().expect("driver thread panicked");
        total.submitted += c.submitted;
        total.succeeded += c.succeeded;
        total.retried += c.retried;
        total.failed += c.failed;
        total.lost += c.lost;
        total.mismatches += c.mismatches;
    }
    let wall = t0.elapsed();

    // -- supervision observability across both pools ----------------------
    let mut merged = Metrics::new();
    for s in registry.stats() {
        println!("model {} v{} [{}]: {}", s.name, s.version, s.backend, s.metrics.summary());
        merged.merge(&s.metrics);
    }
    for (rule, fired) in faults::fired_counts() {
        println!("fault {rule}: fired {fired}x");
    }

    let ok = total.succeeded + total.retried;
    let availability = ok as f64 / total.submitted.max(1) as f64;
    println!(
        "\nchaos soak: {} requests over {:.2}s — {} ok ({} via retry), {} failed, \
         {} lost, {} mismatched; availability {:.4}",
        total.submitted,
        wall.as_secs_f64(),
        ok,
        total.retried,
        total.failed,
        total.lost,
        total.mismatches,
        availability
    );
    println!(
        "supervision: {} crashes, {} restarts, {} requests served via failover",
        merged.crashes, merged.restarts, merged.requests_failed_over
    );

    // -- the contract ------------------------------------------------------
    assert_eq!(total.lost, 0, "request conservation violated: {} replies lost", total.lost);
    assert_eq!(total.mismatches, 0, "successful replies must match the scalar oracle");
    assert!(
        availability >= 0.99,
        "availability {availability:.4} under faults fell below 0.99"
    );
    assert!(merged.crashes > 0, "fault plan fired no worker crashes — soak proved nothing");
    assert!(merged.restarts > 0, "workers crashed but the supervisor never restarted one");
    assert!(
        merged.requests_failed_over > 0,
        "no requests were served via a degradation path"
    );

    // -- artifact ----------------------------------------------------------
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert(
        "schema_version".into(),
        Json::Num(repro::benchkit::BENCH_SCHEMA_VERSION as f64),
    );
    obj.insert("bench".into(), Json::Str("chaos_soak".into()));
    obj.insert("git_commit".into(), Json::Str(repro::benchkit::git_commit()));
    obj.insert("config_fingerprint".into(), Json::Str("tiny;fault-plan-soak".into()));
    obj.insert("requests".into(), Json::Num(total.submitted as f64));
    obj.insert("succeeded".into(), Json::Num(ok as f64));
    obj.insert("retried".into(), Json::Num(total.retried as f64));
    obj.insert("failed".into(), Json::Num(total.failed as f64));
    obj.insert("lost".into(), Json::Num(total.lost as f64));
    obj.insert("mismatches".into(), Json::Num(total.mismatches as f64));
    obj.insert("availability".into(), Json::Num(availability));
    obj.insert("p50_us".into(), Json::Num(merged.p50().as_micros() as f64));
    obj.insert("p99_us".into(), Json::Num(merged.p99().as_micros() as f64));
    obj.insert("crashes".into(), Json::Num(merged.crashes as f64));
    obj.insert("restarts".into(), Json::Num(merged.restarts as f64));
    obj.insert("requests_failed_over".into(), Json::Num(merged.requests_failed_over as f64));
    obj.insert("duration_s".into(), Json::Num(wall.as_secs_f64()));
    obj.insert("smoke".into(), Json::Bool(smoke));
    obj.insert("fault_plan".into(), Json::Str(spec));
    obj.insert(
        "faults_fired".into(),
        Json::Obj(
            faults::fired_counts()
                .into_iter()
                .map(|(rule, n)| (rule, Json::Num(n as f64)))
                .collect(),
        ),
    );
    let json = Json::Obj(obj);
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "rust/BENCH_chaos.json".into());
    let text = json.to_string();
    if std::fs::write(&path, &text).is_err() {
        // running from inside rust/ (e.g. CI cwd): fall back
        std::fs::write("BENCH_chaos.json", &text)?;
        println!("wrote BENCH_chaos.json");
    } else {
        println!("wrote {path}");
    }
    faults::clear();
    Ok(())
}
