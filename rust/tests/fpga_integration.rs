//! FPGA simulator integration: streaming schedule vs engine numerics,
//! double-buffering ablation, optimizer plans on real models.

use repro::bcnn::Engine;
use repro::coordinator::workload::random_images;
use repro::fpga::stream::{simulate, StreamConfig};
use repro::fpga::timing::{LayerParams, PipelineModel};
use repro::fpga::{layer_geometry, DEFAULT_FREQ_HZ};
use repro::model::{BcnnModel, NetConfig};
use repro::optimizer::{optimize, OptimizeOptions};

fn load(name: &str) -> BcnnModel {
    BcnnModel::load_or_synthetic(name, "artifacts", 0xB_C0DE).expect("built-in config")
}

fn stream_config(model: &BcnnModel) -> StreamConfig {
    let net = model.config();
    let plan = optimize(&net, &OptimizeOptions::default()).unwrap();
    StreamConfig {
        freq_hz: DEFAULT_FREQ_HZ,
        params: plan.layers.iter().map(|l| l.params).collect(),
        pipeline: PipelineModel::default(),
        double_buffered: true,
    }
}

#[test]
fn stream_scores_bit_exact_vs_engine() {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let config = stream_config(&model);
    let images = random_images(&model.config(), 7, 21);
    let report = simulate(&engine, &config, &images).unwrap();
    assert_eq!(report.scores.len(), images.len());
    for (img, got) in images.iter().zip(&report.scores) {
        assert_eq!(&engine.infer(img).unwrap(), got, "simulator numerics diverged");
    }
}

#[test]
fn stream_throughput_is_bottleneck_bound() {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let config = stream_config(&model);
    let images = random_images(&model.config(), 12, 22);
    let report = simulate(&engine, &config, &images).unwrap();
    let bottleneck = *report.layer_cycles.iter().max().unwrap();
    assert_eq!(report.phase_cycles, bottleneck);
    // steady state: one image per phase; fill adds n_layers phases
    let phases = report.total_cycles / report.phase_cycles;
    assert!(
        phases as usize >= images.len()
            && phases as usize <= images.len() + report.layer_cycles.len() + 1,
        "phases {phases} images {}",
        images.len()
    );
}

#[test]
fn double_buffering_ablation_matches_sum_over_max() {
    // without double buffering throughput degrades by sum(C)/max(C) —
    // the time-multiplexed single-layer scheme of Ref. 21 (paper §6.2)
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let mut config = stream_config(&model);
    let images = random_images(&model.config(), 6, 23);
    let on = simulate(&engine, &config, &images).unwrap();
    config.double_buffered = false;
    let off = simulate(&engine, &config, &images).unwrap();
    for (a, b) in on.scores.iter().zip(&off.scores) {
        assert_eq!(a, b, "ablation must not change numerics");
    }
    let sum: u64 = on.layer_cycles.iter().sum();
    let max: u64 = *on.layer_cycles.iter().max().unwrap();
    let expected_ratio = sum as f64 / max as f64;
    let measured_ratio = on.fps / off.fps;
    assert!(
        (measured_ratio - expected_ratio).abs() / expected_ratio < 0.01,
        "ratio {measured_ratio} vs {expected_ratio}"
    );
    assert!(measured_ratio > 1.5, "streaming must be a real win: {measured_ratio}");
}

#[test]
fn latency_is_layers_plus_feed_times_phase() {
    // an image spends one phase in the host-feed channel plus one phase
    // per layer (the input load is double-buffered like every other
    // channel, §4.3), so first latency = (L + 1) * phase
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let config = stream_config(&model);
    let images = random_images(&model.config(), 3, 24);
    let report = simulate(&engine, &config, &images).unwrap();
    let n_layers = report.layer_cycles.len() as f64;
    let expected = (n_layers + 1.0) * report.phase_cycles as f64 / config.freq_hz;
    assert!(
        (report.first_latency_s - expected).abs() / expected < 0.01,
        "latency {} vs expected {expected}",
        report.first_latency_s
    );
}

#[test]
fn table2_plan_hits_paper_fps_band() {
    // full Table-2 design at the paper's design point: the modeled system
    // FPS must land within 25% of the paper's 6218 (see EXPERIMENTS.md for
    // the exact deltas; the residual is unmodeled HLS control overhead)
    let plan = repro::tables::default_plan();
    assert!((plan.fps - 6218.0).abs() / 6218.0 < 0.25, "modeled fps {}", plan.fps);
}

#[test]
fn optimizer_plans_are_feasible_for_all_configs() {
    for name in ["tiny", "small", "table2"] {
        let cfg = NetConfig::by_name(name).unwrap();
        let plan = optimize(&cfg, &OptimizeOptions::default()).unwrap();
        assert!(plan.resources.fits(), "{name} plan does not fit");
        assert!(plan.fps > 0.0);
        // every layer meets the bottleneck target
        for l in &plan.layers {
            assert!(l.cycle_est <= plan.bottleneck_est, "{}", l.geom.name);
        }
    }
}

#[test]
fn stream_rejects_wrong_param_count() {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let config = StreamConfig {
        freq_hz: DEFAULT_FREQ_HZ,
        params: vec![LayerParams::new(32, 2)], // wrong: model has 4 layers
        pipeline: PipelineModel::default(),
        double_buffered: true,
    };
    assert!(simulate(&engine, &config, &random_images(&model.config(), 1, 0)).is_err());
}

#[test]
fn small_model_geometry_consistency() {
    // geometry derived from the .bcnn file equals the static config
    let model = load("small");
    let from_file = layer_geometry(&model.config());
    let from_static = layer_geometry(&NetConfig::small());
    assert_eq!(from_file.len(), from_static.len());
    for (a, b) in from_file.iter().zip(&from_static) {
        assert_eq!(a.cnum, b.cnum);
        assert_eq!(a.dep, b.dep);
        assert_eq!(a.wid, b.wid);
    }
}
