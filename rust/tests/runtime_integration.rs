//! Runtime integration: PJRT loads the AOT HLO-text artifacts, binds
//! weights from `.bcnn`, and must agree with the native engine — the
//! end-to-end proof that L1 (Pallas) + L2 (JAX) + L3 (rust) compose.
//!
//! Every test skips cleanly when the PJRT runtime (in-tree stub build) or
//! the trained artifacts are absent; the skip is printed so CI logs show
//! what was exercised.

use repro::bcnn::Engine;
use repro::coordinator::workload::random_images;
use repro::model::BcnnModel;
use repro::runtime::{Manifest, Runtime};

const DIR: &str = "artifacts";

fn bcnn(name: &str) -> String {
    format!("{DIR}/model_{name}.bcnn")
}

/// PJRT runtime + trained model, or `None` (skip) when unavailable.
fn runtime_and_model(name: &str) -> Option<(Runtime, BcnnModel)> {
    let rt = match Runtime::new(DIR) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable: {e:#}");
            return None;
        }
    };
    let model = match BcnnModel::load(bcnn(name)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping: trained artifact missing: {e:#}");
            return None;
        }
    };
    Some((rt, model))
}

#[test]
fn manifest_parses() {
    let path = format!("{DIR}/model_tiny_b1.json");
    if !std::path::Path::new(&path).exists() {
        eprintln!("skipping: {path} not present (run `make artifacts`)");
        return;
    }
    let m = Manifest::load(path).unwrap();
    assert_eq!(m.config, "tiny");
    assert_eq!(m.batch, 1);
    assert_eq!(m.input_shape, vec![1, 16, 16, 3]);
    assert_eq!(m.output_shape, vec![1, 10]);
    assert_eq!(m.params.first().unwrap().name, "w1");
    assert_eq!(m.params.last().unwrap().name, "bias");
}

#[test]
fn pjrt_matches_native_tiny_b1() {
    let Some((mut rt, model)) = runtime_and_model("tiny") else { return };
    let engine = Engine::new(model.clone()).expect("valid model");
    let loaded = rt.load_model("tiny", 1, bcnn("tiny")).unwrap();
    let images = random_images(&model.config(), 5, 31);
    for (i, img) in images.iter().enumerate() {
        let pjrt = loaded.infer_batch(img).unwrap();
        let native = engine.infer(img).unwrap();
        assert_eq!(pjrt.len(), native.len());
        for (a, b) in pjrt.iter().zip(&native) {
            assert!((a - b).abs() < 1e-3, "image {i}: pjrt {a} vs native {b}");
        }
    }
}

#[test]
fn pjrt_matches_native_small_batched() {
    let Some((mut rt, model)) = runtime_and_model("small") else { return };
    let engine = Engine::new(model.clone()).expect("valid model");
    let loaded = rt.load_model("small", 8, bcnn("small")).unwrap();
    let images = random_images(&model.config(), 8, 32);
    let per: usize = images[0].len();
    let mut flat = Vec::with_capacity(8 * per);
    for img in &images {
        flat.extend_from_slice(img);
    }
    let scores = loaded.infer_batch(&flat).unwrap();
    let classes = loaded.classes();
    for (i, img) in images.iter().enumerate() {
        let native = engine.infer(img).unwrap();
        for (a, b) in scores[i * classes..(i + 1) * classes].iter().zip(&native) {
            assert!((a - b).abs() < 1e-3, "image {i}: {a} vs {b}");
        }
    }
}

#[test]
fn runtime_caches_executables() {
    let Some((mut rt, _model)) = runtime_and_model("tiny") else { return };
    rt.load_model("tiny", 1, bcnn("tiny")).unwrap();
    assert!(rt.get("tiny", 1).is_some());
    assert!(rt.get("tiny", 99).is_none());
    // loading again must not fail (idempotent)
    rt.load_model("tiny", 1, bcnn("tiny")).unwrap();
}

#[test]
fn rejects_wrong_input_length() {
    let Some((mut rt, _model)) = runtime_and_model("tiny") else { return };
    let loaded = rt.load_model("tiny", 1, bcnn("tiny")).unwrap();
    assert!(loaded.infer_batch(&[0i32; 3]).is_err());
}

#[test]
fn missing_artifact_is_clean_error() {
    let Some((mut rt, _model)) = runtime_and_model("tiny") else { return };
    let msg = match rt.load_model("nonexistent", 1, bcnn("tiny")) {
        Ok(_) => panic!("expected error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("nonexistent"), "unhelpful error: {msg}");
}

#[test]
fn platform_is_cpu() {
    let rt = match Runtime::new(DIR) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable: {e:#}");
            return;
        }
    };
    assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform());
}
