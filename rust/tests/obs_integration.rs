//! Observability integration: one request's trace ID correlating every
//! span across coordinator and pipeline tracks in the exported Chrome
//! trace, and windowed telemetry confining an injected latency fault to
//! the windows it actually happened in while the cumulative tail lags.

use std::collections::{BTreeMap, BTreeSet};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use repro::bcnn::Engine;
use repro::coordinator::workload::random_images;
use repro::model::{BcnnModel, NetConfig};
use repro::obs::{self, WindowTracker};
use repro::serving::{serve_registry, BackendSpec, ControlClient, DeploySpec, ModelRegistry};
use repro::util::faults::{self, FaultPlan};
use repro::util::json::Json;

/// Tracing arming and fault plans are process-global; every test in this
/// binary serializes on this lock and restores the defaults (tracing on,
/// faults clear) before running.
fn guard() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    let g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    obs::set_enabled(true);
    faults::clear();
    g
}

fn tiny(seed: u64) -> BcnnModel {
    BcnnModel::synthetic(&NetConfig::tiny(), seed)
}

type ServerHandle = std::thread::JoinHandle<anyhow::Result<()>>;

fn start_server(registry: Arc<ModelRegistry>) -> (String, Arc<AtomicBool>, ServerHandle) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || serve_registry(listener, registry, stop))
    };
    (addr, stop, handle)
}

/// The ISSUE's trace acceptance: infer one image against a
/// pipeline-backed model over the wire, pull `OP_TRACE`, and follow the
/// reply's trace ID through admission, queue, batch and reply spans on
/// the shard track plus one stage span per layer on the `pipe*/stage*`
/// tracks.
#[test]
fn one_request_trace_correlates_across_all_tracks() {
    let _g = guard();
    let model = tiny(3);
    let n_layers = model.layers.len();
    let registry = Arc::new(ModelRegistry::new());
    registry
        .deploy(
            "m",
            DeploySpec::new(model.clone())
                .with_backend(BackendSpec::Pipeline { inflight: 4, stage_threads: 0 }),
        )
        .unwrap();
    let (addr, stop, server) = start_server(Arc::clone(&registry));
    let mut admin = ControlClient::connect(&addr).unwrap();

    let img = random_images(&NetConfig::tiny(), 1, 11).pop().unwrap();
    let reply = admin.infer("m", &img).unwrap();
    assert_ne!(reply.trace_id, 0, "v2 replies must carry the trace id");
    assert_eq!(
        reply.scores,
        Engine::new(model).unwrap().infer(&img).unwrap(),
        "tracing must not perturb the scores"
    );

    // the final stage span lands on its ring nanoseconds after the reply
    // ticket completes — retry the fetch instead of racing that write
    let want_spans = 4 + n_layers;
    let mut tracks: BTreeMap<u64, String> = BTreeMap::new();
    let mut spans: Vec<Json> = Vec::new();
    for _ in 0..200 {
        let trace = admin.trace().unwrap();
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        tracks.clear();
        for e in &events {
            if e.get("ph").unwrap().as_str().unwrap() == "M" {
                let tid = e.get("tid").unwrap().as_f64().unwrap() as u64;
                let name = e.get("args").unwrap().get("name").unwrap().as_str().unwrap();
                tracks.insert(tid, name.to_string());
            }
        }
        spans = events
            .iter()
            .filter(|e| {
                e.get("ph").unwrap().as_str().unwrap() == "X"
                    && e.get("args").unwrap().get("trace_id").unwrap().as_f64().unwrap() as u64
                        == reply.trace_id
            })
            .cloned()
            .collect();
        if spans.len() >= want_spans {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(
        spans.len(),
        want_spans,
        "expected admission+queue+batch+reply plus {n_layers} stage spans, got {spans:?}"
    );

    // the four coordinator phases, each on a shard track
    for want in ["admission", "queue", "batch", "reply"] {
        let span = spans
            .iter()
            .find(|s| s.get("cat").unwrap().as_str().unwrap() == want)
            .unwrap_or_else(|| panic!("missing {want} span for trace {}", reply.trace_id));
        let tid = span.get("tid").unwrap().as_f64().unwrap() as u64;
        let track = &tracks[&tid];
        assert!(track.contains("/shard"), "{want} span on track {track:?}, want a shard track");
        assert!(span.get("dur").unwrap().as_f64().unwrap() >= 0.0);
    }
    // one complete stage span per layer, each on its own pipe/stage track
    let mut layers_seen = BTreeSet::new();
    for s in spans.iter().filter(|s| s.get("cat").unwrap().as_str().unwrap() == "stage") {
        let layer = s.get("args").unwrap().get("layer").unwrap().as_f64().unwrap() as usize;
        let tid = s.get("tid").unwrap().as_f64().unwrap() as u64;
        let track = &tracks[&tid];
        assert!(
            track.starts_with("pipe") && track.ends_with(&format!("stage{layer}")),
            "stage-{layer} span landed on track {track:?}"
        );
        assert!(s.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        layers_seen.insert(layer);
    }
    assert_eq!(
        layers_seen,
        (0..n_layers).collect::<BTreeSet<_>>(),
        "every pipeline layer must contribute a stage span"
    );

    admin.close().unwrap();
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
}

/// The ISSUE's windowing acceptance: a latency fault injected mid-run
/// spikes p99 only in the windows where it fired; the neighbouring
/// windows stay fast, while the cumulative histogram keeps carrying the
/// spike long after recovery.
#[test]
fn latency_fault_spike_is_confined_to_its_windows() {
    let _g = guard();
    let registry = ModelRegistry::new();
    registry.deploy("m", DeploySpec::new(tiny(5))).unwrap();
    let entry = registry.router().resolve(Some("m")).unwrap();
    let client = entry.client();
    let images = random_images(&NetConfig::tiny(), 4, 21);
    let drive = |n: usize| {
        for i in 0..n {
            client.infer(images[i % images.len()].clone()).unwrap().scores.unwrap();
        }
    };

    // ticks use fabricated instants at exact 1-s boundaries, so which
    // requests land in which window is deterministic regardless of how
    // long the phases really took
    let mut tracker = WindowTracker::new(Duration::from_secs(1), 16);
    let start = tracker.started();

    drive(100);
    assert!(tracker.tick(start + Duration::from_secs(1), &registry.cumulative_metrics()));

    faults::install(FaultPlan::parse("backend_infer:delay=30ms").unwrap());
    drive(12);
    faults::clear();
    assert!(tracker.tick(start + Duration::from_secs(2), &registry.cumulative_metrics()));

    drive(100);
    assert!(tracker.tick(start + Duration::from_secs(3), &registry.cumulative_metrics()));

    let w = tracker.windows();
    assert_eq!(w.len(), 3);
    let per_window: Vec<u64> = w.iter().map(|s| s.delta.requests).collect();
    assert_eq!(per_window, vec![100, 12, 100], "deltas must partition the traffic");

    // the spike lives in the faulted window...
    assert!(
        w[1].delta.p99() >= Duration::from_millis(25),
        "faulted window p99 {:?} should carry the 30ms delay",
        w[1].delta.p99()
    );
    // ...and nowhere else
    for i in [0usize, 2] {
        assert!(
            w[i].delta.p99() < Duration::from_millis(15),
            "window {i} p99 {:?} should be unaffected by the fault",
            w[i].delta.p99()
        );
    }
    // while the cumulative tail still reports the spike after recovery
    let cumulative = registry.cumulative_metrics();
    assert!(
        cumulative.p99() >= Duration::from_millis(25),
        "cumulative p99 {:?} must lag the recovery",
        cumulative.p99()
    );
    assert!(cumulative.p99() > w[2].delta.p99());
}
