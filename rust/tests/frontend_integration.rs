//! Event-driven front-end integration: incremental frame decoding over
//! the reactor (split writes, pipelining), oversized-frame handling,
//! slow-reader write backpressure, and two-lane deadline shedding —
//! protocol v1 (`serve_tcp_frontend`) and v2 (`serve_registry_frontend`).
//!
//! Every test body runs on a worker thread behind a done-channel
//! watchdog, so a front-end hang fails the test instead of wedging the
//! harness.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use repro::bcnn::Engine;
use repro::coordinator::server::WIRE_ERROR;
use repro::coordinator::workload::random_images;
use repro::coordinator::{
    frontend_snapshot, reactor_supported, serve_tcp_frontend, Backend, BackendFactory,
    BatchPolicy, BatchResult, Coordinator, CoordinatorConfig, FrontendConfig, Lane,
    NativeBackend, QosConfig, MAX_WIRE_VALUES,
};
use repro::model::{BcnnModel, NetConfig};
use repro::serving::admin::{OP_INFER_QOS, REPLY_EXPIRED, REPLY_SCORES};
use repro::serving::{
    serve_registry_frontend, BackendSpec, ControlClient, DeploySpec, InferOutcome, ModelRegistry,
};

fn tiny_model() -> BcnnModel {
    BcnnModel::synthetic(&NetConfig::tiny(), 5)
}

fn native_factory(model: &BcnnModel) -> BackendFactory {
    let model = model.clone();
    Arc::new(move || {
        let b = NativeBackend::new(model.clone())?;
        Ok(Box::new(b) as Box<dyn Backend>)
    })
}

/// Run `body` on a worker thread; fail via the watchdog if it hangs.
fn with_watchdog<T: Send + 'static>(secs: u64, body: impl FnOnce() -> T + Send + 'static) -> T {
    let (done_tx, done_rx) = mpsc::channel();
    let worker = std::thread::spawn(move || {
        let _ = done_tx.send(body());
    });
    let out = done_rx
        .recv_timeout(Duration::from_secs(secs))
        .expect("front-end test hung past its watchdog");
    worker.join().unwrap();
    out
}

type ServeHandle = std::thread::JoinHandle<anyhow::Result<()>>;

fn start_v1(
    factory: BackendFactory,
    frontend: FrontendConfig,
    workers: usize,
    queue_depth: usize,
) -> (String, Arc<AtomicBool>, ServeHandle, Coordinator) {
    let coord = Coordinator::start_sharded(
        factory,
        CoordinatorConfig {
            workers,
            queue_depth,
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::ZERO },
            ..Default::default()
        },
    )
    .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let client = coord.client();
    let serve = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || serve_tcp_frontend(listener, client, stop, frontend))
    };
    (addr, stop, serve, coord)
}

fn v1_frame(image: &[i32]) -> Vec<u8> {
    let mut out = (image.len() as u32).to_le_bytes().to_vec();
    for v in image {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

enum V1Reply {
    Scores(Vec<f32>),
    Error(String),
}

fn read_v1_reply(stream: &mut TcpStream) -> V1Reply {
    let mut tag = [0u8; 4];
    stream.read_exact(&mut tag).expect("reply tag");
    let n = u32::from_le_bytes(tag);
    if n == WIRE_ERROR {
        let mut len = [0u8; 4];
        stream.read_exact(&mut len).unwrap();
        let mut msg = vec![0u8; u32::from_le_bytes(len) as usize];
        stream.read_exact(&mut msg).unwrap();
        V1Reply::Error(String::from_utf8_lossy(&msg).into_owned())
    } else {
        let mut raw = vec![0u8; n as usize * 4];
        stream.read_exact(&mut raw).unwrap();
        V1Reply::Scores(
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
        )
    }
}

#[test]
fn split_writes_reassemble_into_one_frame() {
    let model = tiny_model();
    let oracle = Engine::new(model.clone()).unwrap();
    let img = random_images(&model.config(), 1, 3).remove(0);
    let want = oracle.infer(&img).unwrap();
    let (addr, stop, serve, coord) =
        start_v1(native_factory(&model), FrontendConfig::default(), 1, 16);

    with_watchdog(60, move || {
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).unwrap();
        // drip the frame across many tiny writes with real pauses so the
        // decoder sees it over several readiness events
        let frame = v1_frame(&img);
        for chunk in frame.chunks(7) {
            conn.write_all(chunk).unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
        match read_v1_reply(&mut conn) {
            V1Reply::Scores(s) => assert_eq!(s, want, "split-written frame must decode intact"),
            V1Reply::Error(e) => panic!("unexpected error reply: {e}"),
        }
        conn.write_all(&0u32.to_le_bytes()).unwrap(); // graceful close
    });

    stop.store(true, Ordering::Relaxed);
    serve.join().unwrap().unwrap();
    coord.shutdown();
}

#[test]
fn pipelined_frames_reply_in_order() {
    let model = tiny_model();
    let oracle = Engine::new(model.clone()).unwrap();
    let images = random_images(&model.config(), 8, 7);
    let expected: Vec<Vec<f32>> = images.iter().map(|i| oracle.infer(i).unwrap()).collect();
    // a single worker serves strictly FIFO, so reply order is the oracle
    let (addr, stop, serve, coord) =
        start_v1(native_factory(&model), FrontendConfig::default(), 1, 32);

    with_watchdog(60, move || {
        let mut conn = TcpStream::connect(&addr).unwrap();
        let mut all = Vec::new();
        for img in &images {
            all.extend_from_slice(&v1_frame(img));
        }
        // one burst: every frame is in flight before the first reply
        conn.write_all(&all).unwrap();
        for (i, want) in expected.iter().enumerate() {
            match read_v1_reply(&mut conn) {
                V1Reply::Scores(s) => {
                    assert_eq!(&s, want, "pipelined reply {i} must match its request")
                }
                V1Reply::Error(e) => panic!("pipelined request {i} failed: {e}"),
            }
        }
        conn.write_all(&0u32.to_le_bytes()).unwrap();
    });

    stop.store(true, Ordering::Relaxed);
    serve.join().unwrap().unwrap();
    coord.shutdown();
}

#[test]
fn oversized_frame_is_discarded_without_dropping_the_connection() {
    let model = tiny_model();
    let oracle = Engine::new(model.clone()).unwrap();
    let img = random_images(&model.config(), 1, 11).remove(0);
    let want = oracle.infer(&img).unwrap();
    let (addr, stop, serve, coord) =
        start_v1(native_factory(&model), FrontendConfig::default(), 1, 16);

    with_watchdog(120, move || {
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

        // plausible-but-oversized: the server must reply "too large",
        // swallow the payload, and keep the connection serving
        let n = (MAX_WIRE_VALUES + 1) as u32;
        conn.write_all(&n.to_le_bytes()).unwrap();
        conn.write_all(&vec![0u8; (MAX_WIRE_VALUES + 1) * 4]).unwrap();
        match read_v1_reply(&mut conn) {
            V1Reply::Error(e) => assert!(e.contains("too large"), "{e}"),
            V1Reply::Scores(_) => panic!("oversized frame must not produce scores"),
        }

        // the same connection still serves a well-formed request
        conn.write_all(&v1_frame(&img)).unwrap();
        match read_v1_reply(&mut conn) {
            V1Reply::Scores(s) => assert_eq!(s, want, "connection must survive a discard"),
            V1Reply::Error(e) => panic!("post-discard request failed: {e}"),
        }

        // an implausible ~17 GiB claim is protocol garbage: error + close
        conn.write_all(&0xFEFF_FFFFu32.to_le_bytes()).unwrap();
        match read_v1_reply(&mut conn) {
            V1Reply::Error(e) => assert!(e.contains("too large"), "{e}"),
            V1Reply::Scores(_) => panic!("garbage tag must not produce scores"),
        }
        let mut probe = [0u8; 1];
        assert_eq!(
            conn.read(&mut probe).unwrap_or(0),
            0,
            "connection must close after an implausible frame"
        );
    });

    stop.store(true, Ordering::Relaxed);
    serve.join().unwrap().unwrap();
    coord.shutdown();
}

#[test]
fn slow_reader_engages_write_backpressure_without_losing_replies() {
    if !reactor_supported() {
        eprintln!("skipping: reactor unsupported on this platform (threaded fallback)");
        return;
    }
    let model = tiny_model();
    let img = random_images(&model.config(), 1, 13).remove(0);
    let (addr, stop, serve, coord) =
        start_v1(native_factory(&model), FrontendConfig::default(), 2, 256);

    let paused_after = with_watchdog(180, move || {
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).unwrap();
        conn.set_nonblocking(true).unwrap();
        let frame = v1_frame(&img);
        let base = frontend_snapshot().paused_reads;

        // flood requests while never reading replies: once the kernel
        // buffers fill, the server's write buffer crosses its high-water
        // mark and the reactor pauses this connection's read interest
        const MAX_FRAMES: usize = 1 << 16;
        let deadline = Instant::now() + Duration::from_secs(120);
        let mut outbox: Vec<u8> = Vec::new();
        let mut opos = 0usize;
        let mut sent = 0usize;
        while frontend_snapshot().paused_reads == base {
            assert!(Instant::now() < deadline, "backpressure never engaged ({sent} frames)");
            if opos >= outbox.len() {
                assert!(sent < MAX_FRAMES, "no pause after {MAX_FRAMES} unread-reply frames");
                outbox.clear();
                opos = 0;
                for _ in 0..64 {
                    outbox.extend_from_slice(&frame);
                    sent += 1;
                }
            }
            match conn.write(&outbox[opos..]) {
                Ok(0) => panic!("socket closed while flooding"),
                Ok(n) => opos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1))
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => panic!("flood write failed: {e}"),
            }
        }

        // drain: finish flushing queued frames while reading every reply.
        // Replies may be scores or typed overload sheds — either way,
        // every request must get exactly one (conservation, no drops).
        let reply_len = |buf: &[u8]| -> Option<usize> {
            if buf.len() < 4 {
                return None;
            }
            let tag = u32::from_le_bytes(buf[..4].try_into().unwrap());
            if tag == WIRE_ERROR {
                if buf.len() < 8 {
                    return None;
                }
                let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
                return (buf.len() >= 8 + len).then_some(8 + len);
            }
            let total = 4 + tag as usize * 4;
            (buf.len() >= total).then_some(total)
        };
        let mut rbuf: Vec<u8> = Vec::new();
        let mut tmp = [0u8; 16384];
        let mut got = 0usize;
        while got < sent {
            assert!(Instant::now() < deadline, "drain stalled at {got}/{sent} replies");
            let mut progressed = false;
            if opos < outbox.len() {
                match conn.write(&outbox[opos..]) {
                    Ok(n) => {
                        opos += n;
                        progressed = n > 0;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => panic!("drain write failed: {e}"),
                }
            }
            match conn.read(&mut tmp) {
                Ok(0) => panic!("server closed with {got}/{sent} replies delivered"),
                Ok(n) => {
                    rbuf.extend_from_slice(&tmp[..n]);
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => panic!("drain read failed: {e}"),
            }
            while let Some(len) = reply_len(&rbuf) {
                rbuf.drain(..len);
                got += 1;
            }
            if !progressed {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        assert_eq!(got, sent, "every flooded request must get exactly one reply");
        frontend_snapshot().paused_reads
    });
    assert!(paused_after > 0, "the reactor must have paused reads at least once");

    stop.store(true, Ordering::Relaxed);
    serve.join().unwrap().unwrap();
    coord.shutdown();
}

/// Parks every batch until the gate opens — wedges a 1-worker pool so
/// admitted-but-undispatchable requests age past their deadline.
struct GateBackend(Arc<AtomicBool>);

impl Backend for GateBackend {
    fn name(&self) -> &str {
        "gate"
    }
    fn infer_batch(&mut self, images: &[&[i32]]) -> anyhow::Result<BatchResult> {
        while !self.0.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(BatchResult {
            scores: images.iter().map(|_| vec![0.0]).collect(),
            modeled_device_time: None,
        })
    }
}

#[test]
fn v1_default_deadline_sheds_typed_when_the_pool_is_wedged() {
    const REQUESTS: usize = 6;
    let gate = Arc::new(AtomicBool::new(false));
    let factory: BackendFactory = {
        let gate = Arc::clone(&gate);
        Arc::new(move || Ok(Box::new(GateBackend(Arc::clone(&gate))) as Box<dyn Backend>))
    };
    let frontend = FrontendConfig {
        reactor_threads: 1,
        qos: QosConfig {
            default_deadline: Some(Duration::from_millis(30)),
            ..QosConfig::default()
        },
    };
    let (addr, stop, serve, coord) = start_v1(factory, frontend, 1, 1);

    with_watchdog(60, move || {
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let img = vec![7i32; 16];
        for _ in 0..REQUESTS {
            conn.write_all(&v1_frame(&img)).unwrap();
        }

        // the wedged worker strands the overflow in the admission lane;
        // those requests must come back as typed deadline sheds while
        // the gate is still closed
        let mut sheds = 0usize;
        let mut scores = 0usize;
        match read_v1_reply(&mut conn) {
            V1Reply::Error(e) => {
                assert!(e.contains("deadline expired"), "shed must be deadline-typed: {e}");
                sheds += 1;
            }
            V1Reply::Scores(_) => panic!("no request can complete while the gate is closed"),
        }

        // open the gate: the dispatched requests finish, and every one
        // of the six gets exactly one reply
        gate.store(true, Ordering::Relaxed);
        for _ in 0..REQUESTS - 1 {
            match read_v1_reply(&mut conn) {
                V1Reply::Error(e) => {
                    assert!(e.contains("deadline expired"), "shed must be deadline-typed: {e}");
                    sheds += 1;
                }
                V1Reply::Scores(_) => scores += 1,
            }
        }
        assert!(sheds >= 1, "the wedged pool must shed at least one request");
        assert!(scores >= 1, "the gated batch must still complete after release");
        assert_eq!(sheds + scores, REQUESTS, "conservation: one reply per request");
        conn.write_all(&0u32.to_le_bytes()).unwrap();
    });

    stop.store(true, Ordering::Relaxed);
    serve.join().unwrap().unwrap();
    coord.shutdown();
}

fn infer_qos_frame(name: &str, lane: Lane, deadline_ms: u32, image: &[i32]) -> Vec<u8> {
    let mut out = OP_INFER_QOS.to_le_bytes().to_vec();
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
    out.extend_from_slice(&lane.wire().to_le_bytes());
    out.extend_from_slice(&deadline_ms.to_le_bytes());
    out.extend_from_slice(&(image.len() as u32).to_le_bytes());
    for v in image {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Length of the v2 reply frame at the head of `buf`, if complete.
fn v2_reply_len(buf: &[u8]) -> Option<usize> {
    if buf.len() < 4 {
        return None;
    }
    let tag = u32::from_le_bytes(buf[..4].try_into().unwrap());
    if tag == REPLY_SCORES {
        if buf.len() < 24 {
            return None;
        }
        let n = u32::from_le_bytes(buf[20..24].try_into().unwrap()) as usize;
        return (buf.len() >= 24 + n * 4).then_some(24 + n * 4);
    }
    if tag == REPLY_EXPIRED || tag == WIRE_ERROR {
        if buf.len() < 8 {
            return None;
        }
        let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        return (buf.len() >= 8 + len).then_some(8 + len);
    }
    panic!("unexpected v2 reply tag {tag:#010x}");
}

#[test]
fn v2_offline_backlog_sheds_with_typed_expired_reply() {
    const FLOOD: usize = 1024;
    let model = tiny_model();
    let oracle = Engine::new(model.clone()).unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry
        .deploy(
            "m",
            DeploySpec {
                model,
                backend: BackendSpec::Engine { lanes: 1 },
                workers: 1,
                queue_depth: 1,
                policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            },
        )
        .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let serve = {
        let stop = Arc::clone(&stop);
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || {
            serve_registry_frontend(listener, registry, stop, FrontendConfig::default())
        })
    };

    with_watchdog(120, move || {
        let img = random_images(&NetConfig::tiny(), 1, 3).remove(0);

        // a deep pipelined offline backlog through an un-batched 1-worker
        // pool; its replies stay unread while the probe runs
        let mut flood = TcpStream::connect(&addr).unwrap();
        let frame = infer_qos_frame("", Lane::Offline, 0, &img);
        let mut all = Vec::new();
        for _ in 0..FLOOD {
            all.extend_from_slice(&frame);
        }
        flood.write_all(&all).unwrap();

        // an offline probe with a 1 ms deadline joins the queue tail: it
        // must come back as a typed REPLY_EXPIRED, not an opaque error
        // (bounded retry in case the backlog drains implausibly fast)
        let mut admin = ControlClient::connect(&addr).unwrap();
        let mut saw_expired = false;
        for _ in 0..10 {
            match admin
                .infer_qos("m", Lane::Offline, Some(Duration::from_millis(1)), &img)
                .unwrap()
            {
                InferOutcome::Expired(msg) => {
                    assert!(msg.contains("expired"), "expiry must say so: {msg}");
                    saw_expired = true;
                    break;
                }
                InferOutcome::Scores(_) => {}
            }
        }
        assert!(saw_expired, "a 1 ms deadline behind a {FLOOD}-deep backlog must expire");

        // the same connection keeps serving after a typed expiry, and the
        // online lane cuts past the offline backlog
        match admin.infer_qos("m", Lane::Online, None, &img).unwrap() {
            InferOutcome::Scores(reply) => {
                assert_eq!(reply.scores, oracle.infer(&img).unwrap(), "online reply bit-exact")
            }
            InferOutcome::Expired(msg) => panic!("no-deadline online infer expired: {msg}"),
        }
        admin.close().unwrap();

        // conservation on the flood connection: one reply per request
        flood.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut rbuf: Vec<u8> = Vec::new();
        let mut tmp = [0u8; 16384];
        let mut got = 0usize;
        while got < FLOOD {
            let n = flood.read(&mut tmp).expect("flood drain read");
            assert!(n > 0, "server closed with {got}/{FLOOD} flood replies delivered");
            rbuf.extend_from_slice(&tmp[..n]);
            while let Some(len) = v2_reply_len(&rbuf) {
                rbuf.drain(..len);
                got += 1;
            }
        }
        assert_eq!(got, FLOOD, "every flood request must get exactly one reply");
        flood.write_all(&0u32.to_le_bytes()).unwrap();
    });

    stop.store(true, Ordering::Relaxed);
    serve.join().unwrap().unwrap();
    registry.drain_retired(Duration::from_secs(5)).unwrap();
}
