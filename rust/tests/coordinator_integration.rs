//! Coordinator integration: batching policy, serving metrics, TCP
//! front-end, simulator backends on the request path.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use repro::bcnn::Engine;
use repro::coordinator::server::{serve_tcp, TcpClient};
use repro::coordinator::workload::{random_images, run_closed_loop, run_open_loop};
use repro::coordinator::{
    Backend, BatchPolicy, Coordinator, CoordinatorConfig, FpgaSimBackend, GpuSimBackend,
    NativeBackend,
};
use repro::gpu::GpuKernel;
use repro::model::BcnnModel;

fn load(name: &str) -> BcnnModel {
    BcnnModel::load(format!("artifacts/model_{name}.bcnn"))
        .expect("run `make artifacts` before `cargo test`")
}

fn start_native(max_batch: usize, max_wait: Duration) -> (Coordinator, Engine) {
    let model = load("tiny");
    let engine = Engine::new(model.clone());
    let coord = Coordinator::start(
        Box::new(NativeBackend::new(model)),
        CoordinatorConfig { policy: BatchPolicy { max_batch, max_wait } },
    );
    (coord, engine)
}

#[test]
fn serves_correct_scores() {
    let (coord, engine) = start_native(4, Duration::from_millis(1));
    let cfg = engine.model().config();
    let images = random_images(&cfg, 6, 41);
    let client = coord.client();
    for img in &images {
        let reply = client.infer(img.clone()).unwrap();
        assert_eq!(reply.scores, engine.infer(img).unwrap());
    }
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 6);
}

#[test]
fn closed_loop_batches_up() {
    let (coord, engine) = start_native(8, Duration::from_millis(20));
    let cfg = engine.model().config();
    let report = run_closed_loop(&coord.client(), &cfg, 32, 42).unwrap();
    assert_eq!(report.replies.len(), 32);
    // under a burst, batches should form well above size 1
    assert!(report.mean_batch() > 2.0, "mean batch {}", report.mean_batch());
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 32);
    assert!(metrics.batches < 32, "no batching happened");
}

#[test]
fn open_loop_low_rate_means_small_batches() {
    let (coord, engine) = start_native(16, Duration::from_millis(1));
    let cfg = engine.model().config();
    // slow trickle: requests should mostly ride alone
    let report = run_open_loop(&coord.client(), &cfg, 10, 50.0, 43).unwrap();
    assert!(report.mean_batch() < 4.0, "mean batch {}", report.mean_batch());
    coord.shutdown();
}

#[test]
fn replies_match_request_order_data() {
    // each reply must carry the scores of ITS request (no cross-wiring)
    let (coord, engine) = start_native(8, Duration::from_millis(10));
    let cfg = engine.model().config();
    let images = random_images(&cfg, 16, 44);
    let client = coord.client();
    let rxs: Vec<_> = images.iter().map(|img| client.submit(img.clone())).collect();
    for (img, rx) in images.iter().zip(rxs) {
        let reply = rx.recv().unwrap();
        assert_eq!(reply.scores, engine.infer(img).unwrap());
    }
    coord.shutdown();
}

#[test]
fn fpga_sim_backend_reports_modeled_time() {
    let model = load("tiny");
    let mut backend = FpgaSimBackend::new(model.clone()).unwrap();
    let images = random_images(&model.config(), 4, 45);
    let out = backend.infer_batch(&images).unwrap();
    let modeled = out.modeled_device_time.expect("simulator must model time");
    assert!(modeled > Duration::ZERO);
    // (images + layers + slack) phases at 90 MHz with a generous per-phase
    // bound for the tiny config — modeled time must stay physical
    let n_layers = backend.stream_config().params.len();
    let upper = (images.len() + n_layers + 2) as f64 * 262_144.0 / 90.0e6;
    assert!(modeled.as_secs_f64() < upper, "modeled {modeled:?} > bound {upper}");
}

#[test]
fn gpu_sim_backend_penalizes_small_batches() {
    let model = load("tiny");
    let mut backend = GpuSimBackend::new(model.clone(), GpuKernel::Xnor);
    let one = backend
        .infer_batch(&random_images(&model.config(), 1, 46))
        .unwrap()
        .modeled_device_time
        .unwrap();
    let many = backend
        .infer_batch(&random_images(&model.config(), 64, 46))
        .unwrap()
        .modeled_device_time
        .unwrap();
    // 64 images take longer than 1, but far less than 64x (latency hiding)
    assert!(many > one);
    assert!(many < one * 64, "no latency hiding in model");
}

#[test]
fn tcp_round_trip() {
    let (coord, engine) = start_native(4, Duration::from_millis(1));
    let cfg = engine.model().config();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let client = coord.client();
    let server = std::thread::spawn(move || serve_tcp(listener, client, stop2));

    let images = random_images(&cfg, 3, 47);
    let mut tcp = TcpClient::connect(&addr).unwrap();
    for img in &images {
        let scores = tcp.infer(img).unwrap();
        assert_eq!(scores, engine.infer(img).unwrap());
    }
    tcp.close().unwrap();
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
    coord.shutdown();
}

#[test]
fn metrics_quantiles_present() {
    let (coord, engine) = start_native(4, Duration::from_millis(1));
    let cfg = engine.model().config();
    run_closed_loop(&coord.client(), &cfg, 12, 48).unwrap();
    let m = coord.shutdown();
    assert_eq!(m.requests, 12);
    assert!(m.latency.quantile(0.5) > Duration::ZERO);
    assert!(m.latency.quantile(0.99) >= m.latency.quantile(0.5));
    assert!(m.mean_batch() >= 1.0);
    assert!(m.summary().contains("requests=12"));
}

#[test]
fn shutdown_disconnects_clients() {
    let (coord, engine) = start_native(4, Duration::from_millis(1));
    let client = coord.client();
    let cfg = engine.model().config();
    coord.shutdown();
    let img = random_images(&cfg, 1, 49).pop().unwrap();
    assert!(client.infer(img).is_err());
}
