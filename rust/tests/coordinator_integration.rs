//! Coordinator integration: batching policy, sharded worker pool,
//! bounded-queue backpressure, typed error replies, serving metrics, TCP
//! front-end, simulator backends on the request path.
//!
//! Runs against trained artifacts when present, else deterministic
//! synthetic weights (numerics-equivalence needs no training).

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::bail;
use repro::bcnn::Engine;
use repro::coordinator::server::{serve_tcp, TcpClient};
use repro::coordinator::workload::{random_images, run_closed_loop, run_open_loop};
use repro::coordinator::{
    Backend, BackendFactory, BatchPolicy, BatchResult, Coordinator, CoordinatorConfig,
    FpgaSimBackend, GpuSimBackend, NativeBackend, SubmitError,
};
use repro::gpu::GpuKernel;
use repro::model::BcnnModel;

fn load(name: &str) -> BcnnModel {
    BcnnModel::load_or_synthetic(name, "artifacts", 0xB_C0DE).expect("built-in config")
}

fn start_native(max_batch: usize, max_wait: Duration) -> (Coordinator, Engine) {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let coord = Coordinator::start(
        Box::new(NativeBackend::new(model).expect("valid model")),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch, max_wait },
            ..CoordinatorConfig::default()
        },
    );
    (coord, engine)
}

fn start_sharded(workers: usize, policy: BatchPolicy, queue_depth: usize) -> (Coordinator, Engine) {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let factory: BackendFactory = Arc::new(move || -> anyhow::Result<Box<dyn Backend>> {
        Ok(Box::new(NativeBackend::new(model.clone())?))
    });
    let coord = Coordinator::start_sharded(
        factory,
        CoordinatorConfig { policy, workers, queue_depth },
    )
    .expect("start sharded pool");
    (coord, engine)
}

#[test]
fn serves_correct_scores() {
    let (coord, engine) = start_native(4, Duration::from_millis(1));
    let cfg = engine.model().config();
    let images = random_images(&cfg, 6, 41);
    let client = coord.client();
    for img in &images {
        let reply = client.infer(img.clone()).unwrap();
        assert_eq!(reply.scores.unwrap(), engine.infer(img).unwrap());
    }
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 6);
    assert_eq!(metrics.errors, 0);
}

#[test]
fn closed_loop_batches_up() {
    let (coord, engine) = start_native(8, Duration::from_millis(20));
    let cfg = engine.model().config();
    let report = run_closed_loop(&coord.client(), &cfg, 32, 42).unwrap();
    assert_eq!(report.replies.len(), 32);
    // under a burst, batches should form well above size 1
    assert!(report.mean_batch() > 2.0, "mean batch {}", report.mean_batch());
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 32);
    assert!(metrics.batches < 32, "no batching happened");
}

#[test]
fn open_loop_low_rate_means_small_batches() {
    let (coord, engine) = start_native(16, Duration::from_millis(1));
    let cfg = engine.model().config();
    // slow trickle: requests should mostly ride alone
    let report = run_open_loop(&coord.client(), &cfg, 10, 50.0, 43).unwrap();
    assert!(report.mean_batch() < 4.0, "mean batch {}", report.mean_batch());
    coord.shutdown();
}

#[test]
fn replies_match_request_order_data() {
    // each reply must carry the scores of ITS request (no cross-wiring)
    let (coord, engine) = start_native(8, Duration::from_millis(10));
    let cfg = engine.model().config();
    let images = random_images(&cfg, 16, 44);
    let client = coord.client();
    let rxs: Vec<_> = images
        .iter()
        .map(|img| client.submit(img.clone()).expect("queue has room"))
        .collect();
    for (img, rx) in images.iter().zip(rxs) {
        let reply = rx.recv().unwrap();
        assert_eq!(reply.scores.unwrap(), engine.infer(img).unwrap());
    }
    coord.shutdown();
}

#[test]
fn sharded_pool_concurrent_clients_get_correct_replies() {
    // M client threads through a 4-shard pool: every reply must carry the
    // scores of its own request, across shard boundaries
    let (coord, engine) = start_sharded(
        4,
        BatchPolicy { max_batch: 8, max_wait: Duration::ZERO },
        64,
    );
    const THREADS: usize = 8;
    const PER_THREAD: usize = 8;
    let mut joins = Vec::new();
    for t in 0..THREADS {
        let client = coord.client();
        let cfg = engine.model().config();
        joins.push(std::thread::spawn(move || {
            let images = random_images(&cfg, PER_THREAD, 100 + t as u64);
            images
                .into_iter()
                .map(|img| {
                    let reply = client.infer(img.clone()).unwrap();
                    (img, reply)
                })
                .collect::<Vec<_>>()
        }));
    }
    let mut served = 0u64;
    for j in joins {
        for (img, reply) in j.join().unwrap() {
            assert_eq!(reply.scores.unwrap(), engine.infer(&img).unwrap());
            served += 1;
        }
    }
    assert_eq!(served, (THREADS * PER_THREAD) as u64);

    // dispatch spread the load: total adds up and >= 2 shards served work
    let per_shard: Vec<u64> = coord.shard_metrics().iter().map(|m| m.requests).collect();
    assert_eq!(per_shard.iter().sum::<u64>(), served);
    assert!(
        per_shard.iter().filter(|&&r| r > 0).count() >= 2,
        "round-robin + least-loaded dispatch never spread load: {per_shard:?}"
    );
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, served);
    assert_eq!(metrics.errors, 0);
}

/// Backend that parks inside `infer_batch` until released (deterministic
/// queue-full setup) and reports when it has started.
struct GatedBackend {
    started: Arc<AtomicBool>,
    release: Arc<AtomicBool>,
}

impl Backend for GatedBackend {
    fn name(&self) -> &str {
        "gated"
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> anyhow::Result<BatchResult> {
        self.started.store(true, Ordering::SeqCst);
        let t0 = Instant::now();
        while !self.release.load(Ordering::SeqCst) {
            assert!(t0.elapsed() < Duration::from_secs(10), "test gate never released");
            std::thread::sleep(Duration::from_micros(50));
        }
        Ok(BatchResult { scores: vec![vec![0.0]; images.len()], modeled_device_time: None })
    }
}

#[test]
fn full_bounded_queue_returns_queue_full() {
    let started = Arc::new(AtomicBool::new(false));
    let release = Arc::new(AtomicBool::new(false));
    let backend = GatedBackend { started: Arc::clone(&started), release: Arc::clone(&release) };
    let coord = Coordinator::start(
        Box::new(backend),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            workers: 1,
            queue_depth: 2,
        },
    );
    let client = coord.client();

    // occupy the worker, then wait until it is provably inside infer_batch
    let rx0 = client.submit(vec![0i32; 4]).unwrap();
    let t0 = Instant::now();
    while !started.load(Ordering::SeqCst) {
        assert!(t0.elapsed() < Duration::from_secs(5), "worker never started");
        std::thread::sleep(Duration::from_micros(50));
    }

    // fill the (now empty) 2-deep queue, then overflow it
    let rx1 = client.submit(vec![1i32; 4]).unwrap();
    let rx2 = client.submit(vec![2i32; 4]).unwrap();
    let overflow = vec![3i32; 4];
    match client.submit(overflow.clone()) {
        Err(SubmitError::QueueFull { image }) => {
            assert_eq!(image, overflow, "backpressure must hand the image back");
        }
        Err(SubmitError::ShardDown { .. }) | Err(SubmitError::Shutdown) => {
            panic!("pool is alive")
        }
        Ok(_) => panic!("4th request fit a 2-deep queue with a busy worker"),
    }

    // release: everything admitted must still be served
    release.store(true, Ordering::SeqCst);
    for rx in [rx0, rx1, rx2] {
        let reply = rx.recv().unwrap();
        assert!(reply.scores.is_ok());
    }
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 3);
}

/// Backend that always fails.
struct FailingBackend;

impl Backend for FailingBackend {
    fn name(&self) -> &str {
        "failing"
    }

    fn infer_batch(&mut self, _images: &[&[i32]]) -> anyhow::Result<BatchResult> {
        bail!("synthetic device fault")
    }
}

#[test]
fn backend_error_becomes_typed_reply_not_silent_drop() {
    let coord = Coordinator::start(
        Box::new(FailingBackend),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            ..CoordinatorConfig::default()
        },
    );
    let client = coord.client();
    let reply = client.infer(vec![0i32; 8]).unwrap();
    let err = reply.scores.expect_err("failing backend must produce an error reply");
    assert!(err.message.contains("synthetic device fault"), "{err}");
    assert_eq!(reply.argmax(), None);
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 1);
    assert_eq!(metrics.errors, 1);
    assert!(metrics.summary().contains("errors=1"));
}

#[test]
fn fpga_sim_backend_reports_modeled_time() {
    let model = load("tiny");
    let mut backend = FpgaSimBackend::new(model.clone()).unwrap();
    let images = random_images(&model.config(), 4, 45);
    let out = backend.infer_owned(&images).unwrap();
    let modeled = out.modeled_device_time.expect("simulator must model time");
    assert!(modeled > Duration::ZERO);
    // (images + layers + slack) phases at 90 MHz with a generous per-phase
    // bound for the tiny config — modeled time must stay physical
    let n_layers = backend.stream_config().params.len();
    let upper = (images.len() + n_layers + 2) as f64 * 262_144.0 / 90.0e6;
    assert!(modeled.as_secs_f64() < upper, "modeled {modeled:?} > bound {upper}");
}

#[test]
fn gpu_sim_backend_penalizes_small_batches() {
    let model = load("tiny");
    let mut backend = GpuSimBackend::new(model.clone(), GpuKernel::Xnor).unwrap();
    let one = backend
        .infer_owned(&random_images(&model.config(), 1, 46))
        .unwrap()
        .modeled_device_time
        .unwrap();
    let many = backend
        .infer_owned(&random_images(&model.config(), 64, 46))
        .unwrap()
        .modeled_device_time
        .unwrap();
    // 64 images take longer than 1, but far less than 64x (latency hiding)
    assert!(many > one);
    assert!(many < one * 64, "no latency hiding in model");
}

#[test]
fn native_backend_lanes_match_serial() {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let images = random_images(&model.config(), 9, 50);
    let mut parallel = NativeBackend::with_lanes(model, 4).unwrap();
    let out = parallel.infer_owned(&images).unwrap();
    assert_eq!(out.scores.len(), images.len());
    for (img, got) in images.iter().zip(&out.scores) {
        assert_eq!(&engine.infer(img).unwrap(), got, "lane split changed numerics");
    }
}

#[test]
fn tcp_round_trip() {
    let (coord, engine) = start_native(4, Duration::from_millis(1));
    let cfg = engine.model().config();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let client = coord.client();
    let server = std::thread::spawn(move || serve_tcp(listener, client, stop2));

    let images = random_images(&cfg, 3, 47);
    let mut tcp = TcpClient::connect(&addr).unwrap();
    for img in &images {
        let scores = tcp.infer(img).unwrap();
        assert_eq!(scores, engine.infer(img).unwrap());
    }
    tcp.close().unwrap();
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
    coord.shutdown();
}

#[test]
fn tcp_oversized_request_rejected_with_error_frame() {
    // satellite coverage for the server's oversized path end-to-end: the
    // *client* must decode the error frame, and — because the server
    // discards the committed payload instead of slamming the connection —
    // the very next request on the same connection must still be served
    let (coord, engine) = start_native(4, Duration::from_millis(1));
    let cfg = engine.model().config();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let client = coord.client();
    let server = std::thread::spawn(move || serve_tcp(listener, client, stop2));

    let huge = vec![0i32; repro::coordinator::server::MAX_WIRE_VALUES + 1];
    let mut tcp = TcpClient::connect(&addr).unwrap();
    let err = tcp.infer(&huge).expect_err("oversized request must be rejected");
    assert!(err.to_string().contains("too large"), "unhelpful error: {err}");

    // the connection survived the rejection
    let img = random_images(&cfg, 1, 61).pop().unwrap();
    assert_eq!(tcp.infer(&img).unwrap(), engine.infer(&img).unwrap());
    tcp.close().unwrap();

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
    coord.shutdown();
}

#[test]
fn tcp_backend_failure_becomes_decodable_error_frame() {
    // satellite coverage for the server's backend-failure reply: the
    // typed error frame must round-trip to the client, and the
    // connection must stay open for subsequent requests
    let coord = Coordinator::start(
        Box::new(FailingBackend),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            ..CoordinatorConfig::default()
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let client = coord.client();
    let server = std::thread::spawn(move || serve_tcp(listener, client, stop2));

    let mut tcp = TcpClient::connect(&addr).unwrap();
    for attempt in 0..2 {
        let err = tcp.infer(&[0i32; 8]).expect_err("failing backend must surface an error");
        assert!(
            err.to_string().contains("synthetic device fault"),
            "attempt {attempt}: undecodable error: {err}"
        );
    }
    tcp.close().unwrap();

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
    let metrics = coord.shutdown();
    assert_eq!(metrics.errors, 2, "both failures must be counted");
}

#[test]
fn submit_deadline_expires_with_queue_full() {
    let started = Arc::new(AtomicBool::new(false));
    let release = Arc::new(AtomicBool::new(false));
    let backend = GatedBackend { started: Arc::clone(&started), release: Arc::clone(&release) };
    let coord = Coordinator::start(
        Box::new(backend),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            workers: 1,
            queue_depth: 1,
        },
    );
    let client = coord.client();

    // park the worker inside infer_batch, then fill the 1-deep queue
    let rx0 = client.submit(vec![0i32; 4]).unwrap();
    let t0 = Instant::now();
    while !started.load(Ordering::SeqCst) {
        assert!(t0.elapsed() < Duration::from_secs(5), "worker never started");
        std::thread::sleep(Duration::from_micros(50));
    }
    let rx1 = client.submit(vec![1i32; 4]).unwrap();

    // a saturated pool must bound the wait and hand the image back
    let t0 = Instant::now();
    match client.submit_deadline(vec![2i32; 4], Duration::from_millis(20)) {
        Err(SubmitError::QueueFull { image }) => assert_eq!(image, vec![2i32; 4]),
        Err(SubmitError::ShardDown { .. }) | Err(SubmitError::Shutdown) => {
            panic!("pool is alive")
        }
        Ok(_) => panic!("deadline submit fit a full queue"),
    }
    let waited = t0.elapsed();
    assert!(waited >= Duration::from_millis(20), "returned before the deadline: {waited:?}");
    assert!(waited < Duration::from_secs(5), "deadline failed to bound the wait: {waited:?}");

    release.store(true, Ordering::SeqCst);
    for rx in [rx0, rx1] {
        assert!(rx.recv().unwrap().scores.is_ok());
    }
    coord.shutdown();
}

#[test]
fn tcp_concurrent_clients_through_sharded_pool() {
    let (coord, engine) = start_sharded(
        4,
        BatchPolicy { max_batch: 8, max_wait: Duration::ZERO },
        64,
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let client = coord.client();
    let server = std::thread::spawn(move || serve_tcp(listener, client, stop2));

    let mut joins = Vec::new();
    for t in 0..4u64 {
        let addr = addr.clone();
        let cfg = engine.model().config();
        joins.push(std::thread::spawn(move || {
            let images = random_images(&cfg, 4, 200 + t);
            let mut tcp = TcpClient::connect(&addr).unwrap();
            let out: Vec<_> = images
                .iter()
                .map(|img| (img.clone(), tcp.infer(img).unwrap()))
                .collect();
            tcp.close().unwrap();
            out
        }));
    }
    for j in joins {
        for (img, scores) in j.join().unwrap() {
            assert_eq!(scores, engine.infer(&img).unwrap());
        }
    }
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
    let metrics = coord.shutdown();
    assert_eq!(metrics.requests, 16);
    assert_eq!(metrics.errors, 0);
}

#[test]
fn metrics_quantiles_present() {
    let (coord, engine) = start_native(4, Duration::from_millis(1));
    let cfg = engine.model().config();
    run_closed_loop(&coord.client(), &cfg, 12, 48).unwrap();
    let m = coord.shutdown();
    assert_eq!(m.requests, 12);
    assert!(m.latency.quantile(0.5) > Duration::ZERO);
    assert!(m.latency.quantile(0.99) >= m.latency.quantile(0.5));
    assert!(m.mean_batch() >= 1.0);
    assert!(m.summary().contains("requests=12"));
}

#[test]
fn shutdown_disconnects_clients() {
    let (coord, engine) = start_native(4, Duration::from_millis(1));
    let client = coord.client();
    let cfg = engine.model().config();
    coord.shutdown();
    let img = random_images(&cfg, 1, 49).pop().unwrap();
    match client.submit(img.clone()) {
        Err(SubmitError::Shutdown) => {}
        Err(SubmitError::QueueFull { .. }) => panic!("dead pool reported backpressure"),
        Err(SubmitError::ShardDown { .. }) => panic!("graceful shutdown reported crash-down"),
        Ok(_) => panic!("submit to a dead pool succeeded"),
    }
    assert!(client.infer(img).is_err());
}
