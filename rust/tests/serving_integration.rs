//! Serving control plane integration: registry deploy/undeploy/rollback,
//! epoch-tagged routing swaps, protocol-v2 wire framing (model routing +
//! admin frames + v1 compat), and the headline guarantee — zero-downtime
//! hot-swap under live traffic with bit-exact, version-attributed replies.

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use repro::bcnn::Engine;
use repro::coordinator::server::TcpClient;
use repro::coordinator::workload::random_images;
use repro::model::{BcnnModel, NetConfig};
use repro::serving::{
    serve_registry, BackendSpec, ControlClient, DeploySpec, ModelRegistry, ModelSource, RouteError,
};

fn tiny(seed: u64) -> BcnnModel {
    BcnnModel::synthetic(&NetConfig::tiny(), seed)
}

#[test]
fn registry_deploy_resolve_undeploy() {
    let registry = ModelRegistry::new();
    let router = registry.router();
    assert!(matches!(router.resolve(None), Err(RouteError::NoDefault)));

    let v_a = registry.deploy("a", DeploySpec::new(tiny(1))).unwrap();
    let v_b = registry.deploy("b", DeploySpec::new(tiny(2))).unwrap();
    assert!(v_b > v_a, "versions must increase");
    assert_eq!(router.names(), vec!["a".to_string(), "b".to_string()]);

    // first deployment becomes the default route
    assert_eq!(router.resolve(None).unwrap().name, "a");
    assert_eq!(router.resolve(Some("b")).unwrap().version, v_b);
    assert!(matches!(
        router.resolve(Some("nope")),
        Err(RouteError::Unknown(n)) if n == "nope"
    ));

    // the default route can be repointed explicitly
    registry.set_default("b").unwrap();
    assert_eq!(router.resolve(None).unwrap().name, "b");
    assert!(registry.set_default("nope").is_err());
    registry.set_default("a").unwrap();

    // undeploy the default: the route falls over to the survivor
    registry.undeploy("a").unwrap();
    assert_eq!(router.resolve(None).unwrap().name, "b");
    assert!(registry.undeploy("a").is_err(), "double undeploy must fail");
    registry.drain_retired(Duration::from_secs(5)).unwrap();
}

#[test]
fn epoch_bumps_on_every_swap() {
    let registry = ModelRegistry::new();
    let e0 = registry.epoch();
    registry.deploy("m", DeploySpec::new(tiny(1))).unwrap();
    let e1 = registry.epoch();
    assert!(e1 > e0);
    registry.deploy("m", DeploySpec::new(tiny(2))).unwrap();
    let e2 = registry.epoch();
    assert!(e2 > e1);
    registry.rollback("m").unwrap();
    assert!(registry.epoch() > e2);
}

#[test]
fn swap_is_zero_downtime_for_inflight_requests() {
    // hold a resolved entry across a swap: its pool must keep serving
    let registry = ModelRegistry::new();
    let v1 = registry.deploy("m", DeploySpec::new(tiny(1))).unwrap();
    let router = registry.router();
    let old = router.resolve(Some("m")).unwrap();
    assert_eq!(old.version, v1);

    let v2 = registry.deploy("m", DeploySpec::new(tiny(2))).unwrap();
    // the old pool still answers a submission made through the held ref
    let img = random_images(&NetConfig::tiny(), 1, 9).pop().unwrap();
    let engine_old = Engine::new(tiny(1)).unwrap();
    let reply = old.client().infer(img.clone()).unwrap();
    assert_eq!(reply.scores.unwrap(), engine_old.infer(&img).unwrap());

    // new resolutions land on the new version
    assert_eq!(router.resolve(Some("m")).unwrap().version, v2);

    drop(old);
    registry.drain_retired(Duration::from_secs(5)).unwrap();
    // after drain, per-model stats still account for the retired pool
    let stats = registry.stats();
    let m = stats.iter().find(|s| s.name == "m").unwrap();
    assert!(m.live);
    assert_eq!(m.metrics.requests, 1, "retired pool's request must survive the swap");
}

#[test]
fn rollback_restores_previous_weights() {
    let registry = ModelRegistry::new();
    registry.deploy("m", DeploySpec::new(tiny(1))).unwrap();
    registry.deploy("m", DeploySpec::new(tiny(2))).unwrap();
    let v3 = registry.rollback("m").unwrap();

    let img = random_images(&NetConfig::tiny(), 1, 10).pop().unwrap();
    let engine_a = Engine::new(tiny(1)).unwrap();
    let entry = registry.router().resolve(Some("m")).unwrap();
    assert_eq!(entry.version, v3);
    let reply = entry.client().infer(img.clone()).unwrap();
    assert_eq!(
        reply.scores.unwrap(),
        engine_a.infer(&img).unwrap(),
        "rollback must serve the original weights"
    );
    drop(entry);

    // the history was consumed: nothing left to roll back to
    assert!(registry.rollback("m").is_err());
}

#[test]
fn model_source_and_backend_spec_parse() {
    assert_eq!(
        ModelSource::parse("synthetic:tiny:7").unwrap(),
        ModelSource::Synthetic { config: "tiny".into(), seed: 7 }
    );
    let file = ModelSource::parse("artifacts/model_small.bcnn").unwrap();
    assert!(matches!(file, ModelSource::File(_)));
    assert!(ModelSource::parse("synthetic:").is_err());
    assert!(ModelSource::parse("synthetic:tiny:notanumber").is_err());
    assert!(ModelSource::parse("synthetic:nope:1").unwrap().load().is_err());

    assert_eq!(BackendSpec::parse("engine:4").unwrap(), BackendSpec::Engine { lanes: 4 });
    assert_eq!(
        BackendSpec::parse("pipeline").unwrap(),
        BackendSpec::Pipeline { inflight: 8, stage_threads: 0 }
    );
    assert_eq!(
        BackendSpec::parse("pipeline:4").unwrap(),
        BackendSpec::Pipeline { inflight: 4, stage_threads: 0 }
    );
    assert_eq!(
        BackendSpec::parse("pipeline:4:12").unwrap(),
        BackendSpec::Pipeline { inflight: 4, stage_threads: 12 }
    );
    assert_eq!(BackendSpec::parse("fpga-sim").unwrap(), BackendSpec::FpgaSim);
    assert!(BackendSpec::parse("tpu").is_err());
    assert!(BackendSpec::parse("pipeline:4:x").is_err());
    let label = BackendSpec::Engine { lanes: 2 }.label();
    assert_eq!(BackendSpec::parse(&label).unwrap(), BackendSpec::Engine { lanes: 2 });
    // the stage-balanced pipeline label round-trips too (wire deploys)
    let label = BackendSpec::Pipeline { inflight: 4, stage_threads: 12 }.label();
    assert_eq!(label, "pipeline:4:12");
    assert_eq!(
        BackendSpec::parse(&label).unwrap(),
        BackendSpec::Pipeline { inflight: 4, stage_threads: 12 }
    );
    assert_eq!(BackendSpec::Pipeline { inflight: 8, stage_threads: 0 }.label(), "pipeline:8");
}

type ServerHandle = std::thread::JoinHandle<anyhow::Result<()>>;

fn start_server(registry: Arc<ModelRegistry>) -> (String, Arc<AtomicBool>, ServerHandle) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || serve_registry(listener, registry, stop))
    };
    (addr, stop, handle)
}

#[test]
fn v2_wire_admin_and_routing() {
    let registry = Arc::new(ModelRegistry::new());
    registry.deploy("prod", DeploySpec::new(tiny(1))).unwrap();
    let (addr, stop, server) = start_server(Arc::clone(&registry));

    let mut admin = ControlClient::connect(&addr).unwrap();
    let v = admin.deploy("canary", "synthetic:tiny:5", "engine:2", 1, 16).unwrap();

    let list = admin.list().unwrap();
    let models = list.get("models").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(models.len(), 2);
    let canary = models
        .iter()
        .find(|m| m.get("name").unwrap().as_str().unwrap() == "canary")
        .expect("canary listed");
    assert_eq!(canary.get("version").unwrap().as_f64().unwrap() as u64, v);
    assert_eq!(canary.get("backend").unwrap().as_str().unwrap(), "engine:2");

    // routed inference: each name serves its own weights
    let img = random_images(&NetConfig::tiny(), 1, 3).pop().unwrap();
    let prod_reply = admin.infer("prod", &img).unwrap();
    let canary_reply = admin.infer("canary", &img).unwrap();
    assert_eq!(prod_reply.scores, Engine::new(tiny(1)).unwrap().infer(&img).unwrap());
    assert_eq!(canary_reply.scores, Engine::new(tiny(5)).unwrap().infer(&img).unwrap());
    assert_eq!(canary_reply.version, v);

    // a wire redeploy with unset fields inherits the tuned pool
    // parameters instead of resetting them to defaults
    let v2 = admin.deploy("canary", "synthetic:tiny:6", "", 0, 0).unwrap();
    let list = admin.list().unwrap();
    let canary = list
        .get("models")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|m| m.get("name").unwrap().as_str().unwrap() == "canary")
        .cloned()
        .expect("canary listed");
    assert_eq!(canary.get("version").unwrap().as_f64().unwrap() as u64, v2);
    assert_eq!(
        canary.get("backend").unwrap().as_str().unwrap(),
        "engine:2",
        "unset wire fields must inherit the deployed pool's parameters"
    );

    // unknown model: error frame, connection stays usable
    let err = admin.infer("ghost", &img).unwrap_err();
    assert!(err.to_string().contains("ghost"), "{err}");
    assert!(admin.infer("prod", &img).is_ok(), "connection must survive a routing error");

    // undeploy via wire; the name disappears from LIST
    admin.undeploy("canary").unwrap();
    let list = admin.list().unwrap();
    assert_eq!(list.get("models").unwrap().as_arr().unwrap().len(), 1);
    assert!(admin.undeploy("canary").is_err());
    assert!(admin.infer("prod", &img).is_ok(), "connection must survive an admin error");

    admin.close().unwrap();
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
}

#[test]
fn v1_clients_are_served_by_the_default_model() {
    let registry = Arc::new(ModelRegistry::new());
    registry.deploy("prod", DeploySpec::new(tiny(1))).unwrap();
    registry.deploy("other", DeploySpec::new(tiny(2))).unwrap();
    let (addr, stop, server) = start_server(Arc::clone(&registry));

    let engine = Engine::new(tiny(1)).unwrap();
    let images = random_images(&NetConfig::tiny(), 3, 8);
    let mut v1 = TcpClient::connect(&addr).unwrap();
    for img in &images {
        assert_eq!(v1.infer(img).unwrap(), engine.infer(img).unwrap());
    }
    v1.close().unwrap();

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
}

#[test]
fn garbage_tag_is_rejected_promptly_not_drained() {
    use std::io::{Read, Write};

    let registry = Arc::new(ModelRegistry::new());
    registry.deploy("m", DeploySpec::new(tiny(1))).unwrap();
    let (addr, stop, server) = start_server(Arc::clone(&registry));

    // a tag claiming a ~17 GiB v1 payload (with no payload behind it)
    // must get an immediate error frame + close — the server must not
    // park this connection's thread trying to drain it
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    raw.write_all(&0xFEFF_FFFFu32.to_le_bytes()).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 4];
    raw.read_exact(&mut buf).unwrap();
    assert_eq!(u32::from_le_bytes(buf), u32::MAX, "expected error sentinel");
    raw.read_exact(&mut buf).unwrap();
    let mut msg = vec![0u8; u32::from_le_bytes(buf) as usize];
    raw.read_exact(&mut msg).unwrap();
    assert!(String::from_utf8_lossy(&msg).contains("too large"));
    let mut probe = [0u8; 1];
    assert_eq!(raw.read(&mut probe).unwrap_or(0), 0, "connection must close");
    drop(raw);

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
}

#[test]
fn health_frame_reports_pool_state_over_the_wire() {
    let registry = Arc::new(ModelRegistry::new());
    registry.deploy("prod", DeploySpec::new(tiny(1)).with_workers(2)).unwrap();
    let (addr, stop, server) = start_server(Arc::clone(&registry));

    let mut admin = ControlClient::connect(&addr).unwrap();
    let health = admin.health().unwrap();
    assert_eq!(health.get("epoch").unwrap().as_f64().unwrap() as u64, registry.epoch());
    let models = health.get("models").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(models.len(), 1);
    let prod = &models[0];
    assert_eq!(prod.get("name").unwrap().as_str().unwrap(), "prod");
    assert_eq!(prod.get("state").unwrap().as_str().unwrap(), "ready");
    let shards = prod.get("shards").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(shards.len(), 2, "one health row per worker shard");
    for s in &shards {
        assert_eq!(s.get("state").unwrap().as_str().unwrap(), "ready");
        assert_eq!(s.get("crashes").unwrap().as_f64().unwrap() as u64, 0);
        assert_eq!(s.get("restarts").unwrap().as_f64().unwrap() as u64, 0);
    }

    // the connection survives a HEALTH frame and keeps serving
    let img = random_images(&NetConfig::tiny(), 1, 4).pop().unwrap();
    assert!(admin.infer("prod", &img).is_ok());
    admin.close().unwrap();
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
}

/// Schema pinning: the exact key sets of `STATS` (`stats_json`) and
/// `Metrics::to_json` are wire contract — `repro top` and the CI obs
/// smoke parse them by name, so a silently added, dropped, or renamed
/// key must fail here rather than in a consumer.
#[test]
fn stats_json_schema_is_pinned() {
    use repro::util::json::Json;

    fn keys(j: &Json) -> Vec<String> {
        match j {
            Json::Obj(m) => m.keys().cloned().collect(),
            other => panic!("expected an object, got {other:?}"),
        }
    }

    let registry = ModelRegistry::new();
    registry.deploy("eng", DeploySpec::new(tiny(1))).unwrap();
    registry
        .deploy(
            "pipe",
            DeploySpec::new(tiny(2))
                .with_backend(BackendSpec::Pipeline { inflight: 4, stage_threads: 0 }),
        )
        .unwrap();
    // one request per model so the kernel label and (for the pipeline)
    // the per-stage counters are folded into the pool metrics
    let img = random_images(&NetConfig::tiny(), 1, 12).pop().unwrap();
    for name in ["eng", "pipe"] {
        let entry = registry.router().resolve(Some(name)).unwrap();
        entry.client().infer(img.clone()).unwrap().scores.unwrap();
    }

    let stats = repro::serving::admin::stats_json(&registry);
    assert_eq!(keys(&stats), ["epoch", "frontend", "models", "windows"]);

    // the front-end aggregate and its per-lane counters are wire
    // contract too (`repro top` renders them by name)
    let fe = stats.get("frontend").unwrap();
    assert_eq!(keys(fe), ["connections", "lanes", "paused_reads", "reactor_threads"]);
    let lanes = fe.get("lanes").unwrap();
    assert_eq!(keys(lanes), ["offline", "online"]);
    for lane in ["offline", "online"] {
        assert_eq!(
            keys(lanes.get(lane).unwrap()),
            ["admitted", "depth", "dispatched", "shed_expired", "shed_overload"]
        );
    }

    let base = [
        "batches",
        "crashes",
        "errors",
        "kernel",
        "latency_max_us",
        "latency_mean_us",
        "latency_p50_us",
        "latency_p99_us",
        "mean_batch",
        "modeled_busy_us",
        "requests",
        "requests_failed_over",
        "restarts",
        "throughput",
    ];
    let models = stats.get("models").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(models.len(), 2);
    for m in &models {
        assert_eq!(keys(m), ["backend", "config", "live", "metrics", "name", "version"]);
        let name = m.get("name").unwrap().as_str().unwrap();
        let metrics = m.get("metrics").unwrap();
        if name == "pipe" {
            // staged backends add the per-stage table and its shape flag
            let mut want: Vec<&str> = base.to_vec();
            want.extend(["stages", "stages_mixed"]);
            want.sort_unstable();
            assert_eq!(keys(metrics), want);
            assert!(!metrics.get("stages_mixed").unwrap().as_bool().unwrap());
            let stages = metrics.get("stages").unwrap().as_arr().unwrap().to_vec();
            assert!(!stages.is_empty());
            for s in &stages {
                assert_eq!(
                    keys(s),
                    [
                        "busy_us",
                        "bytes_moved",
                        "images",
                        "lanes",
                        "layer",
                        "popcounts",
                        "rows_in",
                        "stall_in_us",
                        "stall_out_us",
                        "xor_words",
                    ]
                );
            }
        } else {
            assert_eq!(keys(metrics), base);
        }
    }

    // cross a real 1-s window boundary, then pin the window-row schema
    std::thread::sleep(Duration::from_millis(1_100));
    let stats = repro::serving::admin::stats_json(&registry);
    let windows = stats.get("windows").unwrap().as_arr().unwrap().to_vec();
    assert!(!windows.is_empty(), "a 1-s boundary must have closed a window");
    for w in &windows {
        assert_eq!(
            keys(w),
            [
                "crash_rate",
                "crashes",
                "end_s",
                "error_rate",
                "errors",
                "index",
                "latency_max_us",
                "latency_p50_us",
                "latency_p99_us",
                "rate",
                "requests",
                "requests_failed_over",
                "restarts",
            ]
        );
    }
}

/// The acceptance scenario: a continuous client load loop while the
/// server flips between two synthetic configs >= 3 times.  Every
/// submission must be answered, every reply must be bit-identical to a
/// direct `Engine::infer` of the version that claims to have served it,
/// and `STATS` request counts must sum to the number of submissions.
#[test]
fn hot_swap_under_live_traffic_is_lossless_and_bit_exact() {
    const SEED_A: u64 = 101;
    const SEED_B: u64 = 202;
    const CYCLES: usize = 3;
    const THREADS: usize = 3;

    let cfg = NetConfig::tiny();
    let engine_a = Engine::new(tiny(SEED_A)).unwrap();
    let engine_b = Engine::new(tiny(SEED_B)).unwrap();

    let registry = Arc::new(ModelRegistry::new());
    let v1 = registry
        .deploy("m", DeploySpec::new(tiny(SEED_A)).with_workers(2))
        .unwrap();
    let (addr, stop, server) = start_server(Arc::clone(&registry));

    let images = random_images(&cfg, 6, 55);
    let submitted = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for t in 0..THREADS {
        let addr = addr.clone();
        let images = images.clone();
        let stop = Arc::clone(&stop);
        let submitted = Arc::clone(&submitted);
        clients.push(std::thread::spawn(
            move || -> anyhow::Result<Vec<(usize, u64, Vec<f32>)>> {
                let mut conn = ControlClient::connect(&addr)?;
                let mut got = Vec::new();
                let mut i = t;
                while !stop.load(Ordering::Relaxed) {
                    let idx = i % images.len();
                    submitted.fetch_add(1, Ordering::Relaxed);
                    let reply = conn.infer("m", &images[idx])?;
                    got.push((idx, reply.version, reply.scores));
                    i += 1;
                }
                conn.close()?;
                Ok(got)
            },
        ));
    }

    // versions deployed so far -> which weights they serve
    let mut version_seed: BTreeMap<u64, u64> = BTreeMap::new();
    version_seed.insert(v1, SEED_A);
    let mut admin = ControlClient::connect(&addr).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    for _ in 0..CYCLES {
        let v = admin
            .deploy("m", &format!("synthetic:tiny:{SEED_B}"), "engine", 2, 0)
            .unwrap();
        version_seed.insert(v, SEED_B);
        std::thread::sleep(Duration::from_millis(30));
        let v = admin.rollback("m").unwrap();
        version_seed.insert(v, SEED_A);
        std::thread::sleep(Duration::from_millis(30));
    }

    stop.store(true, Ordering::Relaxed);
    let mut replies = Vec::new();
    for c in clients {
        replies.extend(c.join().unwrap().expect("client saw an error (a drop)"));
    }

    // zero drops, zero hangs
    let submitted = submitted.load(Ordering::Relaxed);
    assert_eq!(replies.len() as u64, submitted, "every submission must be answered");
    assert!(submitted > 0, "load loop never ran");

    // bit-exact attribution to the serving version
    let mut versions_seen = std::collections::BTreeSet::new();
    for (idx, version, scores) in &replies {
        let seed = version_seed
            .get(version)
            .unwrap_or_else(|| panic!("reply claims unknown version {version}"));
        versions_seen.insert(*version);
        let engine = if *seed == SEED_A { &engine_a } else { &engine_b };
        assert_eq!(
            &engine.infer(&images[*idx]).unwrap(),
            scores,
            "reply from v{version} diverged from that version's weights"
        );
    }
    assert!(
        versions_seen.len() >= 2,
        "traffic never spanned a swap (saw versions {versions_seen:?}); \
         the test needs in-flight coverage of both configs"
    );

    // STATS conservation across live + retired pools
    let stats = admin.stats().unwrap();
    let mut stats_requests = 0u64;
    for m in stats.get("models").unwrap().as_arr().unwrap() {
        stats_requests +=
            m.get("metrics").unwrap().get("requests").unwrap().as_f64().unwrap() as u64;
    }
    assert_eq!(stats_requests, submitted, "STATS counts must sum to submissions");
    admin.close().unwrap();

    server.join().unwrap().unwrap();
    registry.drain_retired(Duration::from_secs(5)).unwrap();
}
