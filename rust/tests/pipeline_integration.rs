//! Pipeline-runtime integration: the row-streaming layer pipeline against
//! the whole-image engine and the textbook ±1 reference, plus the
//! channel-geometry pinning and the shutdown-with-images-in-flight
//! guarantees.
//!
//! The headline property: [`PipelineBackend`] output is **bit-identical**
//! to `Engine::infer` on every shape — the pipeline runs the same
//! tap-major kernels over a 3-row window, so not even the float ops of
//! the classifier differ in order.

use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::bcnn::{scalar_ref, Engine};
use repro::coordinator::workload::random_images;
use repro::coordinator::{
    Backend, BackendFactory, BatchPolicy, Coordinator, CoordinatorConfig, PipelineBackend,
};
use repro::fpga::channel::{fifo_rows, CHANNEL_SLOTS};
use repro::model::{BcnnModel, ConvSpec, NetConfig};
use repro::pipeline::{PipelineRuntime, StageError, StagePlan};

fn load(name: &str) -> BcnnModel {
    BcnnModel::load_or_synthetic(name, "artifacts", 0xB_C0DE).expect("built-in config")
}

/// Ad-hoc network shapes for the property sweep.
fn custom_cfg(hw: usize, conv: &[(usize, bool)], fc: &[usize]) -> NetConfig {
    NetConfig {
        name: "pipe-prop".into(),
        conv: conv
            .iter()
            .map(|&(out_channels, pool)| ConvSpec { out_channels, pool })
            .collect(),
        fc: fc.to_vec(),
        classes: 10,
        input_hw: hw,
        input_channels: 3,
        input_bits: 6,
    }
}

#[test]
fn pipeline_is_bit_exact_vs_engine_and_reference_on_random_shapes() {
    // the shapes that stress the row window: odd hw (asymmetric borders),
    // channel counts off the 64-bit lattice (partial packed words), pool
    // on/off (fused pair folding), multi-FC tails (row-flatten order)
    let cases: &[(usize, &[(usize, bool)], &[usize])] = &[
        (8, &[(33, false), (65, true)], &[32]),
        (7, &[(64, false)], &[16]),
        (12, &[(100, true), (40, true)], &[]),
        (6, &[(128, true), (96, false)], &[24]),
        (3, &[(5, false)], &[]),
        (2, &[(17, true)], &[]),
    ];
    for (ci, &(hw, conv, fc)) in cases.iter().enumerate() {
        let cfg = custom_cfg(hw, conv, fc);
        let model = BcnnModel::synthetic(&cfg, 0xD00D + ci as u64);
        let engine = Engine::new(model.clone()).expect("valid model");
        let mut backend = PipelineBackend::new(model.clone(), 4).expect("valid model");
        let images = random_images(&cfg, 4, 1000 + ci as u64);
        let piped = backend.infer_owned(&images).unwrap().scores;
        assert_eq!(piped.len(), images.len());
        for (ii, img) in images.iter().enumerate() {
            // vs the whole-image engine: identical arithmetic, identical
            // float op order -> exact equality
            let seq = engine.infer(img).unwrap();
            assert_eq!(piped[ii], seq, "case {ci} image {ii}: pipeline != engine");
            // vs the textbook reference: same tolerance as the engine's
            // own property sweep (float summation order differs there)
            let slow = scalar_ref::infer_reference(&model, img).unwrap();
            assert_eq!(piped[ii].len(), slow.len());
            for (a, b) in piped[ii].iter().zip(&slow) {
                assert!((a - b).abs() < 1e-3, "case {ci} image {ii}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn batch_grouping_does_not_change_scores() {
    // the same 12 images through batch sizes 1, 3, and 12 — grouping is a
    // serving-side artifact and must be invisible in the numerics
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let images = random_images(&model.config(), 12, 33);
    let want: Vec<Vec<f32>> = images.iter().map(|i| engine.infer(i).unwrap()).collect();
    for group in [1usize, 3, 12] {
        let mut backend = PipelineBackend::new(model.clone(), 4).expect("valid model");
        let mut got: Vec<Vec<f32>> = Vec::new();
        for chunk in images.chunks(group) {
            got.extend(backend.infer_owned(chunk).unwrap().scores);
        }
        assert_eq!(got, want, "batch grouping {group} changed the scores");
    }
}

#[test]
fn tickets_complete_in_submission_order_with_many_images_in_flight() {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let runtime = PipelineRuntime::new(Engine::new(model.clone()).unwrap(), 16).unwrap();
    let images = random_images(&model.config(), 16, 5);
    // submit everything before collecting anything: the whole set is in
    // flight across the stages simultaneously
    let tickets: Vec<_> = images
        .iter()
        .map(|img| runtime.submit(img.clone()).unwrap())
        .collect();
    for (img, ticket) in images.iter().zip(tickets) {
        assert_eq!(ticket.wait().unwrap(), engine.infer(img).unwrap());
    }
}

#[test]
fn every_stage_plan_is_bit_exact_and_grouping_insensitive() {
    // Acceptance: under every tested StagePlan, pipelined scores stay
    // bit-identical to Engine::infer AND the batch-1 : batch-64 grouping
    // invariance holds (grouping is a serving-side artifact; the lane
    // groups must not perturb image order or numerics).  Shapes stress
    // the lanes: odd-lattice channels, pool fold, FC tail.
    let cfg = custom_cfg(8, &[(33, false), (65, true)], &[32]);
    let model = BcnnModel::synthetic(&cfg, 0x51A6E);
    let engine = Engine::new(model.clone()).expect("valid model");
    let images = random_images(&cfg, 64, 91);
    let want: Vec<Vec<f32>> = images.iter().map(|i| engine.infer(i).unwrap()).collect();
    let n = engine.layer_shapes().len();
    let plans = vec![
        StagePlan::uniform(n, 1),
        StagePlan::uniform(n, 2),
        StagePlan::uniform(n, 3),
        // deliberately lopsided
        StagePlan { lanes_per_layer: (0..n).map(|i| 1 + (i * 7) % 4).collect() },
        StagePlan::balanced(&engine, 2 * n).expect("calibration"),
    ];
    for plan in plans {
        let label = format!("{:?}", plan.lanes_per_layer);
        let runtime = PipelineRuntime::with_plan(Engine::new(model.clone()).unwrap(), 8, plan)
            .expect("spawn planned pipeline");
        // executed lane counts stay within every layer's split limit
        for (lanes, shape) in runtime.plan().lanes_per_layer.iter().zip(runtime.shapes()) {
            assert!((1..=shape.out_c.max(1)).contains(lanes), "plan {label} not clamped");
        }
        assert_eq!(runtime.thread_count(), runtime.plan().total_lanes() + 1);
        for group in [1usize, 64] {
            let mut got: Vec<Vec<f32>> = Vec::new();
            for chunk in images.chunks(group) {
                let tickets: Vec<_> =
                    chunk.iter().map(|img| runtime.submit(img.clone()).unwrap()).collect();
                got.extend(tickets.into_iter().map(|t| t.wait().unwrap()));
            }
            assert_eq!(got, want, "plan {label} group {group} changed the scores");
        }
    }
}

#[test]
fn oversubscribed_plans_clamp_to_channel_counts() {
    // a plan asking for more lanes than a layer has output channels is
    // clamped, not rejected — and still scores bit-exactly
    let cfg = custom_cfg(4, &[(3, false)], &[]);
    let model = BcnnModel::synthetic(&cfg, 0xC1A);
    let engine = Engine::new(model.clone()).unwrap();
    let n = engine.layer_shapes().len();
    let runtime = PipelineRuntime::with_plan(
        Engine::new(model.clone()).unwrap(),
        4,
        StagePlan { lanes_per_layer: vec![1000; n] },
    )
    .expect("clamped spawn");
    for (lanes, shape) in runtime.plan().lanes_per_layer.iter().zip(runtime.shapes()) {
        assert_eq!(*lanes, shape.out_c, "clamped to out_c");
    }
    // a plan of the wrong length is a construction error, not a panic
    assert!(PipelineRuntime::with_plan(
        Engine::new(model.clone()).unwrap(),
        4,
        StagePlan { lanes_per_layer: vec![1; n + 1] },
    )
    .is_err());
    for img in random_images(&cfg, 4, 55) {
        let want = engine.infer(&img).unwrap();
        assert_eq!(runtime.submit(img).unwrap().wait().unwrap(), want);
    }
}

#[test]
fn stage_stats_expose_the_bottleneck() {
    // per-stage busy/stall counters: after streaming a backlog, every
    // stage has consumed rows and flushed images, and the counters are
    // live (busy time observed somewhere)
    let model = load("tiny");
    let engine = Engine::new(model.clone()).unwrap();
    let n = engine.layer_shapes().len();
    let runtime =
        PipelineRuntime::with_plan(engine, 8, StagePlan::uniform(n, 2)).expect("spawn");
    let images = random_images(&model.config(), 12, 17);
    let tickets: Vec<_> =
        images.iter().map(|img| runtime.submit(img.clone()).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let stats = runtime.stage_stats();
    assert_eq!(stats.len(), n);
    let hw = model.input_hw as u64;
    for (i, s) in stats.iter().enumerate() {
        assert_eq!(s.layer, i);
        assert_eq!(s.images, images.len() as u64, "stage {i} image count");
        if i == 0 {
            assert_eq!(s.rows_in, images.len() as u64 * hw, "stage 0 row count");
        }
        assert!(s.rows_in > 0, "stage {i} consumed no rows");
    }
    assert!(
        stats.iter().any(|s| s.busy > std::time::Duration::ZERO),
        "no stage recorded busy time"
    );
}

#[test]
fn shutdown_failures_are_typed_not_stringly() {
    // the satellite contract: callers distinguish shutdown-in-flight from
    // stage failure by matching the StageError variant, no string-scraping
    let model = load("tiny");
    let runtime = PipelineRuntime::new(Engine::new(model.clone()).unwrap(), 4).unwrap();
    let images = random_images(&model.config(), 8, 23);
    let tickets: Vec<_> =
        images.iter().map(|img| runtime.submit(img.clone()).unwrap()).collect();
    drop(runtime);
    let engine = Engine::new(model).unwrap();
    for (img, ticket) in images.iter().zip(tickets) {
        match ticket.wait_typed() {
            Ok(scores) => assert_eq!(scores, engine.infer(img).unwrap()),
            Err(StageError::Shutdown) => {}
            Err(StageError::Failed(msg)) => {
                panic!("shutdown must not surface as a stage failure: {msg}")
            }
        }
    }
}

#[test]
fn fifo_capacity_is_pinned_to_channel_geometry() {
    // one source of truth: the pipeline's FIFO depth IS the §4.3
    // double-buffer geometry — CHANNEL_SLOTS feature maps of rows per
    // inter-layer channel, nothing locally invented
    let model = load("tiny");
    let runtime = PipelineRuntime::new(Engine::new(model).unwrap(), 2).unwrap();
    let caps = runtime.stage_fifo_capacities();
    let shapes = runtime.shapes();
    assert_eq!(caps.len(), shapes.len());
    for (cap, shape) in caps.iter().zip(shapes) {
        assert_eq!(*cap, fifo_rows(shape.in_hw), "stage fifo drifted from channel geometry");
        assert_eq!(*cap, CHANNEL_SLOTS * shape.in_hw.max(1));
    }
}

#[test]
fn drop_with_images_in_flight_neither_deadlocks_nor_leaks() {
    let model = load("small");
    let runtime = PipelineRuntime::new(Engine::new(model.clone()).unwrap(), 32).unwrap();
    let images = random_images(&model.config(), 24, 9);
    let tickets: Vec<_> = images
        .iter()
        .map(|img| runtime.submit(img.clone()).unwrap())
        .collect();
    // drop the runtime while all 24 images are somewhere between the
    // feeder and the classifier; the drop must drain and join every
    // stage thread in bounded time (watchdogged, not just test-timeout)
    let dropper = std::thread::spawn(move || drop(runtime));
    let deadline = Instant::now() + Duration::from_secs(60);
    while !dropper.is_finished() {
        assert!(Instant::now() < deadline, "PipelineRuntime::drop deadlocked");
        std::thread::sleep(Duration::from_millis(10));
    }
    dropper.join().unwrap();
    // every ticket resolves immediately now — drained images get scores,
    // anything that could not complete gets an error, nothing hangs
    let engine = Engine::new(model).unwrap();
    for (img, ticket) in images.iter().zip(tickets) {
        match ticket.wait() {
            Ok(scores) => assert_eq!(scores, engine.infer(img).unwrap()),
            Err(e) => {
                let msg = e.to_string();
                assert!(
                    msg.contains("shut down") || msg.contains("exited"),
                    "unexpected ticket error: {msg}"
                );
            }
        }
    }
}

#[test]
fn rejects_wrong_image_size_and_shuts_down_idle() {
    let model = load("tiny");
    let runtime = PipelineRuntime::new(Engine::new(model.clone()).unwrap(), 2).unwrap();
    let hw = model.input_hw;
    let c = model.input_channels;
    // wrong image size is rejected before admission
    assert!(runtime.submit(vec![0i32; hw * hw * c + 1]).is_err());
    // explicit shutdown of an idle pipeline joins every thread promptly
    runtime.shutdown();
}

#[test]
fn pipeline_serves_through_the_sharded_coordinator() {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let m = model.clone();
    let factory: BackendFactory = Arc::new(move || -> anyhow::Result<Box<dyn Backend>> {
        Ok(Box::new(PipelineBackend::new(m.clone(), 4)?))
    });
    let coord = Coordinator::start_sharded(
        factory,
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            workers: 2,
            queue_depth: 32,
        },
    )
    .expect("start pipeline pool");
    let client = coord.client();
    let images = random_images(&model.config(), 10, 77);
    for img in &images {
        let reply = client.infer(img.clone()).expect("infer");
        assert_eq!(reply.scores.expect("scores"), engine.infer(img).unwrap());
    }
    let metrics = coord.shutdown();
    assert_eq!(metrics.errors, 0);
    assert_eq!(metrics.requests, images.len() as u64);
}
