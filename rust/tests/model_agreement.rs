//! Property test: the FPGA streaming simulator and the closed-form
//! timing model are the same arithmetic.
//!
//! `fpga::stream::simulate` claims its per-layer cycle counts are the
//! paper's eq. 9-11 (`cycle_real`) evaluated on the layer geometry, and
//! its phase/total/fps identities follow eq. 12.  The performance
//! accounting layer (`obs::account`) leans on exactly that claim when it
//! reconciles measured busy time against the model — so here a swept
//! family of pseudo-random configurations and unroll parameters pins the
//! agreement exactly (`==` on cycles, not a tolerance).

use repro::bcnn::Engine;
use repro::coordinator::workload::random_images;
use repro::fpga::layer_geometry;
use repro::fpga::stream::{simulate, StreamConfig};
use repro::fpga::timing::{cycle_est, cycle_real, LayerParams, PipelineModel};
use repro::model::{BcnnModel, ConvSpec, NetConfig};

/// xorshift64* — deterministic parameter sweep, no rand crate.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn pick<T: Copy>(&mut self, choices: &[T]) -> T {
        choices[(self.next() % choices.len() as u64) as usize]
    }
}

/// A small pseudo-random configuration: 1-2 conv layers, optional pool,
/// optional hidden FC — every shape the geometry walker distinguishes.
fn random_config(rng: &mut Rng, case: usize) -> NetConfig {
    let n_conv = rng.pick(&[1usize, 2]);
    let mut conv = Vec::new();
    for i in 0..n_conv {
        conv.push(ConvSpec {
            out_channels: rng.pick(&[4usize, 8, 16]),
            // pooling halves the resolution; only the first conv pools so
            // the spatial size stays a positive even number
            pool: i == 0 && rng.pick(&[true, false]),
        });
    }
    NetConfig {
        name: format!("prop-{case}"),
        conv,
        fc: if rng.pick(&[true, false]) {
            vec![rng.pick(&[8usize, 16])]
        } else {
            vec![]
        },
        classes: rng.pick(&[4usize, 10]),
        input_hw: rng.pick(&[4usize, 8]),
        input_channels: rng.pick(&[1usize, 3]),
        input_bits: rng.pick(&[4usize, 6]),
    }
}

#[test]
fn simulator_cycles_equal_the_closed_form_model() {
    let mut rng = Rng(0xD1CE_D1CE_D1CE_D1CE);
    for case in 0..12 {
        let cfg = random_config(&mut rng, case);
        let model = BcnnModel::synthetic(&cfg, 0xC0FFEE ^ case as u64);
        let geoms = layer_geometry(&cfg);
        let n_layers = model.layers.len();
        assert_eq!(geoms.len(), n_layers, "case {case}: geometry walker length");

        let params: Vec<LayerParams> = (0..n_layers)
            .map(|_| LayerParams::new(rng.pick(&[1usize, 3]), rng.pick(&[1usize, 2, 4])))
            .collect();
        let pipeline = PipelineModel::default();
        let engine = Engine::new(model).expect("valid model");
        let n_images = 3usize;
        let images = random_images(&cfg, n_images, 0xAB ^ case as u64);

        let stream = StreamConfig {
            freq_hz: 90.0e6,
            params: params.clone(),
            pipeline: pipeline.clone(),
            double_buffered: true,
        };
        let report = simulate(&engine, &stream, &images).expect("simulate");

        // eq. 9-11: per-layer cycles are cycle_real on the geometry, bit
        // for bit, and never less than the pre-overhead estimate
        for (l, (geom, p)) in geoms.iter().zip(&params).enumerate() {
            let expect = cycle_real(geom, p, &pipeline);
            assert_eq!(
                report.layer_cycles[l], expect,
                "case {case} layer {l}: simulator disagrees with cycle_real"
            );
            assert!(
                expect >= cycle_est(geom, p),
                "case {case} layer {l}: overheads made the model go backwards"
            );
        }

        // eq. 12 identities: phase = max cycles, one image per phase, a
        // full pipeline of fill before the first completion
        let phase = *report.layer_cycles.iter().max().expect("non-empty");
        assert_eq!(report.phase_cycles, phase, "case {case}: phase is max layer cycles");
        assert_eq!(
            report.total_cycles,
            (n_images + n_layers) as u64 * phase,
            "case {case}: total = (n + L) * phase"
        );
        for (i, &done) in report.completion_cycles.iter().enumerate() {
            assert_eq!(
                done,
                (i + n_layers + 1) as u64 * phase,
                "case {case}: image {i} completion"
            );
        }
        assert_eq!(report.fps, 90.0e6 / phase as f64, "case {case}: fps = freq / phase");
        for (l, &u) in report.utilization.iter().enumerate() {
            assert_eq!(
                u,
                report.layer_cycles[l] as f64 / phase as f64,
                "case {case} layer {l}: utilization = C_l / phase"
            );
        }

        // numerics ride along: the simulator is bit-exact vs the engine
        for (i, img) in images.iter().enumerate() {
            assert_eq!(
                report.scores[i],
                engine.infer(img).expect("infer"),
                "case {case}: image {i} scores diverged"
            );
        }
    }
}

#[test]
fn sequential_ablation_sums_the_same_cycles() {
    let mut rng = Rng(0xFEED_FACE_CAFE_BEEF);
    for case in 0..6 {
        let cfg = random_config(&mut rng, case);
        let model = BcnnModel::synthetic(&cfg, 0xD0_0D ^ case as u64);
        let geoms = layer_geometry(&cfg);
        let params: Vec<LayerParams> =
            geoms.iter().map(|_| LayerParams::new(1, rng.pick(&[1usize, 2]))).collect();
        let pipeline = PipelineModel::default();
        let engine = Engine::new(model).expect("valid model");
        let n_images = 2usize;
        let images = random_images(&cfg, n_images, 0x51 ^ case as u64);

        let stream = StreamConfig {
            freq_hz: 90.0e6,
            params: params.clone(),
            pipeline: pipeline.clone(),
            double_buffered: false,
        };
        let report = simulate(&engine, &stream, &images).expect("simulate");

        let per_image: u64 = geoms
            .iter()
            .zip(&params)
            .map(|(g, p)| cycle_real(g, p, &pipeline))
            .sum();
        assert_eq!(report.phase_cycles, per_image, "case {case}: phase is the cycle sum");
        assert_eq!(
            report.total_cycles,
            n_images as u64 * per_image,
            "case {case}: no overlap without double buffering"
        );
        assert_eq!(report.fps, 90.0e6 / per_image as f64, "case {case}: sequential fps");
    }
}
