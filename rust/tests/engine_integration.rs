//! Engine integration: the packed-u64 engine against the textbook ±1
//! reference and the PE-array datapath.
//!
//! Equivalence tests run on trained artifacts when present, else on
//! deterministic synthetic weights (both sides consume the same model, so
//! the check is equally strong).  Only the accuracy test needs `make
//! artifacts`, and it skips cleanly without them.

use repro::bcnn::{scalar_ref, Engine, LayerOutput};
use repro::coordinator::workload::random_images;
use repro::fpga::kernel;
use repro::fpga::timing::LayerParams;
use repro::model::{BcnnModel, LayerWeights};
use repro::util::SplitMix64;

fn load(name: &str) -> BcnnModel {
    BcnnModel::load_or_synthetic(name, "artifacts", 0xB_C0DE).expect("built-in config")
}

#[test]
fn engine_matches_textbook_reference_tiny() {
    let model = load("tiny");
    let engine = Engine::new(model.clone());
    let images = random_images(&model.config(), 6, 1);
    for (i, img) in images.iter().enumerate() {
        let fast = engine.infer(img).unwrap();
        let slow = scalar_ref::infer_reference(&model, img).unwrap();
        assert_eq!(fast.len(), slow.len());
        for (a, b) in fast.iter().zip(&slow) {
            assert!((a - b).abs() < 1e-4, "image {i}: {a} vs {b}");
        }
    }
}

#[test]
fn engine_matches_textbook_reference_small() {
    let model = load("small");
    let engine = Engine::new(model.clone());
    let images = random_images(&model.config(), 2, 2);
    for img in &images {
        let fast = engine.infer(img).unwrap();
        let slow = scalar_ref::infer_reference(&model, img).unwrap();
        for (a, b) in fast.iter().zip(&slow) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}

#[test]
fn engine_matches_pe_datapath_per_layer() {
    // drive the same activations through the engine and the fig.6 kernel
    // datapath (independent implementation) layer by layer
    let model = load("tiny");
    let engine = Engine::new(model.clone());
    let images = random_images(&model.config(), 2, 3);
    let mut scratch = repro::bcnn::engine::Scratch::default();
    for img in &images {
        let hw = model.input_hw;
        let c = model.input_channels;
        let mut act = repro::bcnn::Activation::Int { hw, c, data: img.clone() };
        for (i, layer) in model.layers.iter().enumerate() {
            // run_layer_at resolves the layer by index, so the prepared
            // transposed-weight paths engage exactly as in inference
            let engine_out = engine.run_layer_at(i, &act, &mut scratch).unwrap();
            if matches!(layer, LayerWeights::FpConv { .. }) {
                // PE datapath covers binary layers; FpConv is DSP-side
                match engine_out {
                    LayerOutput::Act(a) => act = a,
                    LayerOutput::Scores(_) => unreachable!(),
                }
                continue;
            }
            let kernel_out =
                kernel::run_layer(layer, &act, &LayerParams::new(64, 4)).unwrap();
            match (&engine_out, &kernel_out.output) {
                (LayerOutput::Act(a), LayerOutput::Act(b)) => assert_eq!(a, b),
                (LayerOutput::Scores(a), LayerOutput::Scores(b)) => {
                    for (x, y) in a.iter().zip(b) {
                        assert!((x - y).abs() < 1e-4);
                    }
                }
                _ => panic!("output kind mismatch"),
            }
            match engine_out {
                LayerOutput::Act(a) => act = a,
                LayerOutput::Scores(_) => break,
            }
        }
    }
}

#[test]
fn batch_equals_singles() {
    let model = load("tiny");
    let engine = Engine::new(model.clone());
    let images = random_images(&model.config(), 5, 4);
    let batch = engine.infer_batch(&images).unwrap();
    for (img, want) in images.iter().zip(&batch) {
        assert_eq!(&engine.infer(img).unwrap(), want);
    }
}

#[test]
fn scratch_reuse_is_transparent() {
    let model = load("tiny");
    let engine = Engine::new(model.clone());
    let images = random_images(&model.config(), 4, 5);
    let mut scratch = repro::bcnn::engine::Scratch::default();
    for img in &images {
        let a = engine.infer(img).unwrap();
        let b = engine.infer_with_scratch(img, &mut scratch).unwrap();
        assert_eq!(a, b);
    }
}

#[test]
fn rejects_wrong_image_size() {
    let model = load("tiny");
    let engine = Engine::new(model);
    assert!(engine.infer(&[0i32; 7]).is_err());
}

#[test]
fn deterministic_across_runs() {
    let model = load("small");
    let engine = Engine::new(model.clone());
    let img = random_images(&model.config(), 1, 6).pop().unwrap();
    let a = engine.infer(&img).unwrap();
    let b = engine.infer(&img).unwrap();
    assert_eq!(a, b);
}

#[test]
fn scores_sensitive_to_input() {
    // flipping pixels hard should (almost surely) change some score
    let model = load("small");
    let engine = Engine::new(model.clone());
    let mut rng = SplitMix64::new(7);
    let mut img = random_images(&model.config(), 1, 8).pop().unwrap();
    let base = engine.infer(&img).unwrap();
    let mut changed = false;
    for _ in 0..16 {
        let idx = rng.below(img.len() as u64) as usize;
        let old = img[idx];
        img[idx] = if old > 0 { -31 } else { 31 };
        let new = engine.infer(&img).unwrap();
        img[idx] = old;
        if new != base {
            changed = true;
            break;
        }
    }
    assert!(changed, "16 large pixel perturbations never changed any score");
}

#[test]
fn trained_small_model_beats_chance_on_testset() {
    // the end-to-end trained artifact: accuracy on the held-out synthetic
    // test set must far exceed the 10% chance level (training reached
    // ~100%; see artifacts/model_small.json and EXPERIMENTS.md).  Needs
    // the TRAINED weights — synthetic ones are at chance by construction.
    let Ok(model) = BcnnModel::load("artifacts/model_small.bcnn") else {
        eprintln!("skipping: trained artifacts not present (run `make artifacts`)");
        return;
    };
    let engine = Engine::new(model);
    let Ok(ts) = repro::model::TestSet::load("artifacts/testset_small.bin") else {
        eprintln!("skipping: testset artifact not present (run `make artifacts`)");
        return;
    };
    let mut correct = 0usize;
    for (img, &label) in ts.images.iter().zip(&ts.labels) {
        let scores = engine.infer(img).unwrap();
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == label as usize {
            correct += 1;
        }
    }
    let acc = correct as f64 / ts.len() as f64;
    assert!(acc > 0.9, "accuracy {acc} on {} samples", ts.len());
}
