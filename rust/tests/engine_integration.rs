//! Engine integration: the packed-u64 engine against the textbook ±1
//! reference and the PE-array datapath.
//!
//! Equivalence tests run on trained artifacts when present, else on
//! deterministic synthetic weights (both sides consume the same model, so
//! the check is equally strong).  Only the accuracy test needs `make
//! artifacts`, and it skips cleanly without them.

use repro::bcnn::{
    scalar_ref, Activation, Engine, LayerOutput, ModelError, RowRef, Scratch, StepperOut,
};
use repro::coordinator::workload::random_images;
use repro::fpga::kernel;
use repro::fpga::timing::LayerParams;
use repro::model::{BcnnModel, ConvSpec, LayerWeights, NetConfig};
use repro::util::kernels::{Kernel, KernelKind};
use repro::util::SplitMix64;

fn load(name: &str) -> BcnnModel {
    BcnnModel::load_or_synthetic(name, "artifacts", 0xB_C0DE).expect("built-in config")
}

/// Ad-hoc network shapes for the tap-major property sweep.
fn custom_cfg(hw: usize, conv: &[(usize, bool)], fc: &[usize]) -> NetConfig {
    NetConfig {
        name: "prop".into(),
        conv: conv
            .iter()
            .map(|&(out_channels, pool)| ConvSpec { out_channels, pool })
            .collect(),
        fc: fc.to_vec(),
        classes: 10,
        input_hw: hw,
        input_channels: 3,
        input_bits: 6,
    }
}

#[test]
fn engine_matches_textbook_reference_tiny() {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let images = random_images(&model.config(), 6, 1);
    for (i, img) in images.iter().enumerate() {
        let fast = engine.infer(img).unwrap();
        let slow = scalar_ref::infer_reference(&model, img).unwrap();
        assert_eq!(fast.len(), slow.len());
        for (a, b) in fast.iter().zip(&slow) {
            assert!((a - b).abs() < 1e-4, "image {i}: {a} vs {b}");
        }
    }
}

#[test]
fn engine_matches_textbook_reference_small() {
    let model = load("small");
    let engine = Engine::new(model.clone()).expect("valid model");
    let images = random_images(&model.config(), 2, 2);
    for img in &images {
        let fast = engine.infer(img).unwrap();
        let slow = scalar_ref::infer_reference(&model, img).unwrap();
        for (a, b) in fast.iter().zip(&slow) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}

#[test]
fn engine_matches_pe_datapath_per_layer() {
    // drive the same activations through the engine and the fig.6 kernel
    // datapath (independent implementation) layer by layer
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let images = random_images(&model.config(), 2, 3);
    let mut scratch = repro::bcnn::engine::Scratch::default();
    for img in &images {
        let hw = model.input_hw;
        let c = model.input_channels;
        let mut act = repro::bcnn::Activation::Int { hw, c, data: img.clone() };
        for (i, layer) in model.layers.iter().enumerate() {
            // run_layer_at resolves the layer by index, so the prepared
            // transposed-weight paths engage exactly as in inference
            let engine_out = engine.run_layer_at(i, &act, &mut scratch).unwrap();
            if matches!(layer, LayerWeights::FpConv { .. }) {
                // PE datapath covers binary layers; FpConv is DSP-side
                match engine_out {
                    LayerOutput::Act(a) => act = a,
                    LayerOutput::Scores(_) => unreachable!(),
                }
                continue;
            }
            let kernel_out =
                kernel::run_layer(layer, &act, &LayerParams::new(64, 4)).unwrap();
            match (&engine_out, &kernel_out.output) {
                (LayerOutput::Act(a), LayerOutput::Act(b)) => assert_eq!(a, b),
                (LayerOutput::Scores(a), LayerOutput::Scores(b)) => {
                    for (x, y) in a.iter().zip(b) {
                        assert!((x - y).abs() < 1e-4);
                    }
                }
                _ => panic!("output kind mismatch"),
            }
            match engine_out {
                LayerOutput::Act(a) => act = a,
                LayerOutput::Scores(_) => break,
            }
        }
    }
}

#[test]
fn batch_equals_singles() {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let images = random_images(&model.config(), 5, 4);
    let batch = engine.infer_batch(&images).unwrap();
    for (img, want) in images.iter().zip(&batch) {
        assert_eq!(&engine.infer(img).unwrap(), want);
    }
}

#[test]
fn scratch_reuse_is_transparent() {
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let images = random_images(&model.config(), 4, 5);
    let mut scratch = repro::bcnn::engine::Scratch::default();
    for img in &images {
        let a = engine.infer(img).unwrap();
        let b = engine.infer_with_scratch(img, &mut scratch).unwrap();
        assert_eq!(a, b);
    }
}

#[test]
fn tap_major_matches_reference_on_random_models() {
    // Randomized synthetic models across the shapes that stress the
    // tap-major path: varied hw (odd included), channel counts off the
    // 64-bit lattice, pool on/off, FC widths that exercise the unaligned
    // flatten.  The textbook ±1 reference is the bit-exactness oracle.
    let cases: &[(usize, &[(usize, bool)], &[usize])] = &[
        (8, &[(33, false), (65, true)], &[32]),
        (7, &[(64, false)], &[16]),
        (12, &[(100, true), (40, true)], &[]),
        (6, &[(128, true), (96, false)], &[24]),
    ];
    for (ci, &(hw, conv, fc)) in cases.iter().enumerate() {
        let cfg = custom_cfg(hw, conv, fc);
        let model = BcnnModel::synthetic(&cfg, 0xC0FFEE + ci as u64);
        let engine = Engine::new(model.clone()).expect("valid model");
        let mut scratch = Scratch::default();
        for (ii, img) in random_images(&cfg, 3, 77 + ci as u64).iter().enumerate() {
            let fast = engine.infer_with_scratch(img, &mut scratch).unwrap();
            let slow = scalar_ref::infer_reference(&model, img).unwrap();
            assert_eq!(fast.len(), slow.len());
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-3, "case {ci} image {ii}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn simd_kernels_match_scalar_bit_exactly() {
    // The SIMD dispatch contract: every ISA tier the host can run must
    // reproduce the scalar kernel's scores EXACTLY (same popcounts, same
    // integer thresholds — float equality, not tolerance).  Shapes stress
    // the vector paths: channel counts off the 64-bit word lattice
    // (partial-word tails), widths straddling the 4-word AVX2 vector and
    // 64-word Harley–Seal block boundaries, odd hw (border path), pool
    // on/off, FC widths exercising the flatten dot.
    let simd: Vec<Kernel> = KernelKind::ALL
        .iter()
        .filter(|k| **k != KernelKind::Scalar && k.available())
        .map(|&k| Kernel::force(k).expect("availability checked"))
        .collect();
    if simd.is_empty() {
        eprintln!("skipping: no SIMD kernel available on this host/toolchain");
        return;
    }
    let cases: &[(usize, &[(usize, bool)], &[usize])] = &[
        (8, &[(33, false), (65, true)], &[32]),
        (7, &[(64, false)], &[16]),
        (9, &[(3, false)], &[]),
        (12, &[(100, true), (40, true)], &[]),
        (6, &[(130, true), (96, false)], &[24]),
        (5, &[(9, false)], &[7]),
    ];
    for (ci, &(hw, conv, fc)) in cases.iter().enumerate() {
        let cfg = custom_cfg(hw, conv, fc);
        let model = BcnnModel::synthetic(&cfg, 0x51D_0FF + ci as u64);
        let scalar = Engine::with_kernel(model.clone(), Kernel::scalar()).expect("valid model");
        let images = random_images(&cfg, 3, 909 + ci as u64);
        let want: Vec<Vec<f32>> =
            images.iter().map(|img| scalar.infer(img).unwrap()).collect();
        for &kernel in &simd {
            let engine = Engine::with_kernel(model.clone(), kernel).expect("valid model");
            assert_eq!(engine.kernel().kind(), kernel.kind());
            let mut scratch = Scratch::default();
            for (ii, (img, want)) in images.iter().zip(&want).enumerate() {
                let got = engine.infer_with_scratch(img, &mut scratch).unwrap();
                assert_eq!(&got, want, "case {ci} image {ii} kernel {kernel}");
            }
            // the stage-lane path (partitioned steppers) dispatches the
            // same kernel: OR-merged partitions must also match scalar
            let got = infer_via_partitions(&engine, &images[0], 3);
            assert_eq!(got, want[0], "case {ci} kernel {kernel}: partitioned lanes");
        }
    }
}

#[test]
fn dispatched_kernel_matches_scalar_end_to_end() {
    // whatever Engine::new resolves (BCNN_KERNEL env or auto-detect) must
    // agree exactly with a pinned-scalar engine on a real config
    let model = load("small");
    let dispatched = Engine::new(model.clone()).expect("valid model");
    let scalar = Engine::with_kernel(model.clone(), Kernel::scalar()).expect("valid model");
    for img in &random_images(&model.config(), 4, 31) {
        assert_eq!(
            dispatched.infer(img).unwrap(),
            scalar.infer(img).unwrap(),
            "dispatched kernel {} diverges from scalar",
            dispatched.kernel()
        );
    }
}

#[test]
fn scratch_capacity_stable_after_warmup() {
    // the zero-allocation contract: one warm-up image grows the arena to
    // the network maximum; every later image performs zero heap
    // allocations (scratch capacity frozen, score buffer reused in place)
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let images = random_images(&model.config(), 8, 21);
    let mut scratch = Scratch::default();
    let mut scores = Vec::new();
    engine.infer_into(&images[0], &mut scratch, &mut scores).unwrap();
    let cap = scratch.capacity_bytes();
    let score_cap = scores.capacity();
    assert!(cap > 0, "warm-up must populate the arena");
    assert_eq!(scores.len(), model.classes);
    for img in images.iter().cycle().take(64) {
        engine.infer_into(img, &mut scratch, &mut scores).unwrap();
    }
    assert_eq!(scratch.capacity_bytes(), cap, "scratch arena grew after warm-up");
    assert_eq!(scores.capacity(), score_cap, "score buffer grew after warm-up");
}

#[test]
fn odd_pool_rejected_at_construction() {
    // first layer pooling at hw = 9
    let model = BcnnModel::synthetic(&custom_cfg(9, &[(32, true)], &[]), 1);
    match Engine::new(model) {
        Err(ModelError::OddPoolInput { layer: 0, hw: 9 }) => {}
        other => panic!("expected OddPoolInput at layer 0, got {other:?}"),
    }
    // second pool hits an odd resolution only after the first halving
    let model = BcnnModel::synthetic(&custom_cfg(6, &[(16, true), (16, true)], &[]), 2);
    match Engine::new(model) {
        Err(ModelError::OddPoolInput { layer: 1, hw: 3 }) => {}
        other => panic!("expected OddPoolInput at layer 1, got {other:?}"),
    }
}

#[test]
fn malformed_weight_rows_rejected() {
    let cfg = custom_cfg(8, &[(32, false), (32, false)], &[]);
    let mut model = BcnnModel::synthetic(&cfg, 3);
    for layer in &mut model.layers {
        if let LayerWeights::BinConv { words_per_row, .. } = layer {
            *words_per_row += 1; // corrupt the packed row stride
            break;
        }
    }
    match Engine::new(model) {
        Err(ModelError::WeightRowWidth { layer: 1, .. }) => {}
        other => panic!("expected WeightRowWidth at layer 1, got {other:?}"),
    }
}

#[test]
fn inconsistent_layer_chain_rejected() {
    // in_f shrunk within the same packed word count: every per-layer
    // check still passes, so only the cross-layer geometry walk can
    // catch it (before that walk existed, the row-streaming path would
    // score such a model against phantom pad bits instead of erroring)
    let cfg = custom_cfg(8, &[(16, true)], &[32]);
    let mut model = BcnnModel::synthetic(&cfg, 4);
    let mut declared = 0usize;
    for layer in &mut model.layers {
        if let LayerWeights::BinFc { in_f, .. } = layer {
            declared = *in_f;
            *in_f -= 6; // words_for unchanged, bit width wrong
            break;
        }
    }
    assert!(declared > 0, "config has a hidden FC layer");
    match Engine::new(model) {
        Err(ModelError::ChainMismatch { layer: 1, what: "input features", got, want }) => {
            assert_eq!((got, want), (declared - 6, declared));
        }
        other => panic!("expected ChainMismatch at layer 1, got {other:?}"),
    }
}

#[test]
fn portable_run_layer_matches_prepared_path() {
    // the on-the-fly prepared path (arbitrary layer values) must agree
    // with the index-addressed prepared banks
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let img = random_images(&model.config(), 1, 91).pop().unwrap();
    let mut act = repro::bcnn::Activation::Int {
        hw: model.input_hw,
        c: model.input_channels,
        data: img,
    };
    let mut scratch = Scratch::default();
    for (i, layer) in model.layers.iter().enumerate() {
        let a = engine.run_layer_at(i, &act, &mut scratch).unwrap();
        let b = engine.run_layer(layer, &act).unwrap();
        assert_eq!(a, b, "layer {i}");
        match a {
            LayerOutput::Act(next) => act = next,
            LayerOutput::Scores(_) => break,
        }
    }
}

#[test]
fn layer_stepper_rows_match_whole_image_layers() {
    // the pipeline's building block: feeding a layer row by row must
    // reproduce the whole-image path bit for bit — same packed words,
    // same row count, same classifier floats
    let model = load("tiny");
    let engine = Engine::new(model.clone()).expect("valid model");
    let img = random_images(&model.config(), 1, 55).pop().unwrap();
    let mut act = Activation::Int { hw: model.input_hw, c: model.input_channels, data: img };
    let mut scratch = Scratch::default();
    for i in 0..model.layers.len() {
        let mut stepper = engine.layer_stepper(i).unwrap();
        let shape = stepper.shape();
        let mut rows: Vec<Vec<u64>> = Vec::new();
        let mut scores: Option<Vec<f32>> = None;
        {
            let mut emit = |o: StepperOut| match o {
                StepperOut::Row(r) => rows.push(r),
                StepperOut::Scores(s) => scores = Some(s),
            };
            match &act {
                Activation::Int { hw, c, data } => {
                    let (hw, c) = (*hw, *c);
                    for y in 0..hw {
                        stepper
                            .push_row(RowRef::Int(&data[y * hw * c..(y + 1) * hw * c]), &mut emit)
                            .unwrap();
                    }
                }
                Activation::Bits(f) => {
                    let wpr = f.hw * f.words_per_pixel;
                    for y in 0..f.hw {
                        stepper
                            .push_row(RowRef::Bits(&f.data[y * wpr..(y + 1) * wpr]), &mut emit)
                            .unwrap();
                    }
                }
            }
            stepper.flush(&mut emit).unwrap();
        }
        match engine.run_layer_at(i, &act, &mut scratch).unwrap() {
            LayerOutput::Act(next) => {
                let Activation::Bits(f) = &next else {
                    panic!("layer {i}: expected binary activation");
                };
                assert_eq!(rows.len(), shape.out_hw, "layer {i} row count");
                assert_eq!(rows.concat(), f.data, "layer {i} packed rows");
                act = next;
            }
            LayerOutput::Scores(s) => {
                assert!(rows.is_empty(), "classifier layer {i} must not emit rows");
                assert_eq!(scores, Some(s), "layer {i} scores");
                break;
            }
        }
    }
}

/// Run the whole network through channel-partitioned steppers (`lanes`
/// per layer), merging lane emissions exactly like a pipeline stage lane
/// group does: packed rows OR together (disjoint bit-ranges), classifier
/// score slices concatenate in ascending lane order.
fn infer_via_partitions(engine: &Engine, img: &[i32], lanes: usize) -> Vec<f32> {
    enum Rows {
        Int(Vec<Vec<i32>>),
        Bits(Vec<Vec<u64>>),
    }
    let model = engine.model();
    let (hw, c) = (model.input_hw, model.input_channels);
    let mut rows =
        Rows::Int((0..hw).map(|y| img[y * hw * c..(y + 1) * hw * c].to_vec()).collect());
    for (i, shape) in engine.layer_shapes().iter().enumerate() {
        let l = lanes.clamp(1, shape.out_c);
        let bounds: Vec<(usize, usize)> =
            (0..l).map(|k| (k * shape.out_c / l, (k + 1) * shape.out_c / l)).collect();
        // every lane sees the full input rows and emits the same schedule
        let mut per_lane: Vec<Vec<StepperOut>> = Vec::with_capacity(l);
        for &(lo, hi) in &bounds {
            let mut stepper = engine.layer_stepper_part(i, lo, hi).unwrap();
            assert_eq!(stepper.partition(), (lo, hi));
            let mut outs: Vec<StepperOut> = Vec::new();
            {
                let mut emit = |o: StepperOut| outs.push(o);
                match &rows {
                    Rows::Int(rs) => {
                        for r in rs {
                            stepper.push_row(RowRef::Int(r), &mut emit).unwrap();
                        }
                    }
                    Rows::Bits(rs) => {
                        for r in rs {
                            stepper.push_row(RowRef::Bits(r), &mut emit).unwrap();
                        }
                    }
                }
                stepper.flush(&mut emit).unwrap();
            }
            per_lane.push(outs);
        }
        let mut merged = per_lane.remove(0);
        for outs in per_lane {
            assert_eq!(outs.len(), merged.len(), "layer {i}: lane emission schedules diverged");
            for (m, o) in merged.iter_mut().zip(outs) {
                match (m, o) {
                    (StepperOut::Row(a), StepperOut::Row(b)) => {
                        assert_eq!(a.len(), b.len(), "layer {i}: partial row widths");
                        for (x, y) in a.iter_mut().zip(&b) {
                            // partitions own disjoint bit-ranges
                            assert_eq!(*x & *y, 0, "layer {i}: partitions overlap");
                            *x |= *y;
                        }
                    }
                    (StepperOut::Scores(a), StepperOut::Scores(b)) => a.extend_from_slice(&b),
                    _ => panic!("layer {i}: lane emission kinds diverged"),
                }
            }
        }
        if shape.scores {
            assert_eq!(merged.len(), 1, "classifier emits once");
            let Some(StepperOut::Scores(scores)) = merged.pop() else {
                panic!("classifier layer must emit scores");
            };
            return scores;
        }
        rows = Rows::Bits(
            merged
                .into_iter()
                .map(|o| match o {
                    StepperOut::Row(r) => r,
                    StepperOut::Scores(_) => panic!("hidden layer emitted scores"),
                })
                .collect(),
        );
    }
    panic!("model has no classifier layer");
}

#[test]
fn partitioned_steppers_compose_bit_exactly() {
    // The stage-lane contract: for every lane count, OR-merging the
    // partitions' packed rows and concatenating their score slices must
    // reproduce Engine::infer bit for bit (and the textbook reference
    // within float tolerance).  Shapes stress the partition math: odd hw
    // (asymmetric borders), out_c off the 64-bit word lattice (partition
    // boundaries inside packed words), pool on/off (fused pair folding),
    // FC tails (feature-range dot products).
    let cases: &[(usize, &[(usize, bool)], &[usize])] = &[
        (8, &[(33, false), (65, true)], &[32]),
        (7, &[(64, false)], &[16]),
        (12, &[(100, true), (40, true)], &[]),
        (6, &[(128, true), (96, false)], &[24]),
        (5, &[(9, false)], &[]),
        (2, &[(17, true)], &[]),
    ];
    for (ci, &(hw, conv, fc)) in cases.iter().enumerate() {
        let cfg = custom_cfg(hw, conv, fc);
        let model = BcnnModel::synthetic(&cfg, 0xFA2_B417 + ci as u64);
        let engine = Engine::new(model.clone()).expect("valid model");
        for (ii, img) in random_images(&cfg, 2, 4242 + ci as u64).iter().enumerate() {
            let want = engine.infer(img).unwrap();
            let slow = scalar_ref::infer_reference(&model, img).unwrap();
            for lanes in 1..=4usize {
                let got = infer_via_partitions(&engine, img, lanes);
                assert_eq!(
                    got, want,
                    "case {ci} image {ii} lanes {lanes}: partition merge != Engine::infer"
                );
                assert_eq!(got.len(), slow.len());
                for (a, b) in got.iter().zip(&slow) {
                    assert!(
                        (a - b).abs() < 1e-3,
                        "case {ci} image {ii} lanes {lanes}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn partition_bounds_validated() {
    let model = load("tiny");
    let engine = Engine::new(model).expect("valid model");
    let out_c = engine.layer_shapes()[0].out_c;
    assert!(engine.layer_stepper_part(0, 0, out_c + 1).is_err(), "hi past out_c");
    assert!(engine.layer_stepper_part(0, 3, 3).is_err(), "empty range");
    assert!(engine.layer_stepper_part(99, 0, 1).is_err(), "layer index");
    assert!(engine.layer_stepper_part(0, 0, out_c).is_ok(), "full range");
}

#[test]
fn rejects_wrong_image_size() {
    let model = load("tiny");
    let engine = Engine::new(model).expect("valid model");
    assert!(engine.infer(&[0i32; 7]).is_err());
}

#[test]
fn deterministic_across_runs() {
    let model = load("small");
    let engine = Engine::new(model.clone()).expect("valid model");
    let img = random_images(&model.config(), 1, 6).pop().unwrap();
    let a = engine.infer(&img).unwrap();
    let b = engine.infer(&img).unwrap();
    assert_eq!(a, b);
}

#[test]
fn scores_sensitive_to_input() {
    // flipping pixels hard should (almost surely) change some score
    let model = load("small");
    let engine = Engine::new(model.clone()).expect("valid model");
    let mut rng = SplitMix64::new(7);
    let mut img = random_images(&model.config(), 1, 8).pop().unwrap();
    let base = engine.infer(&img).unwrap();
    let mut changed = false;
    for _ in 0..16 {
        let idx = rng.below(img.len() as u64) as usize;
        let old = img[idx];
        img[idx] = if old > 0 { -31 } else { 31 };
        let new = engine.infer(&img).unwrap();
        img[idx] = old;
        if new != base {
            changed = true;
            break;
        }
    }
    assert!(changed, "16 large pixel perturbations never changed any score");
}

#[test]
fn trained_small_model_beats_chance_on_testset() {
    // the end-to-end trained artifact: accuracy on the held-out synthetic
    // test set must far exceed the 10% chance level (training reached
    // ~100%; see artifacts/model_small.json and EXPERIMENTS.md).  Needs
    // the TRAINED weights — synthetic ones are at chance by construction.
    let Ok(model) = BcnnModel::load("artifacts/model_small.bcnn") else {
        eprintln!("skipping: trained artifacts not present (run `make artifacts`)");
        return;
    };
    let engine = Engine::new(model).expect("valid model");
    let Ok(ts) = repro::model::TestSet::load("artifacts/testset_small.bin") else {
        eprintln!("skipping: testset artifact not present (run `make artifacts`)");
        return;
    };
    let mut correct = 0usize;
    for (img, &label) in ts.images.iter().zip(&ts.labels) {
        let scores = engine.infer(img).unwrap();
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == label as usize {
            correct += 1;
        }
    }
    let acc = correct as f64 / ts.len() as f64;
    assert!(acc > 0.9, "accuracy {acc} on {} samples", ts.len());
}
