//! Fault-injection integration tests: the supervision layer under
//! deterministic crash schedules (DESIGN.md §6).
//!
//! Every test arms a process-global [`FaultPlan`], so the cases serialize
//! through one mutex and disarm on drop — a panicking assertion cannot
//! leak an armed plan into the next case.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use repro::bcnn::Engine;
use repro::coordinator::workload::random_images;
use repro::coordinator::{
    serve_tcp_frontend, Backend, BackendFactory, Coordinator, CoordinatorConfig, FrontendConfig,
    NativeBackend, PipelineBackend, RestartPolicy, SubmitError, TcpClient,
};
use repro::model::{BcnnModel, NetConfig};
use repro::pipeline::PipelineRuntime;
use repro::serving::{DeploySpec, ModelRegistry, RouteError};
use repro::util::faults::{self, FaultPlan};
use repro::util::sync::lock_recover;

/// Serializes the armed-plan global across test threads and guarantees
/// disarm even when the test body panics.
struct FaultGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for FaultGuard {
    fn drop(&mut self) {
        faults::clear();
    }
}

fn arm(spec: &str) -> FaultGuard {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let guard = lock_recover(LOCK.get_or_init(|| Mutex::new(())));
    faults::install(FaultPlan::parse(spec).expect("valid fault spec"));
    FaultGuard(guard)
}

fn tiny_model() -> BcnnModel {
    BcnnModel::synthetic(&NetConfig::tiny(), 5)
}

fn native_factory(model: &BcnnModel) -> BackendFactory {
    let model = model.clone();
    Arc::new(move || {
        let b = NativeBackend::new(model.clone())?;
        Ok(Box::new(b) as Box<dyn Backend>)
    })
}

fn fast_restart(max_consecutive: u32) -> RestartPolicy {
    RestartPolicy {
        max_consecutive,
        base_backoff: Duration::from_millis(1),
        max_backoff: Duration::from_millis(4),
    }
}

#[test]
fn worker_panic_fails_the_batch_typed_then_restarts() {
    let _g = arm("backend_infer:panic@once=1");
    let model = tiny_model();
    let cfg = model.config();
    let oracle = Engine::new(model.clone()).unwrap();
    let coord = Coordinator::start_sharded(
        native_factory(&model),
        CoordinatorConfig {
            workers: 1,
            queue_depth: 16,
            restart: fast_restart(3),
            ..Default::default()
        },
    )
    .unwrap();
    let client = coord.client();
    let img = random_images(&cfg, 1, 3).remove(0);

    // the very first batch rides the injected panic: a typed error reply,
    // not a hang and not a dropped channel
    let rx = client.submit(img.clone()).expect("queue accepts while worker crashes");
    let reply = rx.recv_timeout(Duration::from_secs(10)).expect("crashed batch must still reply");
    assert!(reply.scores.is_err(), "batch on a crashing worker must fail typed");

    // the supervisor rebuilds the replica in place: the next request is
    // served bit-exact on the SAME pool, queue and all
    let rx = client
        .submit_deadline(img.clone(), Duration::from_secs(5))
        .expect("restarted shard accepts work");
    let reply = rx.recv_timeout(Duration::from_secs(10)).expect("restarted shard replies");
    let scores = reply.scores.expect("restarted shard serves successfully");
    assert_eq!(scores, oracle.infer(&img).unwrap(), "post-restart scores must be bit-exact");

    let health = coord.health();
    assert!(health.serviceable(), "one crash must not take the pool down");
    assert_eq!(health.crashes(), 1);
    assert_eq!(health.restarts(), 1);
    let metrics = coord.shutdown();
    assert_eq!(metrics.crashes, 1);
    assert_eq!(metrics.restarts, 1);
    assert!(metrics.errors >= 1, "the crashed batch counts as an error");
}

#[test]
fn repeated_crashes_trip_the_breaker_to_shard_down() {
    let _g = arm("backend_infer:panic@p=1");
    let model = tiny_model();
    let cfg = model.config();
    let coord = Coordinator::start_sharded(
        native_factory(&model),
        CoordinatorConfig {
            workers: 1,
            queue_depth: 16,
            restart: fast_restart(2),
            ..Default::default()
        },
    )
    .unwrap();
    let client = coord.client();
    let img = random_images(&cfg, 1, 3).remove(0);

    // every batch crashes; after 2 consecutive crashes the breaker opens
    // and submits are refused with the typed crash-down error
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut saw_down = false;
    while Instant::now() < deadline {
        match client.submit(img.clone()) {
            Ok(rx) => {
                let reply = rx
                    .recv_timeout(Duration::from_secs(10))
                    .expect("even a doomed batch must reply");
                assert!(reply.scores.is_err());
            }
            Err(SubmitError::ShardDown { image }) => {
                assert_eq!(image, img, "refused submit must hand the image back");
                saw_down = true;
                break;
            }
            Err(SubmitError::QueueFull { .. }) => std::thread::sleep(Duration::from_millis(1)),
            Err(SubmitError::Shutdown) => panic!("pool was never shut down"),
        }
    }
    assert!(saw_down, "breaker never tripped to ShardDown");
    let health = coord.health();
    assert!(!health.serviceable());
    assert_eq!(health.label(), "down");
    assert!(health.crashes() >= 2);
    // shutdown still joins cleanly on a breaker-dead pool (no hang)
    let metrics = coord.shutdown();
    assert!(metrics.crashes >= 2);
}

#[test]
fn stage_death_fails_tickets_typed_within_watchdog_window() {
    let _g = arm("stage_emit:panic@once=3");
    let model = tiny_model();
    let cfg = model.config();
    let images = random_images(&cfg, 4, 9);

    // run the whole submit+wait sequence on a worker thread so a hang —
    // the exact bug the containment exists to prevent — fails the test
    // via the watchdog instead of wedging the harness
    let (done_tx, done_rx) = mpsc::channel();
    let worker = std::thread::spawn(move || {
        let runtime = PipelineRuntime::new(Engine::new(model).unwrap(), 2).unwrap();
        let mut failures = 0usize;
        for img in &images {
            match runtime.submit(img.clone()) {
                Ok(t) => {
                    if t.wait_typed().is_err() {
                        failures += 1;
                    }
                }
                Err(_) => failures += 1,
            }
        }
        let crashes = runtime.crashes();
        let latched = runtime.failure().is_some();
        runtime.shutdown();
        let _ = done_tx.send((failures, crashes, latched));
    });
    let (failures, crashes, latched) = done_rx
        .recv_timeout(Duration::from_secs(20))
        .expect("stage death hung the pipeline instead of failing typed");
    worker.join().unwrap();
    assert!(failures > 0, "the killed stage must fail at least one ticket");
    assert_eq!(crashes, 1, "exactly one contained stage panic");
    assert!(latched, "the failure must latch so future submits fail fast");
}

#[test]
fn pipeline_backend_degrades_to_bit_exact_engine_path() {
    let _g = arm("stage_emit:panic@once=2");
    let model = tiny_model();
    let cfg = model.config();
    let images = random_images(&cfg, 4, 21);
    let oracle = Engine::new(model.clone()).unwrap();
    let expected: Vec<Vec<f32>> = images.iter().map(|i| oracle.infer(i).unwrap()).collect();

    let mut backend = PipelineBackend::new(model, 2).unwrap();
    // the stage dies with this batch in flight; the backend must still
    // answer the WHOLE batch, re-run bit-exact on the engine fallback
    let result = backend.infer_owned(&images).expect("degraded backend still serves");
    assert_eq!(result.scores, expected, "fallback scores must match the scalar oracle");
    assert!(backend.degraded());
    assert_eq!(backend.name(), "pipeline-degraded");
    assert_eq!(backend.crashes(), 1);
    assert_eq!(backend.failovers(), images.len() as u64, "every fallback request is counted");

    // later batches keep being served (and counted) on the fallback
    let again = backend.infer_owned(&images).unwrap();
    assert_eq!(again.scores, expected);
    assert_eq!(backend.failovers(), 2 * images.len() as u64);
}

#[test]
fn submit_deny_storm_is_masked_by_deadline_retry() {
    let _g = arm("submit:deny@first=3");
    let model = tiny_model();
    let cfg = model.config();
    let oracle = Engine::new(model.clone()).unwrap();
    let coord = Coordinator::start_sharded(
        native_factory(&model),
        CoordinatorConfig { workers: 1, queue_depth: 16, ..Default::default() },
    )
    .unwrap();
    let client = coord.client();
    let img = random_images(&cfg, 1, 3).remove(0);

    // a bare submit eats injected hit 1: synthetic backpressure
    match client.submit(img.clone()) {
        Err(SubmitError::QueueFull { image }) => assert_eq!(image, img),
        other => panic!("expected injected QueueFull, got {:?}", other.map(|_| "Ok")),
    }
    // the deadline path retries through hits 2 and 3 and succeeds on 4
    let rx = client
        .submit_deadline(img.clone(), Duration::from_secs(5))
        .expect("retry loop must mask the deny storm");
    let reply = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(reply.scores.unwrap(), oracle.infer(&img).unwrap());
    coord.shutdown();
}

#[test]
fn router_fails_over_to_healthy_same_config_model() {
    let _g = arm("backend_infer:panic@p=1");
    let model = tiny_model();
    let cfg = model.config();
    let registry = ModelRegistry::new();
    registry.deploy("a", DeploySpec::new(model.clone())).unwrap();
    // drive "a" into breaker-open: every batch crashes, and only "a"
    // receives traffic, so "b" (deployed after disarming below) stays
    // healthy
    let entry_a = registry.router().resolve(Some("a")).unwrap();
    let img = random_images(&cfg, 1, 3).remove(0);
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(Instant::now() < deadline, "breaker never opened on model a");
        match entry_a.client().submit(img.clone()) {
            Ok(rx) => {
                let _ = rx.recv_timeout(Duration::from_secs(10)).expect("typed reply");
            }
            Err(SubmitError::ShardDown { .. }) => break,
            Err(SubmitError::QueueFull { .. }) => std::thread::sleep(Duration::from_millis(1)),
            Err(SubmitError::Shutdown) => panic!("pool was never shut down"),
        }
    }
    assert!(!entry_a.is_serviceable());
    assert_eq!(entry_a.health().label(), "down");

    // no compatible standby yet: the router reports Degraded, typed
    match registry.router().resolve_healthy(Some("a")) {
        Err(RouteError::Degraded(name)) => assert_eq!(name, "a"),
        other => panic!("expected Degraded, got {:?}", other.map(|e| e.name.clone())),
    }

    // disarm, then deploy a same-config standby: resolution fails over
    faults::clear();
    registry.deploy("b", DeploySpec::new(model.clone())).unwrap();
    let routed = registry.router().resolve_healthy(Some("a")).expect("failover target exists");
    assert_eq!(routed.name, "b", "router must fail over to the healthy same-config entry");
    assert_eq!(routed.health().label(), "ready");

    // and the failover target really serves, bit-exact
    let oracle = Engine::new(model).unwrap();
    let rx = routed.client().submit_deadline(img.clone(), Duration::from_secs(5)).unwrap();
    let reply = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(reply.scores.unwrap(), oracle.infer(&img).unwrap());
}

/// Spawn a reactor front-end over a 1-worker pool; returns everything a
/// chaos case needs to drive it and tear it down.
fn start_frontend(
    model: &BcnnModel,
) -> (String, Arc<AtomicBool>, std::thread::JoinHandle<anyhow::Result<()>>, Coordinator) {
    let coord = Coordinator::start_sharded(
        native_factory(model),
        CoordinatorConfig { workers: 1, queue_depth: 16, ..Default::default() },
    )
    .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let client = coord.client();
    let serve = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            serve_tcp_frontend(listener, client, stop, FrontendConfig::default())
        })
    };
    (addr, stop, serve, coord)
}

#[test]
fn reactor_frontend_sheds_injected_read_and_write_faults_typed() {
    let _g = arm("server_read:deny@once=1;server_write:deny@once=1");
    let model = tiny_model();
    let cfg = model.config();
    let oracle = Engine::new(model.clone()).unwrap();
    let (addr, stop, serve, coord) = start_frontend(&model);
    let img = random_images(&cfg, 1, 3).remove(0);
    let want = oracle.infer(&img).unwrap();

    // run the client sequence behind a watchdog: a reactor that loses a
    // request to an injected fault would hang the blocking client
    let (done_tx, done_rx) = mpsc::channel();
    let worker = std::thread::spawn(move || {
        let mut client = TcpClient::connect(&addr).unwrap();
        // request 1 eats the read-side deny: typed shed, connection alive
        let e = client.infer(&img).expect_err("read-side deny must surface as an error reply");
        assert!(e.to_string().contains("server_read"), "{e}");
        // request 2 survives parsing but its reply rides the write-side
        // deny: a typed error frame instead of the scores
        let e = client.infer(&img).expect_err("write-side deny must surface as an error reply");
        assert!(e.to_string().contains("server_write"), "{e}");
        // request 3 sails through on the same connection, bit-exact
        let scores = client.infer(&img).expect("connection must outlive both injected faults");
        assert_eq!(scores, want, "post-fault scores must be bit-exact");
        client.close().unwrap();
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("reactor lost a request to an injected fault");
    worker.join().unwrap();

    stop.store(true, Ordering::Relaxed);
    serve.join().unwrap().unwrap();
    coord.shutdown();
}

#[test]
fn reactor_frontend_is_bit_exact_under_injected_read_delays() {
    let _g = arm("seed=7;server_read:delay=1ms@p=0.5");
    let model = tiny_model();
    let cfg = model.config();
    let oracle = Engine::new(model.clone()).unwrap();
    let (addr, stop, serve, coord) = start_frontend(&model);
    let images = random_images(&cfg, 8, 17);

    // random decode-path stalls must reorder nothing and corrupt nothing
    let (done_tx, done_rx) = mpsc::channel();
    let worker = std::thread::spawn(move || {
        let mut client = TcpClient::connect(&addr).unwrap();
        for img in &images {
            let scores = client.infer(img).expect("delayed request still serves");
            assert_eq!(scores, oracle.infer(img).unwrap(), "delayed reply must be bit-exact");
        }
        client.close().unwrap();
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("injected read delays wedged the reactor");
    worker.join().unwrap();

    stop.store(true, Ordering::Relaxed);
    serve.join().unwrap().unwrap();
    coord.shutdown();
}

#[test]
fn fault_free_paths_are_untouched_when_disarmed() {
    let _g = arm(""); // empty plan: armed machinery off, sites are no-ops
    assert!(!faults::active());
    let model = tiny_model();
    let cfg = model.config();
    let oracle = Engine::new(model.clone()).unwrap();
    let coord = Coordinator::start_sharded(
        native_factory(&model),
        CoordinatorConfig { workers: 2, queue_depth: 16, ..Default::default() },
    )
    .unwrap();
    let client = coord.client();
    let images = random_images(&cfg, 8, 13);
    for img in &images {
        let rx = client.submit_deadline(img.clone(), Duration::from_secs(5)).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(reply.scores.unwrap(), oracle.infer(img).unwrap());
    }
    let metrics = coord.shutdown();
    assert_eq!(metrics.crashes, 0);
    assert_eq!(metrics.restarts, 0);
    assert_eq!(metrics.requests_failed_over, 0);
    assert_eq!(metrics.errors, 0);
}
