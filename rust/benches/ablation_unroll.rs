//! Ablation: the unfolding factor rule (paper §4.2.1 / §6).
//!
//! Sweeps `uf_scale` over the paper's full FW*FD unroll and fractions of
//! it, re-running the optimizer each time.  Shows the §4.2 trade: temporal
//! (UF) and spatial (P) parallelism are interchangeable for Cycle_est, but
//! spatial parallelism costs accumulator DSPs and PE instances while
//! unfolding costs BRAM read bandwidth.
//!
//! Run: `cargo bench --bench ablation_unroll`

use repro::benchkit::Table;
use repro::model::NetConfig;
use repro::optimizer::{optimize, OptimizeOptions};

fn main() {
    let mut t = Table::new(&[
        "uf_scale",
        "bottleneck_est",
        "bottleneck_real",
        "FPS(model)",
        "LUTs",
        "BRAMs",
        "DSPs",
        "sum(P) conv",
    ]);
    for &scale in &[1.0f64, 0.5, 0.25, 0.125] {
        let opts = OptimizeOptions { uf_scale: scale, ..OptimizeOptions::default() };
        match optimize(&NetConfig::table2(), &opts) {
            Ok(plan) => {
                let sum_p: u64 = plan.layers[..6].iter().map(|l| l.params.p as u64).sum();
                t.row(&[
                    format!("{scale}"),
                    plan.bottleneck_est.to_string(),
                    plan.bottleneck_real.to_string(),
                    format!("{:.0}", plan.fps),
                    plan.resources.total.luts.to_string(),
                    plan.resources.total.brams.to_string(),
                    plan.resources.total.dsps.to_string(),
                    sum_p.to_string(),
                ]);
            }
            Err(e) => {
                t.row(&[
                    format!("{scale}"),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("infeasible: {e}"),
                    "-".into(),
                ]);
            }
        }
    }
    println!("=== unfolding-factor ablation (Table-2 network, Virtex-7 budget) ===");
    t.print();
    println!(
        "\nreading: at uf_scale=1.0 the paper's UF=FW*FD rule holds the DSP and\n\
         BRAM-bank budgets low; shrinking UF forces the optimizer to buy the\n\
         same lanes as spatial parallelism (P doubles per halving), inflating\n\
         accumulator DSPs — the architectural argument for deep unfolding."
    );
}
