//! Bench: regenerate paper Table 5 (cross-accelerator comparison) and
//! measure the *actual* native-engine hot path on this host for contrast
//! (the simulator row reproduces the paper's Virtex-7 claim; the native
//! engine number is this machine's packed-popcount throughput).
//!
//! Run: `cargo bench --bench table5_throughput`

use std::time::Duration;

use repro::bcnn::Engine;
use repro::benchkit::{bench_with, fmt_ns, BenchOpts};
use repro::coordinator::workload::random_images;
use repro::model::{BcnnModel, NetConfig};
use repro::tables;

fn main() {
    println!("=== Table 5 (paper design point) ===");
    println!("{}", tables::table5(&tables::default_plan()));

    // measured: native engine on the full Table-2 network
    let model =
        BcnnModel::load("artifacts/model_table2.bcnn").expect("run `make artifacts` first");
    let engine = Engine::new(model).expect("valid model");
    let cfg = NetConfig::table2();
    let images = random_images(&cfg, 4, 3);
    let mut idx = 0usize;
    let mut scratch = repro::bcnn::engine::Scratch::default();
    let stats = bench_with(
        BenchOpts {
            warmup: Duration::from_millis(300),
            samples: 10,
            min_batch_time: Duration::from_millis(50),
            budget: Duration::from_secs(20),
        },
        &mut || {
            let img = &images[idx % images.len()];
            idx += 1;
            std::hint::black_box(engine.infer_with_scratch(img, &mut scratch).unwrap());
        },
    );
    let ops = cfg.ops_per_image() as f64;
    let fps = stats.per_second();
    println!("native engine on this host (single core), Table-2 network:");
    println!("  per image : median {}", fmt_ns(stats.median_ns));
    println!("  throughput: {fps:.1} img/s");
    println!("  effective : {:.1} GOPS (binary-op accounting)", ops * fps / 1e9);
    println!(
        "  note: paper FPGA = 7663 GOPS @ 8.2 W; this host's engine is the\n\
         functional model / serving hot path, not the accelerator claim"
    );
}
