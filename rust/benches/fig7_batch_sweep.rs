//! Bench: regenerate Fig. 7 — FPGA vs GPU throughput and energy
//! efficiency across batch sizes — from the models, then validate the
//! *serving-path* version: drive the coordinator with both simulator
//! backends and compare modeled per-batch device times.
//!
//! Run: `cargo bench --bench fig7_batch_sweep`

use repro::benchkit::Table;
use repro::coordinator::workload::random_images;
use repro::coordinator::{Backend, FpgaSimBackend, GpuSimBackend};
use repro::gpu::GpuKernel;
use repro::model::BcnnModel;
use repro::tables;

fn main() {
    let batches = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    println!("=== Fig. 7 (analytic models, Table-2 network) ===");
    println!("{}", tables::fig7(&tables::default_plan(), &batches));

    // serving-path version on the tiny config (full functional numerics):
    // per-batch modeled device time from each simulator backend.
    let model =
        BcnnModel::load("artifacts/model_tiny.bcnn").expect("run `make artifacts` first");
    let mut fpga = FpgaSimBackend::new(model.clone()).expect("fpga backend");
    let mut gpu = GpuSimBackend::new(model.clone(), GpuKernel::Xnor);
    let cfg = model.config();

    println!("=== serving path (tiny config, modeled device time per batch) ===");
    let mut t = Table::new(&[
        "batch",
        "FPGA-sim ms",
        "GPU-sim ms",
        "FPGA img/s",
        "GPU img/s",
        "FPGA/GPU",
    ]);
    for &b in &[1usize, 4, 16, 64, 256] {
        let images = random_images(&cfg, b, 9);
        let f = fpga
            .infer_batch(&images)
            .unwrap()
            .modeled_device_time
            .unwrap()
            .as_secs_f64();
        let g = gpu
            .infer_batch(&images)
            .unwrap()
            .modeled_device_time
            .unwrap()
            .as_secs_f64();
        t.row(&[
            b.to_string(),
            format!("{:.3}", f * 1e3),
            format!("{:.3}", g * 1e3),
            format!("{:.0}", b as f64 / f),
            format!("{:.0}", b as f64 / g),
            format!("{:.2}", g / f),
        ]);
    }
    t.print();
    println!(
        "\nshape check: the FPGA column's img/s saturates immediately (batch-\n\
         insensitive streaming); the GPU column needs large batches to catch up."
    );
}
