//! Bench: regenerate Fig. 7 — FPGA vs GPU throughput and energy
//! efficiency across batch sizes — from the models, then validate the
//! *serving-path* version: drive the coordinator with both simulator
//! backends and compare modeled per-batch device times.  Sweep the
//! sharded pool's worker count to show HOST-side throughput now scales the
//! way the paper says the accelerator does (the old single-worker
//! coordinator collapsed exactly where Fig. 7 says it should not).
//! Finally, the *executed* (not modeled) batch-insensitivity signature:
//! wall-clock throughput of the row-streaming pipeline runtime vs the
//! sequential engine across batch sizes, emitted to
//! `rust/BENCH_pipeline.json`.
//!
//! Run: `cargo bench --bench fig7_batch_sweep`
//! (CI runs a shortened pass with `BENCH_SMOKE=1`.)

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::bcnn::Engine;
use repro::benchkit::{envelope, write_bench_json, Json, Table};
use repro::coordinator::workload::{random_images, run_closed_loop};
use repro::coordinator::{
    Backend, BackendFactory, BatchPolicy, Coordinator, CoordinatorConfig, FpgaSimBackend,
    GpuSimBackend, NativeBackend,
};
use repro::gpu::GpuKernel;
use repro::model::{BcnnModel, ConvSpec, NetConfig};
use repro::pipeline::{PipelineRuntime, ScoreTicket, StagePlan, StageSnapshot};
use repro::tables;

fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

fn main() {
    let batches = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    println!("=== Fig. 7 (analytic models, Table-2 network) ===");
    println!("{}", tables::fig7(&tables::default_plan(), &batches));

    // serving-path version on the tiny config (full functional numerics):
    // per-batch modeled device time from each simulator backend.
    let model = BcnnModel::load_or_synthetic("tiny", "artifacts", 0xB_C0DE)
        .expect("built-in config");
    let mut fpga = FpgaSimBackend::new(model.clone()).expect("fpga backend");
    let mut gpu = GpuSimBackend::new(model.clone(), GpuKernel::Xnor).expect("valid model");
    let cfg = model.config();

    println!("=== serving path (tiny config, modeled device time per batch) ===");
    let mut t = Table::new(&[
        "batch",
        "FPGA-sim ms",
        "GPU-sim ms",
        "FPGA img/s",
        "GPU img/s",
        "FPGA/GPU",
    ]);
    for &b in &[1usize, 4, 16, 64, 256] {
        let images = random_images(&cfg, b, 9);
        let f = fpga
            .infer_owned(&images)
            .unwrap()
            .modeled_device_time
            .unwrap()
            .as_secs_f64();
        let g = gpu
            .infer_owned(&images)
            .unwrap()
            .modeled_device_time
            .unwrap()
            .as_secs_f64();
        t.row(&[
            b.to_string(),
            format!("{:.3}", f * 1e3),
            format!("{:.3}", g * 1e3),
            format!("{:.0}", b as f64 / f),
            format!("{:.0}", b as f64 / g),
            format!("{:.2}", g / f),
        ]);
    }
    t.print();
    println!(
        "\nshape check: the FPGA column's img/s saturates immediately (batch-\n\
         insensitive streaming); the GPU column needs large batches to catch up."
    );

    // --- host-side scaling: sharded worker pool, online regime ---------
    //
    // max_wait = 0 (pure online: batch = whatever is queued) on the native
    // backend; requests fan across N worker shards, each owning an engine
    // replica.  Throughput should scale with the shard count until cores
    // run out — this is the host mirroring the accelerator's spatial
    // parallelism.
    let requests: usize = if smoke() { 64 } else { 512 };
    println!("\n=== host throughput vs worker shards (native, max_wait=0) ===");
    let mut t = Table::new(&["workers", "req/s", "vs 1 worker", "mean batch", "per-shard reqs"]);
    let mut base = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let m = model.clone();
        let factory: BackendFactory = Arc::new(move || -> anyhow::Result<Box<dyn Backend>> {
            Ok(Box::new(NativeBackend::new(m.clone())?))
        });
        let coord = Coordinator::start_sharded(
            factory,
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 16, max_wait: Duration::ZERO },
                workers,
                queue_depth: 64,
                ..Default::default()
            },
        )
        .expect("start pool");
        let report = run_closed_loop(&coord.client(), &cfg, requests, 17).expect("workload");
        let per_shard: Vec<u64> = coord.shard_metrics().iter().map(|m| m.requests).collect();
        coord.shutdown();
        let rps = report.throughput();
        if workers == 1 {
            base = rps;
        }
        t.row(&[
            workers.to_string(),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / base.max(1e-9)),
            format!("{:.1}", report.mean_batch()),
            format!("{per_shard:?}"),
        ]);
    }
    t.print();
    println!(
        "\nreading: the single-worker coordinator serialized every request;\n\
         sharding restores the batch-insensitive scaling the FPGA datapath\n\
         promises (expect ~Nx until physical cores saturate)."
    );

    // --- executed batch-insensitivity: pipeline runtime vs engine -------
    //
    // The sections above *model* Fig. 7; this one executes it.  A backlog
    // of images is handed to each backend in groups of `batch`:
    //
    // * engine — `NativeBackend` with one intra-batch lane per pipeline
    //   thread (fair thread budget); each group is a blocking
    //   `infer_batch` call, so a group of 1 can use only one lane — the
    //   GPU-style "parallelism comes from batching" regime.
    // * pipeline — groups are submitted to the layer-pipeline runtime
    //   back-to-back (admission window = inflight); every layer stage is
    //   its own thread, so a stream of single-image groups keeps all
    //   stages busy and grouping stops mattering — eq. 12 executed.
    //
    // BENCH_pipeline.json records both curves and the batch-1 : batch-64
    // throughput ratio per backend (the batch-insensitivity signature:
    // ~1.0 for the pipeline, well below 1.0 for the laned engine).
    let total = if smoke() { 64usize } else { 256 };
    let sweep = [1usize, 4, 16, 64];
    let images = random_images(&cfg, total, 23);
    let n_stage_threads = model.layers.len() + 1;
    let inflight = 2 * n_stage_threads;

    println!(
        "\n=== executed batch sweep (tiny config, {total} images, \
         {n_stage_threads} threads per backend) ==="
    );
    let mut t = Table::new(&["batch", "engine img/s", "pipeline img/s", "pipeline/engine"]);
    let mut engine_rows: Vec<Json> = Vec::new();
    let mut pipeline_rows: Vec<Json> = Vec::new();
    let mut engine_tput = Vec::new();
    let mut pipeline_tput = Vec::new();
    for &batch in &sweep {
        let e = engine_throughput(&model, &images, batch, n_stage_threads);
        let p = pipeline_throughput(&model, &images, batch, inflight);
        engine_tput.push(e);
        pipeline_tput.push(p);
        engine_rows.push(sweep_row(batch, e));
        pipeline_rows.push(sweep_row(batch, p));
        t.row(&[
            batch.to_string(),
            format!("{e:.0}"),
            format!("{p:.0}"),
            format!("{:.2}", p / e),
        ]);
    }
    t.print();
    let engine_ratio = engine_tput[0] / engine_tput[sweep.len() - 1];
    let pipeline_ratio = pipeline_tput[0] / pipeline_tput[sweep.len() - 1];
    println!(
        "\nbatch-1 : batch-{} throughput — engine {:.2}, pipeline {:.2}\n\
         (batch-insensitive serving keeps the pipeline ratio near 1.0; the\n\
         laned engine needs large batches to light up its threads)",
        sweep[sweep.len() - 1],
        engine_ratio,
        pipeline_ratio,
    );

    // --- stage balance: plan-driven lane parallelism vs 1 lane/stage ----
    //
    // The paper reaches eq. 12's fps only by giving each layer its own P
    // until the stage cycle counts equalize (§4.3, Table 3).  Executed
    // here: a synthetic model with a deliberately skewed bottleneck layer
    // (conv2 carries ~10x the work of its neighbours), streamed through
    // (a) the unbalanced one-lane-per-stage pipeline, whose throughput is
    // pinned to the skewed stage, and (b) a calibrated StagePlan that
    // water-fills the spare lane budget onto that stage.  The per-stage
    // busy/stall counters land in the JSON, so the bottleneck is visible
    // (stage 1 busy, neighbours FIFO-stalled) rather than inferred.
    let skew_cfg = NetConfig {
        name: "skewed".into(),
        conv: vec![
            ConvSpec { out_channels: 8, pool: false },
            ConvSpec { out_channels: 256, pool: false },
        ],
        fc: vec![],
        classes: 10,
        input_hw: 8,
        input_channels: 3,
        input_bits: 6,
    };
    let skew_model = BcnnModel::synthetic(&skew_cfg, 0x5EED);
    let skew_total = if smoke() { 96usize } else { 384 };
    let skew_images = random_images(&skew_cfg, skew_total, 31);
    let n_layers = skew_model.layers.len();
    let skew_inflight = 2 * (n_layers + 1);
    // budget: every stage keeps one lane; the spare lanes all belong to
    // the bottleneck under water-filling
    let budget = n_layers + 3;

    let unbalanced =
        PipelineRuntime::new(Engine::new(skew_model.clone()).expect("valid model"), skew_inflight)
            .expect("spawn unbalanced pipeline");
    let unbal_tput = runtime_throughput(&unbalanced, &skew_images, skew_inflight);
    let unbal_lanes = unbalanced.plan().lanes_per_layer.clone();
    let unbal_stages = unbalanced.stage_stats();
    drop(unbalanced);

    let engine = Engine::new(skew_model.clone()).expect("valid model");
    let plan = StagePlan::balanced(&engine, budget).expect("calibration");
    let balanced = PipelineRuntime::with_plan(engine, skew_inflight, plan)
        .expect("spawn balanced pipeline");
    let bal_tput = runtime_throughput(&balanced, &skew_images, skew_inflight);
    let bal_lanes = balanced.plan().lanes_per_layer.clone();
    let bal_stages = balanced.stage_stats();
    drop(balanced);

    let balance_ratio = bal_tput / unbal_tput;
    println!(
        "\n=== stage balance (skewed model: conv 3->8, conv 8->256, fc 10; \
         {skew_total} images) ===\n\
         unbalanced lanes {unbal_lanes:?}: {unbal_tput:.0} img/s\n\
         balanced   lanes {bal_lanes:?}: {bal_tput:.0} img/s\n\
         balanced/unbalanced = {balance_ratio:.2}x \
         (acceptance target >= 1.5x on a multi-core host)"
    );
    let mut t = Table::new(&["stage", "lanes", "busy ms", "stall-in ms", "stall-out ms", "rows"]);
    for s in &bal_stages {
        t.row(&[
            s.layer.to_string(),
            s.lanes.to_string(),
            format!("{:.1}", s.busy.as_secs_f64() * 1e3),
            format!("{:.1}", s.stall_in.as_secs_f64() * 1e3),
            format!("{:.1}", s.stall_out.as_secs_f64() * 1e3),
            s.rows_in.to_string(),
        ]);
    }
    t.print();

    let mut fields = envelope("pipeline_batch_sweep", "tiny+skewed;executed-sweep");
    fields.extend(vec![
        ("smoke".into(), Json::Bool(smoke())),
        ("config".into(), Json::Str("tiny".into())),
        ("images".into(), Json::Num(total as f64)),
        ("threads_per_backend".into(), Json::Num(n_stage_threads as f64)),
        ("engine".into(), Json::Arr(engine_rows)),
        ("pipeline".into(), Json::Arr(pipeline_rows)),
        ("engine_batch1_over_batch64".into(), Json::Num(engine_ratio)),
        ("pipeline_batch1_over_batch64".into(), Json::Num(pipeline_ratio)),
        (
            "stage_balance".into(),
            Json::Obj(vec![
                (
                    "config".into(),
                    Json::Str("skewed: conv 3->8, conv 8->256 (bottleneck), fc 10".into()),
                ),
                ("images".into(), Json::Num(skew_total as f64)),
                ("lane_budget".into(), Json::Num(budget as f64)),
                ("lanes_unbalanced".into(), lanes_json(&unbal_lanes)),
                ("lanes_balanced".into(), lanes_json(&bal_lanes)),
                ("unbalanced_img_per_s".into(), Json::Num(unbal_tput)),
                ("balanced_img_per_s".into(), Json::Num(bal_tput)),
                ("balanced_over_unbalanced".into(), Json::Num(balance_ratio)),
                // the acceptance bar, recorded (not CI-gated: wall-clock
                // ratios on shared runners are advisory; the skew leaves
                // ~4x of headroom above the 1.5x target)
                ("meets_1p5x_target".into(), Json::Bool(balance_ratio >= 1.5)),
                ("stages_unbalanced".into(), stages_json(&unbal_stages)),
                ("stages_balanced".into(), stages_json(&bal_stages)),
            ]),
        ),
    ]);
    let json = Json::Obj(fields);
    write_bench_json("BENCH_pipeline.json", &json).expect("write BENCH_pipeline.json");
    println!("\nwrote BENCH_pipeline.json (smoke={})", smoke());
}

fn lanes_json(lanes: &[usize]) -> Json {
    Json::Arr(lanes.iter().map(|&l| Json::Num(l as f64)).collect())
}

/// Per-stage busy/stall counters as JSON (the observability satellite:
/// the bottleneck stage is the one with high busy while its neighbours
/// stall on FIFO waits).
fn stages_json(stages: &[StageSnapshot]) -> Json {
    Json::Arr(
        stages
            .iter()
            .map(|s| {
                Json::Obj(vec![
                    ("layer".into(), Json::Num(s.layer as f64)),
                    ("lanes".into(), Json::Num(s.lanes as f64)),
                    ("busy_ms".into(), Json::Num(s.busy.as_secs_f64() * 1e3)),
                    ("stall_in_ms".into(), Json::Num(s.stall_in.as_secs_f64() * 1e3)),
                    ("stall_out_ms".into(), Json::Num(s.stall_out.as_secs_f64() * 1e3)),
                    ("rows_in".into(), Json::Num(s.rows_in as f64)),
                    ("images".into(), Json::Num(s.images as f64)),
                ])
            })
            .collect(),
    )
}

/// Steady-state wall-clock throughput of an already-spawned runtime over
/// the backlog: warm one admission window through the stages, then stream
/// every image back-to-back with at most `inflight` tickets outstanding.
fn runtime_throughput(runtime: &PipelineRuntime, images: &[Vec<i32>], inflight: usize) -> f64 {
    let warm: Vec<ScoreTicket> = images
        .iter()
        .take(inflight.min(images.len()))
        .map(|img| runtime.submit(img.clone()).expect("submit"))
        .collect();
    for ticket in warm {
        ticket.wait().expect("warm-up scores");
    }
    let t0 = Instant::now();
    let mut outstanding: VecDeque<ScoreTicket> = VecDeque::new();
    for img in images {
        while outstanding.len() >= inflight {
            outstanding.pop_front().unwrap().wait().expect("scores");
        }
        outstanding.push_back(runtime.submit(img.clone()).expect("submit"));
    }
    while let Some(ticket) = outstanding.pop_front() {
        ticket.wait().expect("scores");
    }
    images.len() as f64 / t0.elapsed().as_secs_f64()
}

fn sweep_row(batch: usize, img_per_s: f64) -> Json {
    Json::Obj(vec![
        ("batch".into(), Json::Num(batch as f64)),
        ("img_per_s".into(), Json::Num(img_per_s)),
    ])
}

/// Wall-clock throughput of the sequential engine given the backlog in
/// groups of `batch`: one blocking `infer_batch` per group, `lanes`
/// intra-batch threads (the batching-dependent parallelism regime).
fn engine_throughput(model: &BcnnModel, images: &[Vec<i32>], batch: usize, lanes: usize) -> f64 {
    let mut backend = NativeBackend::with_lanes(model.clone(), lanes).expect("valid model");
    // warm the per-lane scratch arenas before timing
    backend
        .infer_owned(&images[..batch.min(images.len())])
        .expect("warm-up");
    let t0 = Instant::now();
    for chunk in images.chunks(batch) {
        backend.infer_owned(chunk).expect("engine batch");
    }
    images.len() as f64 / t0.elapsed().as_secs_f64()
}

/// Wall-clock throughput of the layer-pipeline runtime given the backlog
/// in groups of `batch`: groups are submitted back-to-back (the backlog
/// exists, so the host never idles the device between groups), with at
/// most `inflight` tickets outstanding.
fn pipeline_throughput(
    model: &BcnnModel,
    images: &[Vec<i32>],
    batch: usize,
    inflight: usize,
) -> f64 {
    let runtime = PipelineRuntime::new(Engine::new(model.clone()).expect("valid model"), inflight)
        .expect("spawn pipeline");
    // warm-up: stream one window through the stages before timing
    let warm: Vec<ScoreTicket> = images
        .iter()
        .take(inflight.min(images.len()))
        .map(|img| runtime.submit(img.clone()).expect("submit"))
        .collect();
    for ticket in warm {
        ticket.wait().expect("warm-up scores");
    }
    let t0 = Instant::now();
    let mut outstanding: VecDeque<ScoreTicket> = VecDeque::new();
    for chunk in images.chunks(batch) {
        for img in chunk {
            while outstanding.len() >= inflight {
                outstanding.pop_front().unwrap().wait().expect("scores");
            }
            outstanding.push_back(runtime.submit(img.clone()).expect("submit"));
        }
    }
    while let Some(ticket) = outstanding.pop_front() {
        ticket.wait().expect("scores");
    }
    images.len() as f64 / t0.elapsed().as_secs_f64()
}
