//! Bench: regenerate Fig. 7 — FPGA vs GPU throughput and energy
//! efficiency across batch sizes — from the models, then validate the
//! *serving-path* version: drive the coordinator with both simulator
//! backends and compare modeled per-batch device times.  Finally sweep the
//! sharded pool's worker count to show HOST-side throughput now scales the
//! way the paper says the accelerator does (the old single-worker
//! coordinator collapsed exactly where Fig. 7 says it should not).
//!
//! Run: `cargo bench --bench fig7_batch_sweep`

use std::sync::Arc;
use std::time::Duration;

use repro::benchkit::Table;
use repro::coordinator::workload::{random_images, run_closed_loop};
use repro::coordinator::{
    Backend, BackendFactory, BatchPolicy, Coordinator, CoordinatorConfig, FpgaSimBackend,
    GpuSimBackend, NativeBackend,
};
use repro::gpu::GpuKernel;
use repro::model::BcnnModel;
use repro::tables;

fn main() {
    let batches = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    println!("=== Fig. 7 (analytic models, Table-2 network) ===");
    println!("{}", tables::fig7(&tables::default_plan(), &batches));

    // serving-path version on the tiny config (full functional numerics):
    // per-batch modeled device time from each simulator backend.
    let model = BcnnModel::load_or_synthetic("tiny", "artifacts", 0xB_C0DE)
        .expect("built-in config");
    let mut fpga = FpgaSimBackend::new(model.clone()).expect("fpga backend");
    let mut gpu = GpuSimBackend::new(model.clone(), GpuKernel::Xnor).expect("valid model");
    let cfg = model.config();

    println!("=== serving path (tiny config, modeled device time per batch) ===");
    let mut t = Table::new(&[
        "batch",
        "FPGA-sim ms",
        "GPU-sim ms",
        "FPGA img/s",
        "GPU img/s",
        "FPGA/GPU",
    ]);
    for &b in &[1usize, 4, 16, 64, 256] {
        let images = random_images(&cfg, b, 9);
        let f = fpga
            .infer_owned(&images)
            .unwrap()
            .modeled_device_time
            .unwrap()
            .as_secs_f64();
        let g = gpu
            .infer_owned(&images)
            .unwrap()
            .modeled_device_time
            .unwrap()
            .as_secs_f64();
        t.row(&[
            b.to_string(),
            format!("{:.3}", f * 1e3),
            format!("{:.3}", g * 1e3),
            format!("{:.0}", b as f64 / f),
            format!("{:.0}", b as f64 / g),
            format!("{:.2}", g / f),
        ]);
    }
    t.print();
    println!(
        "\nshape check: the FPGA column's img/s saturates immediately (batch-\n\
         insensitive streaming); the GPU column needs large batches to catch up."
    );

    // --- host-side scaling: sharded worker pool, online regime ---------
    //
    // max_wait = 0 (pure online: batch = whatever is queued) on the native
    // backend; requests fan across N worker shards, each owning an engine
    // replica.  Throughput should scale with the shard count until cores
    // run out — this is the host mirroring the accelerator's spatial
    // parallelism.
    const REQUESTS: usize = 512;
    println!("\n=== host throughput vs worker shards (native, max_wait=0) ===");
    let mut t = Table::new(&["workers", "req/s", "vs 1 worker", "mean batch", "per-shard reqs"]);
    let mut base = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let m = model.clone();
        let factory: BackendFactory = Arc::new(move || -> anyhow::Result<Box<dyn Backend>> {
            Ok(Box::new(NativeBackend::new(m.clone())?))
        });
        let coord = Coordinator::start_sharded(
            factory,
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 16, max_wait: Duration::ZERO },
                workers,
                queue_depth: 64,
            },
        )
        .expect("start pool");
        let report = run_closed_loop(&coord.client(), &cfg, REQUESTS, 17).expect("workload");
        let per_shard: Vec<u64> = coord.shard_metrics().iter().map(|m| m.requests).collect();
        coord.shutdown();
        let rps = report.throughput();
        if workers == 1 {
            base = rps;
        }
        t.row(&[
            workers.to_string(),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / base.max(1e-9)),
            format!("{:.1}", report.mean_batch()),
            format!("{per_shard:?}"),
        ]);
    }
    t.print();
    println!(
        "\nreading: the single-worker coordinator serialized every request;\n\
         sharding restores the batch-insensitive scaling the FPGA datapath\n\
         promises (expect ~Nx until physical cores saturate)."
    );
}
