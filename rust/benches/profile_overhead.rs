//! Bench: the profiler observer effect.  The work ledger is meant to be
//! always-on, so its bar mirrors the tracing one: arming `BCNN_PROFILE`
//! must cost less than 3% of serving throughput, and disarming it must
//! leave nothing but one relaxed load per image on the hot path.
//! Measured the same way as `obs_overhead`: the same closed-loop
//! workload through a pipeline-backed coordinator pool with the ledger
//! armed and disarmed in alternating rounds, comparing the best round
//! of each mode.  Tracing stays armed in BOTH modes so the only varying
//! knob is the profiler gate.  Results land in
//! `rust/BENCH_profile_overhead.json`; the run fails (nonzero exit) if
//! the overhead exceeds the budget.
//!
//! Run: `cargo bench --bench profile_overhead`
//! (CI runs a shortened pass with `BENCH_SMOKE=1`.)

use std::sync::Arc;
use std::time::Duration;

use repro::benchkit::{envelope, write_bench_json, Json, Table};
use repro::coordinator::workload::run_closed_loop;
use repro::coordinator::{Backend, BackendFactory, BatchPolicy, Coordinator, CoordinatorConfig};
use repro::model::BcnnModel;
use repro::obs;
use repro::pipeline::PipelineBackend;

fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

/// Closed-loop throughput of a fresh 2-shard pipeline-backed pool — the
/// configuration where the ledger fires most often (once per image per
/// pipeline stage lane).
fn throughput(model: &BcnnModel, requests: usize, seed: u64) -> f64 {
    let m = model.clone();
    let factory: BackendFactory = Arc::new(move || -> anyhow::Result<Box<dyn Backend>> {
        Ok(Box::new(PipelineBackend::new(m.clone(), 8)?))
    });
    let coord = Coordinator::start_sharded(
        factory,
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::ZERO },
            workers: 2,
            queue_depth: 64,
            ..Default::default()
        },
    )
    .expect("start pool");
    let cfg = model.config();
    // warm the stage threads and per-lane arenas outside the timed window
    run_closed_loop(&coord.client(), &cfg, requests / 4, seed ^ 1).expect("warm-up");
    let report = run_closed_loop(&coord.client(), &cfg, requests, seed).expect("workload");
    coord.shutdown();
    report.throughput()
}

fn main() {
    let model =
        BcnnModel::load_or_synthetic("tiny", "artifacts", 0xB_C0DE).expect("built-in config");
    let requests = if smoke() { 192usize } else { 1024 };
    let rounds = if smoke() { 2usize } else { 4 };

    // hold the tracing gate constant so the A/B isolates the profiler
    obs::set_enabled(true);

    let mut on_best = 0f64;
    let mut off_best = 0f64;
    let mut t = Table::new(&["round", "profiler", "req/s"]);
    for round in 0..rounds {
        for &on in &[true, false] {
            obs::set_profile_enabled(on);
            let rps = throughput(&model, requests, 0xFACE + round as u64);
            if on {
                on_best = on_best.max(rps);
            } else {
                off_best = off_best.max(rps);
            }
            let mode = if on { "on" } else { "off" };
            t.row(&[round.to_string(), mode.to_string(), format!("{rps:.0}")]);
        }
    }
    obs::set_profile_enabled(true); // leave the process default armed
    println!("=== profiler observer effect (tiny config, {requests} req/round) ===");
    t.print();

    let overhead_pct = (off_best - on_best) / off_best.max(1e-9) * 100.0;
    let pass = overhead_pct < 3.0;
    println!(
        "\nprofiler on {on_best:.0} req/s, off {off_best:.0} req/s -> \
         overhead {overhead_pct:.2}% (budget < 3%)"
    );

    let mut fields = envelope("profile_overhead", "tiny;pipeline-pool-w2");
    fields.extend(vec![
        ("smoke".into(), Json::Bool(smoke())),
        ("requests_per_round".into(), Json::Num(requests as f64)),
        ("rounds_per_mode".into(), Json::Num(rounds as f64)),
        ("on_rps".into(), Json::Num(on_best)),
        ("off_rps".into(), Json::Num(off_best)),
        ("overhead_pct".into(), Json::Num(overhead_pct)),
        ("threshold_pct".into(), Json::Num(3.0)),
        ("pass".into(), Json::Bool(pass)),
    ]);
    let json = Json::Obj(fields);
    write_bench_json("BENCH_profile_overhead.json", &json)
        .expect("write BENCH_profile_overhead.json");
    println!("wrote BENCH_profile_overhead.json (smoke={})", smoke());
    assert!(pass, "profiler overhead {overhead_pct:.2}% exceeds the 3% budget");
}
