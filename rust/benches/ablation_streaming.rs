//! Ablation: double-buffered streaming vs time-multiplexed execution.
//!
//! The paper attributes its throughput advantage over Ref. 21 to keeping
//! every layer's kernel active via double-buffered memory channels
//! (§4.3, §6.2).  This bench runs the phase simulator both ways on the
//! real models and reports the measured ratio against the analytic
//! sum(C)/max(C) bound.
//!
//! Run: `cargo bench --bench ablation_streaming`

use repro::bcnn::Engine;
use repro::benchkit::Table;
use repro::coordinator::workload::random_images;
use repro::fpga::stream::{simulate, StreamConfig};
use repro::fpga::timing::PipelineModel;
use repro::fpga::DEFAULT_FREQ_HZ;
use repro::model::BcnnModel;
use repro::optimizer::{optimize, paper_plan, OptimizeOptions};

fn main() {
    let mut t = Table::new(&[
        "config",
        "FPS streaming",
        "FPS time-mux",
        "measured ratio",
        "sum/max bound",
        "numerics",
    ]);

    for name in ["tiny", "small"] {
        let model = BcnnModel::load_or_synthetic(name, "artifacts", 0xB_C0DE)
            .expect("built-in config");
        let net = model.config();
        let plan = optimize(&net, &OptimizeOptions::default()).unwrap();
        let mut config = StreamConfig {
            freq_hz: DEFAULT_FREQ_HZ,
            params: plan.layers.iter().map(|l| l.params).collect(),
            pipeline: PipelineModel::default(),
            double_buffered: true,
        };
        let engine = Engine::new(model).expect("valid model");
        let images = random_images(&net, 8, 5);
        let on = simulate(&engine, &config, &images).unwrap();
        config.double_buffered = false;
        let off = simulate(&engine, &config, &images).unwrap();
        let sum: u64 = on.layer_cycles.iter().sum();
        let max: u64 = *on.layer_cycles.iter().max().unwrap();
        t.row(&[
            name.to_string(),
            format!("{:.0}", on.fps),
            format!("{:.0}", off.fps),
            format!("{:.2}x", on.fps / off.fps),
            format!("{:.2}x", sum as f64 / max as f64),
            if on.scores == off.scores { "identical".into() } else { "MISMATCH".into() },
        ]);
    }

    // table2: analytic only (cycle model, no functional run needed)
    let plan = paper_plan(&OptimizeOptions::default());
    let cycles: Vec<u64> = plan.layers.iter().map(|l| l.cycle_real).collect();
    let sum: u64 = cycles.iter().sum();
    let max: u64 = *cycles.iter().max().unwrap();
    t.row(&[
        "table2 (analytic)".into(),
        format!("{:.0}", DEFAULT_FREQ_HZ / max as f64),
        format!("{:.0}", DEFAULT_FREQ_HZ / sum as f64),
        format!("{:.2}x", sum as f64 / max as f64),
        format!("{:.2}x", sum as f64 / max as f64),
        "-".into(),
    ]);

    println!("=== streaming (double-buffered channels) ablation ===");
    t.print();
    println!(
        "\nreading: the streaming architecture's win equals sum(C_L)/max(C_L);\n\
         with the paper's balanced Cycle_est it approaches the layer count —\n\
         the §4.3 argument for equalizing per-layer execution time."
    );
}
