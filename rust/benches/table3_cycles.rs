//! Bench: regenerate paper Table 3 (optimized UF/P and per-layer cycles)
//! from the throughput model + optimizer, and time the optimizer search.
//!
//! Run: `cargo bench --bench table3_cycles`

use repro::benchkit::{bench, fmt_ns};
use repro::model::NetConfig;
use repro::optimizer::{optimize, OptimizeOptions};
use repro::tables;

fn main() {
    println!("=== Table 3 (paper design point, model columns) ===");
    println!("{}", tables::table3(&tables::default_plan()));

    println!("=== Table 3 (optimizer-derived plan) ===");
    let plan = tables::optimized_plan().expect("optimize table2");
    println!("{}", tables::table3(&plan));

    let stats = bench(|| {
        std::hint::black_box(
            optimize(&NetConfig::table2(), &OptimizeOptions::default()).unwrap(),
        );
    });
    println!(
        "optimizer search latency: median {} (p95 {}, n={})",
        fmt_ns(stats.median_ns),
        fmt_ns(stats.p95_ns),
        stats.iters
    );
}
