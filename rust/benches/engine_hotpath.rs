//! Bench: the native engine hot path — per-config image latency, per-layer
//! breakdown, and effective bit-op rate.  This is the §Perf workload
//! (EXPERIMENTS.md records before/after for each optimization step).
//!
//! Run: `cargo bench --bench engine_hotpath`

use std::time::Duration;

use repro::bcnn::{Engine, LayerOutput};
use repro::benchkit::{bench_with, fmt_ns, BenchOpts, Table};
use repro::coordinator::workload::random_images;
use repro::model::BcnnModel;

fn opts(ms: u64) -> BenchOpts {
    BenchOpts {
        warmup: Duration::from_millis(200),
        samples: 12,
        min_batch_time: Duration::from_millis(ms),
        budget: Duration::from_secs(15),
    }
}

fn main() {
    let mut t = Table::new(&["config", "ms/image", "img/s", "GOPS", "Gbitop/s"]);
    for name in ["tiny", "small", "table2"] {
        let model = BcnnModel::load_or_synthetic(name, "artifacts", 0xB_C0DE)
            .expect("built-in config");
        let cfg = model.config();
        let engine = Engine::new(model);
        let images = random_images(&cfg, 4, 11);
        let mut scratch = repro::bcnn::engine::Scratch::default();
        let mut idx = 0usize;
        let stats = bench_with(opts(30), &mut || {
            let img = &images[idx % images.len()];
            idx += 1;
            std::hint::black_box(engine.infer_with_scratch(img, &mut scratch).unwrap());
        });
        let fps = stats.per_second();
        let ops = cfg.ops_per_image() as f64;
        t.row(&[
            name.to_string(),
            format!("{:.3}", stats.median_ns / 1e6),
            format!("{fps:.1}"),
            format!("{:.2}", ops * fps / 1e9),
            format!("{:.2}", ops * fps / 2.0 / 1e9), // XNOR+acc pairs
        ]);
    }
    println!("=== native engine hot path (single core) ===");
    t.print();

    // per-layer breakdown on table2 (where the time goes)
    let model = BcnnModel::load_or_synthetic("table2", "artifacts", 0xB_C0DE).unwrap();
    let cfg = model.config();
    let engine = Engine::new(model);
    let img = random_images(&cfg, 1, 12).pop().unwrap();
    let n_layers = engine.model().layers.len();

    println!("\n=== per-layer breakdown (table2) ===");
    let mut t = Table::new(&["layer", "median", "share%"]);
    // capture inputs to each layer once (run_layer_at engages the
    // prepared-weight fast paths by index, as in real inference)
    let mut scratch = repro::bcnn::engine::Scratch::default();
    let mut acts = Vec::new();
    let mut act = repro::bcnn::Activation::Int {
        hw: cfg.input_hw,
        c: cfg.input_channels,
        data: img.clone(),
    };
    for i in 0..n_layers {
        acts.push(act.clone());
        match engine.run_layer_at(i, &act, &mut scratch).unwrap() {
            LayerOutput::Act(a) => act = a,
            LayerOutput::Scores(_) => break,
        }
    }
    let mut medians = Vec::new();
    for (i, input) in acts.iter().enumerate() {
        let stats = bench_with(opts(20), &mut || {
            std::hint::black_box(engine.run_layer_at(i, input, &mut scratch).unwrap());
        });
        medians.push(stats.median_ns);
    }
    let total: f64 = medians.iter().sum();
    for (i, m) in medians.iter().enumerate() {
        t.row(&[
            format!("layer {}", i + 1),
            fmt_ns(*m),
            format!("{:.1}", 100.0 * m / total),
        ]);
    }
    t.row(&["TOTAL".into(), fmt_ns(total), "100.0".into()]);
    t.print();
}
