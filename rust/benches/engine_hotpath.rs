//! Bench: the native engine hot path — per-config image latency, per-layer
//! breakdown, and effective bit-op rate.  This is the §Perf workload
//! (EXPERIMENTS.md records before/after for each optimization step).
//!
//! Emits `rust/BENCH_engine.json` (ns/image per layer + end-to-end; bench
//! binaries run with the package root as cwd) so the perf trajectory is
//! machine-readable and comparable across commits; CI runs a shortened
//! pass with `BENCH_SMOKE=1` to keep the artifact fresh.
//!
//! Run: `cargo bench --bench engine_hotpath`

use std::time::Duration;

use repro::bcnn::{Engine, LayerOutput, Scratch};
use repro::benchkit::{bench_with, envelope, fmt_ns, write_bench_json, BenchOpts, Json, Table};
use repro::coordinator::workload::random_images;
use repro::model::BcnnModel;
use repro::util::kernels::{Kernel, KernelKind};

fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

fn opts(ms: u64) -> BenchOpts {
    if smoke() {
        return BenchOpts {
            warmup: Duration::from_millis(10),
            samples: 3,
            min_batch_time: Duration::from_millis(1),
            budget: Duration::from_secs(1),
        };
    }
    BenchOpts {
        warmup: Duration::from_millis(200),
        samples: 12,
        min_batch_time: Duration::from_millis(ms),
        budget: Duration::from_secs(15),
    }
}

fn main() {
    let mut t = Table::new(&["config", "ms/image", "img/s", "GOPS", "Gbitop/s"]);
    let mut e2e_rows: Vec<Json> = Vec::new();
    for name in ["tiny", "small", "table2"] {
        let model = BcnnModel::load_or_synthetic(name, "artifacts", 0xB_C0DE)
            .expect("built-in config");
        let cfg = model.config();
        let engine = Engine::new(model).expect("valid model");
        let images = random_images(&cfg, 4, 11);
        let mut scratch = Scratch::default();
        let mut idx = 0usize;
        let stats = bench_with(opts(30), &mut || {
            let img = &images[idx % images.len()];
            idx += 1;
            std::hint::black_box(engine.infer_with_scratch(img, &mut scratch).unwrap());
        });
        let fps = stats.per_second();
        let ops = cfg.ops_per_image() as f64;
        t.row(&[
            name.to_string(),
            format!("{:.3}", stats.median_ns / 1e6),
            format!("{fps:.1}"),
            format!("{:.2}", ops * fps / 1e9),
            format!("{:.2}", ops * fps / 2.0 / 1e9), // XNOR+acc pairs
        ]);
        e2e_rows.push(Json::Obj(vec![
            ("config".into(), Json::Str(name.into())),
            ("median_ns_per_image".into(), Json::Num(stats.median_ns)),
            ("img_per_s".into(), Json::Num(fps)),
            ("gops".into(), Json::Num(ops * fps / 1e9)),
        ]));
    }
    println!("=== native engine hot path (single core) ===");
    t.print();

    // per-layer breakdown on table2 (where the time goes)
    let model = BcnnModel::load_or_synthetic("table2", "artifacts", 0xB_C0DE).unwrap();
    let cfg = model.config();
    let engine = Engine::new(model).expect("valid model");
    let img = random_images(&cfg, 1, 12).pop().unwrap();
    let n_layers = engine.model().layers.len();

    println!("\n=== per-layer breakdown (table2) ===");
    let mut t = Table::new(&["layer", "median", "share%"]);
    // capture inputs to each layer once (run_layer_at engages the
    // prepared tap-major banks by index, as in real inference)
    let mut scratch = Scratch::default();
    let mut acts = Vec::new();
    let mut act = repro::bcnn::Activation::Int {
        hw: cfg.input_hw,
        c: cfg.input_channels,
        data: img.clone(),
    };
    for i in 0..n_layers {
        acts.push(act.clone());
        match engine.run_layer_at(i, &act, &mut scratch).unwrap() {
            LayerOutput::Act(a) => act = a,
            LayerOutput::Scores(_) => break,
        }
    }
    let mut medians = Vec::new();
    for (i, input) in acts.iter().enumerate() {
        let stats = bench_with(opts(20), &mut || {
            std::hint::black_box(engine.run_layer_at(i, input, &mut scratch).unwrap());
        });
        medians.push(stats.median_ns);
    }
    let total: f64 = medians.iter().sum();
    let mut layer_rows: Vec<Json> = Vec::new();
    for (i, m) in medians.iter().enumerate() {
        t.row(&[
            format!("layer {}", i + 1),
            fmt_ns(*m),
            format!("{:.1}", 100.0 * m / total),
        ]);
        layer_rows.push(Json::Obj(vec![
            ("layer".into(), Json::Num((i + 1) as f64)),
            ("median_ns".into(), Json::Num(*m)),
            ("share_pct".into(), Json::Num(100.0 * m / total)),
        ]));
    }
    t.row(&["TOTAL".into(), fmt_ns(total), "100.0".into()]);
    t.print();

    // per-kernel A/B on table2: every ISA tier the host can run, pinned
    // via Engine::with_kernel, against the same prepared inputs — scalar
    // is the baseline the speedup column divides by.  This is the SIMD
    // scoreboard EXPERIMENTS.md §Perf iter 7 points at.
    println!("\n=== per-kernel per-layer (table2, dispatched = {}) ===", engine.kernel());
    let mut kernel_rows: Vec<Json> = Vec::new();
    let mut scalar_total: Option<f64> = None;
    let mut t = Table::new(&["kernel", "e2e ns/image", "layer-sum ns", "speedup vs scalar"]);
    for kind in KernelKind::ALL {
        if !kind.available() {
            println!("(skipping {kind}: unavailable on this host/toolchain)");
            continue;
        }
        let model = BcnnModel::load_or_synthetic("table2", "artifacts", 0xB_C0DE).unwrap();
        let kernel = Kernel::force(kind).expect("availability checked above");
        let engine = Engine::with_kernel(model, kernel).expect("valid model");
        let mut scratch = Scratch::default();
        let e2e = bench_with(opts(20), &mut || {
            std::hint::black_box(engine.infer_with_scratch(&img, &mut scratch).unwrap());
        });
        let mut layers: Vec<Json> = Vec::new();
        let mut layer_sum = 0.0;
        for (i, input) in acts.iter().enumerate() {
            let stats = bench_with(opts(10), &mut || {
                std::hint::black_box(engine.run_layer_at(i, input, &mut scratch).unwrap());
            });
            layer_sum += stats.median_ns;
            layers.push(Json::Obj(vec![
                ("layer".into(), Json::Num((i + 1) as f64)),
                ("median_ns".into(), Json::Num(stats.median_ns)),
            ]));
        }
        if kind == KernelKind::Scalar {
            scalar_total = Some(e2e.median_ns);
        }
        let speedup = scalar_total.map(|s| s / e2e.median_ns);
        t.row(&[
            kind.name().to_string(),
            format!("{:.0}", e2e.median_ns),
            format!("{layer_sum:.0}"),
            speedup.map_or("n/a".into(), |s| format!("{s:.2}x")),
        ]);
        kernel_rows.push(Json::Obj(vec![
            ("name".into(), Json::Str(kind.name().into())),
            ("end_to_end_ns_per_image".into(), Json::Num(e2e.median_ns)),
            ("per_layer".into(), Json::Arr(layers)),
            ("layer_sum_ns".into(), Json::Num(layer_sum)),
            (
                "speedup_vs_scalar".into(),
                speedup.map_or(Json::Null, Json::Num),
            ),
        ]));
    }
    t.print();

    let mut fields = envelope("engine_hotpath", "tiny+small+table2;single-core");
    fields.extend(vec![
        ("smoke".into(), Json::Bool(smoke())),
        ("kernel".into(), Json::Str(Kernel::from_env().map_or("invalid", Kernel::name).into())),
        ("end_to_end".into(), Json::Arr(e2e_rows)),
        (
            "per_layer".into(),
            Json::Obj(vec![
                ("config".into(), Json::Str("table2".into())),
                ("layers".into(), Json::Arr(layer_rows)),
                ("total_ns_per_image".into(), Json::Num(total)),
            ]),
        ),
        ("kernels".into(), Json::Arr(kernel_rows)),
    ]);
    let json = Json::Obj(fields);
    write_bench_json("BENCH_engine.json", &json).expect("write BENCH_engine.json");
    println!("\nwrote BENCH_engine.json (smoke={})", smoke());
}
