//! Bench: the native engine hot path — per-config image latency, per-layer
//! breakdown, and effective bit-op rate.  This is the §Perf workload
//! (EXPERIMENTS.md records before/after for each optimization step).
//!
//! Emits `rust/BENCH_engine.json` (ns/image per layer + end-to-end; bench
//! binaries run with the package root as cwd) so the perf trajectory is
//! machine-readable and comparable across commits; CI runs a shortened
//! pass with `BENCH_SMOKE=1` to keep the artifact fresh.
//!
//! Run: `cargo bench --bench engine_hotpath`

use std::time::Duration;

use repro::bcnn::{Engine, LayerOutput, Scratch};
use repro::benchkit::{bench_with, fmt_ns, write_bench_json, BenchOpts, Json, Table};
use repro::coordinator::workload::random_images;
use repro::model::BcnnModel;

fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

fn opts(ms: u64) -> BenchOpts {
    if smoke() {
        return BenchOpts {
            warmup: Duration::from_millis(10),
            samples: 3,
            min_batch_time: Duration::from_millis(1),
            budget: Duration::from_secs(1),
        };
    }
    BenchOpts {
        warmup: Duration::from_millis(200),
        samples: 12,
        min_batch_time: Duration::from_millis(ms),
        budget: Duration::from_secs(15),
    }
}

fn main() {
    let mut t = Table::new(&["config", "ms/image", "img/s", "GOPS", "Gbitop/s"]);
    let mut e2e_rows: Vec<Json> = Vec::new();
    for name in ["tiny", "small", "table2"] {
        let model = BcnnModel::load_or_synthetic(name, "artifacts", 0xB_C0DE)
            .expect("built-in config");
        let cfg = model.config();
        let engine = Engine::new(model).expect("valid model");
        let images = random_images(&cfg, 4, 11);
        let mut scratch = Scratch::default();
        let mut idx = 0usize;
        let stats = bench_with(opts(30), &mut || {
            let img = &images[idx % images.len()];
            idx += 1;
            std::hint::black_box(engine.infer_with_scratch(img, &mut scratch).unwrap());
        });
        let fps = stats.per_second();
        let ops = cfg.ops_per_image() as f64;
        t.row(&[
            name.to_string(),
            format!("{:.3}", stats.median_ns / 1e6),
            format!("{fps:.1}"),
            format!("{:.2}", ops * fps / 1e9),
            format!("{:.2}", ops * fps / 2.0 / 1e9), // XNOR+acc pairs
        ]);
        e2e_rows.push(Json::Obj(vec![
            ("config".into(), Json::Str(name.into())),
            ("median_ns_per_image".into(), Json::Num(stats.median_ns)),
            ("img_per_s".into(), Json::Num(fps)),
            ("gops".into(), Json::Num(ops * fps / 1e9)),
        ]));
    }
    println!("=== native engine hot path (single core) ===");
    t.print();

    // per-layer breakdown on table2 (where the time goes)
    let model = BcnnModel::load_or_synthetic("table2", "artifacts", 0xB_C0DE).unwrap();
    let cfg = model.config();
    let engine = Engine::new(model).expect("valid model");
    let img = random_images(&cfg, 1, 12).pop().unwrap();
    let n_layers = engine.model().layers.len();

    println!("\n=== per-layer breakdown (table2) ===");
    let mut t = Table::new(&["layer", "median", "share%"]);
    // capture inputs to each layer once (run_layer_at engages the
    // prepared tap-major banks by index, as in real inference)
    let mut scratch = Scratch::default();
    let mut acts = Vec::new();
    let mut act = repro::bcnn::Activation::Int {
        hw: cfg.input_hw,
        c: cfg.input_channels,
        data: img.clone(),
    };
    for i in 0..n_layers {
        acts.push(act.clone());
        match engine.run_layer_at(i, &act, &mut scratch).unwrap() {
            LayerOutput::Act(a) => act = a,
            LayerOutput::Scores(_) => break,
        }
    }
    let mut medians = Vec::new();
    for (i, input) in acts.iter().enumerate() {
        let stats = bench_with(opts(20), &mut || {
            std::hint::black_box(engine.run_layer_at(i, input, &mut scratch).unwrap());
        });
        medians.push(stats.median_ns);
    }
    let total: f64 = medians.iter().sum();
    let mut layer_rows: Vec<Json> = Vec::new();
    for (i, m) in medians.iter().enumerate() {
        t.row(&[
            format!("layer {}", i + 1),
            fmt_ns(*m),
            format!("{:.1}", 100.0 * m / total),
        ]);
        layer_rows.push(Json::Obj(vec![
            ("layer".into(), Json::Num((i + 1) as f64)),
            ("median_ns".into(), Json::Num(*m)),
            ("share_pct".into(), Json::Num(100.0 * m / total)),
        ]));
    }
    t.row(&["TOTAL".into(), fmt_ns(total), "100.0".into()]);
    t.print();

    let json = Json::Obj(vec![
        ("bench".into(), Json::Str("engine_hotpath".into())),
        ("smoke".into(), Json::Bool(smoke())),
        ("end_to_end".into(), Json::Arr(e2e_rows)),
        (
            "per_layer".into(),
            Json::Obj(vec![
                ("config".into(), Json::Str("table2".into())),
                ("layers".into(), Json::Arr(layer_rows)),
                ("total_ns_per_image".into(), Json::Num(total)),
            ]),
        ),
    ]);
    write_bench_json("BENCH_engine.json", &json).expect("write BENCH_engine.json");
    println!("\nwrote BENCH_engine.json (smoke={})", smoke());
}
