//! Bench: regenerate paper Table 4 (resource utilization) from the
//! resource model, for both the paper's design point and the optimizer's,
//! plus the power-model breakdown behind Table 5's 8.2 W.
//!
//! Run: `cargo bench --bench table4_resources`

use repro::benchkit::Table;
use repro::fpga::power::power;
use repro::fpga::DEFAULT_FREQ_HZ;
use repro::tables;

fn main() {
    println!("=== Table 4 (paper design point) ===");
    let plan = tables::default_plan();
    println!("{}", tables::table4(&plan));

    println!("=== Table 4 (optimizer-derived plan) ===");
    let opt = tables::optimized_plan().expect("optimize");
    println!("{}", tables::table4(&opt));

    // per-layer breakdown (not in the paper; model introspection)
    println!("=== per-layer resource breakdown (paper design point) ===");
    let mut t = Table::new(&["layer", "LUTs", "BRAMs", "registers", "DSPs"]);
    for (l, r) in plan.layers.iter().zip(&plan.resources.per_layer) {
        t.row(&[
            l.geom.name.clone(),
            r.luts.to_string(),
            r.brams.to_string(),
            r.registers.to_string(),
            r.dsps.to_string(),
        ]);
    }
    t.print();

    let p = power(&plan.resources, DEFAULT_FREQ_HZ);
    println!(
        "\npower model: static {:.2} W + LUT {:.2} W + BRAM {:.2} W + DSP {:.2} W = {:.2} W (paper: 8.2 W)",
        p.static_w,
        p.lut_w,
        p.bram_w,
        p.dsp_w,
        p.total_w()
    );
}
