//! Bench: the event-driven TCP front-end.
//!
//! Phase A races the legacy thread-per-connection front-end against the
//! epoll reactor over the same 2-worker pool and the same multiplexed
//! open-loop load (pipelined v1 frames, saturating windows).  The
//! reactor serves the identical request stream from a fixed handful of
//! event-loop threads instead of one OS thread per socket; at ≥1k
//! connections that difference is the paper's serving story — the 8.3x
//! small-batch scenario only materializes if the host front-end keeps
//! the accelerator fed without drowning in scheduler overhead.
//!
//! Phase B demonstrates the two-lane QoS admission: a saturating
//! offline flood (large windows, short per-request deadlines) competes
//! with a modest Poisson online stream (100 ms deadlines) through the
//! protocol-v2 registry front-end.  The weighted-deficit scheduler must
//! keep the online p99 inside its deadline while the offline lane sheds
//! with typed `REPLY_EXPIRED` frames — and every admitted request must
//! still get exactly one reply (conservation).
//!
//! Results land in `rust/BENCH_serve.json`.  Run:
//! `cargo bench --bench serve_frontend` (CI runs `BENCH_SMOKE=1`).
//! Full mode opens >2k sockets in one process — raise the fd limit
//! first (`ulimit -n 8192`).  `BENCH_SERVE_CONNS` overrides the phase-A
//! connection count.

use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use repro::benchkit::{envelope, write_bench_json, Json, Table};
use repro::coordinator::workload::{
    random_images, run_frontend_load, FrontendLoadConfig, FrontendLoadReport, LoadProto,
};
use repro::coordinator::{
    frontend_snapshot, reactor_supported, serve_tcp_frontend, serve_tcp_threaded, Backend,
    BackendFactory, BatchPolicy, Coordinator, CoordinatorConfig, FrontendConfig, Lane,
    NativeBackend, QosConfig,
};
use repro::model::BcnnModel;
use repro::serving::{BackendSpec, DeploySpec, ModelRegistry};

fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// A front-end thread serving one listener until `stop` is raised.
struct Frontend {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<anyhow::Result<()>>,
}

impl Frontend {
    fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        self.handle.join().expect("front-end thread").expect("front-end serve");
    }
}

fn check_conservation(tag: &str, r: &FrontendLoadReport) {
    assert!(
        r.conservation_ok(),
        "{tag}: reply conservation violated — sent {} ok {} errors {} expired {} lost {}",
        r.sent,
        r.ok,
        r.errors,
        r.expired,
        r.lost
    );
}

// ---------------------------------------------------------------------
// Phase A: thread-per-connection vs reactor, identical pool and load
// ---------------------------------------------------------------------

fn start_pool(model: &BcnnModel) -> Coordinator {
    let m = model.clone();
    let factory: BackendFactory = Arc::new(move || -> anyhow::Result<Box<dyn Backend>> {
        Ok(Box::new(NativeBackend::new(m.clone())?))
    });
    Coordinator::start_sharded(
        factory,
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::ZERO },
            workers: 2,
            queue_depth: 256,
            ..Default::default()
        },
    )
    .expect("start pool")
}

fn start_v1_frontend(pool: &Coordinator, reactor: bool) -> Frontend {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind front-end");
    let addr = listener.local_addr().expect("local addr");
    let stop = Arc::new(AtomicBool::new(false));
    let (client, stop2) = (pool.client(), Arc::clone(&stop));
    let handle = std::thread::spawn(move || {
        if reactor {
            serve_tcp_frontend(listener, client, stop2, FrontendConfig::default())
        } else {
            serve_tcp_threaded(listener, client, stop2)
        }
    });
    Frontend { addr, stop, handle }
}

fn phase_a_rps(
    model: &BcnnModel,
    image: &[i32],
    reactor: bool,
    conns: usize,
    duration: Duration,
) -> f64 {
    let pool = start_pool(model);
    let fe = start_v1_frontend(&pool, reactor);
    let cfg = FrontendLoadConfig {
        addr: fe.addr,
        connections: conns,
        threads: if smoke() { 2 } else { 8 },
        window: 4,
        duration,
        rate_rps: None,
        proto: LoadProto::V1,
        seed: 0xA11CE ^ reactor as u64,
    };
    let report = run_frontend_load(&cfg, image).expect("phase-A load");
    let mode = if reactor { "reactor" } else { "threaded" };
    check_conservation(mode, &report);
    fe.shutdown();
    pool.shutdown();
    report.throughput()
}

// ---------------------------------------------------------------------
// Phase B: two-lane QoS over the protocol-v2 registry front-end
// ---------------------------------------------------------------------

fn start_v2_frontend(registry: Arc<ModelRegistry>) -> Frontend {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind v2 front-end");
    let addr = listener.local_addr().expect("local addr");
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let cfg = FrontendConfig {
        reactor_threads: 0,
        qos: QosConfig {
            online_weight: 8,
            offline_weight: 1,
            // a deep lane so sheds are deadline-typed, not capacity drops
            lane_capacity: 1 << 16,
            ..QosConfig::default()
        },
    };
    let handle =
        std::thread::spawn(move || serve_tcp_registry(listener, registry, stop2, cfg));
    Frontend { addr, stop, handle }
}

fn serve_tcp_registry(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    stop: Arc<AtomicBool>,
    cfg: FrontendConfig,
) -> anyhow::Result<()> {
    repro::serving::serve_registry_frontend(listener, registry, stop, cfg)
}

struct SloOutcome {
    online: FrontendLoadReport,
    offline: FrontendLoadReport,
    online_deadline_ms: u32,
    lane_shed_expired: u64,
}

fn phase_b_slo(model: &BcnnModel, image: &[i32], duration: Duration) -> SloOutcome {
    // one deliberately narrow pool: a single worker with a shallow shard
    // queue, so the offline flood actually queues in the admission lanes
    let registry = Arc::new(ModelRegistry::new());
    registry
        .deploy(
            "demo",
            DeploySpec {
                model: model.clone(),
                backend: BackendSpec::Engine { lanes: 1 },
                workers: 1,
                queue_depth: 8,
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::ZERO },
            },
        )
        .expect("deploy demo model");
    let fe = start_v2_frontend(Arc::clone(&registry));

    let online_deadline_ms: u32 = 100;
    let offline_deadline_ms: u32 = if smoke() { 2 } else { 10 };
    let offline_cfg = FrontendLoadConfig {
        addr: fe.addr,
        connections: if smoke() { 32 } else { 448 },
        threads: if smoke() { 2 } else { 4 },
        window: 32,
        duration,
        rate_rps: None,
        proto: LoadProto::Qos { lane: Lane::Offline, deadline_ms: offline_deadline_ms },
        seed: 0x0FF1,
    };
    let online_cfg = FrontendLoadConfig {
        addr: fe.addr,
        connections: if smoke() { 8 } else { 64 },
        threads: if smoke() { 1 } else { 2 },
        window: 4,
        duration,
        rate_rps: Some(if smoke() { 150.0 } else { 800.0 }),
        proto: LoadProto::Qos { lane: Lane::Online, deadline_ms: online_deadline_ms },
        seed: 0x0511,
    };

    let image_off = image.to_vec();
    let offline_thread = std::thread::spawn(move || {
        run_frontend_load(&offline_cfg, &image_off).expect("offline flood")
    });
    let online = run_frontend_load(&online_cfg, image).expect("online load");
    let offline = offline_thread.join().expect("offline load thread");

    // snapshot the lane counters while the front-end is still live (its
    // stats deregister once the reactor threads exit); zero when the
    // platform fell back to the threaded front-end
    let lane_shed_expired = if reactor_supported() {
        frontend_snapshot().lane(Lane::Offline).shed_expired
    } else {
        0
    };
    fe.shutdown();
    registry.undeploy("demo").expect("undeploy demo model");
    registry.reap_retired();

    check_conservation("online", &online);
    check_conservation("offline", &offline);
    SloOutcome { online, offline, online_deadline_ms, lane_shed_expired }
}

// ---------------------------------------------------------------------

fn report_json(tag: &str, r: &FrontendLoadReport) -> Json {
    Json::Obj(vec![
        ("lane".into(), Json::Str(tag.into())),
        ("sent".into(), Json::Num(r.sent as f64)),
        ("ok".into(), Json::Num(r.ok as f64)),
        ("errors".into(), Json::Num(r.errors as f64)),
        ("expired".into(), Json::Num(r.expired as f64)),
        ("throughput_rps".into(), Json::Num(r.throughput())),
        ("p50_ms".into(), Json::Num(r.percentile_ms(50.0))),
        ("p99_ms".into(), Json::Num(r.percentile_ms(99.0))),
    ])
}

fn main() {
    let model_a =
        BcnnModel::load_or_synthetic("tiny", "artifacts", 0xB_C0DE).expect("tiny config");
    // phase B wants real per-image latency so the flood actually queues
    let model_b =
        BcnnModel::load_or_synthetic("small", "artifacts", 0xB_C0DE).expect("small config");
    let image_a = random_images(&model_a.config(), 1, 0xBEEF).remove(0);
    let image_b = random_images(&model_b.config(), 1, 0xBEEF).remove(0);

    let conns = env_usize("BENCH_SERVE_CONNS", if smoke() { 64 } else { 1024 });
    let duration_a = if smoke() { Duration::from_millis(500) } else { Duration::from_secs(3) };
    let duration_b = if smoke() { Duration::from_millis(800) } else { Duration::from_secs(3) };

    println!(
        "=== serve front-end: {} connections, reactor {} ===",
        conns,
        if reactor_supported() { "native" } else { "UNSUPPORTED (threaded fallback)" }
    );

    // interleave nothing: each mode gets a fresh pool and a quiet machine
    let threaded_rps = phase_a_rps(&model_a, &image_a, false, conns, duration_a);
    let reactor_rps = phase_a_rps(&model_a, &image_a, true, conns, duration_a);
    let ratio = reactor_rps / threaded_rps.max(1e-9);

    let mut t = Table::new(&["front-end", "conns", "req/s"]);
    t.row(&["threaded".into(), conns.to_string(), format!("{threaded_rps:.0}")]);
    t.row(&["reactor".into(), conns.to_string(), format!("{reactor_rps:.0}")]);
    t.print();
    println!("reactor/threaded throughput ratio: {ratio:.2}x\n");

    let slo = phase_b_slo(&model_b, &image_b, duration_b);
    let online_p99 = slo.online.percentile_ms(99.0);
    let mut t = Table::new(&["lane", "sent", "ok", "expired", "p50 ms", "p99 ms"]);
    t.row(&[
        "online".into(),
        slo.online.sent.to_string(),
        slo.online.ok.to_string(),
        slo.online.expired.to_string(),
        format!("{:.2}", slo.online.percentile_ms(50.0)),
        format!("{online_p99:.2}"),
    ]);
    t.row(&[
        "offline".into(),
        slo.offline.sent.to_string(),
        slo.offline.ok.to_string(),
        slo.offline.expired.to_string(),
        format!("{:.2}", slo.offline.percentile_ms(50.0)),
        format!("{:.2}", slo.offline.percentile_ms(99.0)),
    ]);
    t.print();

    let online_within = online_p99 <= slo.online_deadline_ms as f64;
    let sheds_nonzero = slo.offline.expired > 0;
    println!(
        "online p99 {online_p99:.2} ms (deadline {} ms, {}), offline deadline sheds {} \
         (lane counter {})",
        slo.online_deadline_ms,
        if online_within { "met" } else { "MISSED" },
        slo.offline.expired,
        slo.lane_shed_expired,
    );

    // smoke mode (CI shared runners) checks mechanism, not performance:
    // conservation always holds and the offline lane must shed, but the
    // throughput win and the online SLO are only asserted in full runs
    let pass = sheds_nonzero && (smoke() || (ratio > 1.0 && online_within));

    let mut fields = envelope("serve_frontend", "tiny+small;v1-pool-w2;v2-registry-w1");
    fields.extend(vec![
        ("smoke".into(), Json::Bool(smoke())),
        ("reactor_supported".into(), Json::Bool(reactor_supported())),
        ("connections".into(), Json::Num(conns as f64)),
        ("threaded_rps".into(), Json::Num(threaded_rps)),
        ("reactor_rps".into(), Json::Num(reactor_rps)),
        ("reactor_over_threaded_ratio".into(), Json::Num(ratio)),
        (
            "slo".into(),
            Json::Obj(vec![
                ("online".into(), report_json("online", &slo.online)),
                ("offline".into(), report_json("offline", &slo.offline)),
                ("online_deadline_ms".into(), Json::Num(slo.online_deadline_ms as f64)),
                ("online_within_deadline".into(), Json::Bool(online_within)),
                ("offline_deadline_sheds".into(), Json::Num(slo.offline.expired as f64)),
                ("lane_shed_expired".into(), Json::Num(slo.lane_shed_expired as f64)),
            ]),
        ),
        ("conservation_ok".into(), Json::Bool(true)),
        ("pass".into(), Json::Bool(pass)),
    ]);
    write_bench_json("BENCH_serve.json", &Json::Obj(fields)).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json (smoke={})", smoke());
    assert!(pass, "serve front-end bench failed its acceptance gates");
}
