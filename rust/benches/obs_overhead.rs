//! Bench: the tracing observer effect.  The ISSUE's bar for "always-on"
//! is that arming the span rings costs less than 3% of serving
//! throughput — measured here by driving the same closed-loop workload
//! through a pipeline-backed coordinator pool with tracing armed and
//! disarmed in alternating rounds, and comparing the best round of each
//! mode.  Results land in `rust/BENCH_obs.json`; the run fails (nonzero
//! exit) if the overhead exceeds the budget.
//!
//! Run: `cargo bench --bench obs_overhead`
//! (CI runs a shortened pass with `BENCH_SMOKE=1`.)

use std::sync::Arc;
use std::time::Duration;

use repro::benchkit::{write_bench_json, Json, Table};
use repro::coordinator::workload::run_closed_loop;
use repro::coordinator::{Backend, BackendFactory, BatchPolicy, Coordinator, CoordinatorConfig};
use repro::model::BcnnModel;
use repro::obs;
use repro::pipeline::PipelineBackend;

fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

/// Closed-loop throughput of a fresh 2-shard pipeline-backed pool —
/// the configuration that records the most spans per request (the four
/// coordinator spans plus one per pipeline stage).
fn throughput(model: &BcnnModel, requests: usize, seed: u64) -> f64 {
    let m = model.clone();
    let factory: BackendFactory = Arc::new(move || -> anyhow::Result<Box<dyn Backend>> {
        Ok(Box::new(PipelineBackend::new(m.clone(), 8)?))
    });
    let coord = Coordinator::start_sharded(
        factory,
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::ZERO },
            workers: 2,
            queue_depth: 64,
        },
    )
    .expect("start pool");
    let cfg = model.config();
    // warm the stage threads and per-lane arenas outside the timed window
    run_closed_loop(&coord.client(), &cfg, requests / 4, seed ^ 1).expect("warm-up");
    let report = run_closed_loop(&coord.client(), &cfg, requests, seed).expect("workload");
    coord.shutdown();
    report.throughput()
}

fn main() {
    let model =
        BcnnModel::load_or_synthetic("tiny", "artifacts", 0xB_C0DE).expect("built-in config");
    let requests = if smoke() { 192usize } else { 1024 };
    let rounds = if smoke() { 2usize } else { 4 };

    // A/B alternation absorbs machine-state drift (thermal, cache,
    // page-in); each mode's best round is its honest capability.
    let mut on_best = 0f64;
    let mut off_best = 0f64;
    let mut t = Table::new(&["round", "tracing", "req/s"]);
    for round in 0..rounds {
        for &on in &[true, false] {
            obs::set_enabled(on);
            let rps = throughput(&model, requests, 0xB5 + round as u64);
            if on {
                on_best = on_best.max(rps);
            } else {
                off_best = off_best.max(rps);
            }
            let mode = if on { "on" } else { "off" };
            t.row(&[round.to_string(), mode.to_string(), format!("{rps:.0}")]);
        }
    }
    obs::set_enabled(true); // leave the process default (always-on) armed
    println!("=== tracing observer effect (tiny config, {requests} req/round) ===");
    t.print();

    let overhead_pct = (off_best - on_best) / off_best.max(1e-9) * 100.0;
    let pass = overhead_pct < 3.0;
    println!(
        "\ntracing on {on_best:.0} req/s, off {off_best:.0} req/s -> \
         overhead {overhead_pct:.2}% (budget < 3%)"
    );

    let json = Json::Obj(vec![
        ("bench".into(), Json::Str("obs_overhead".into())),
        ("smoke".into(), Json::Bool(smoke())),
        ("config".into(), Json::Str("tiny".into())),
        ("requests_per_round".into(), Json::Num(requests as f64)),
        ("rounds_per_mode".into(), Json::Num(rounds as f64)),
        ("on_rps".into(), Json::Num(on_best)),
        ("off_rps".into(), Json::Num(off_best)),
        ("overhead_pct".into(), Json::Num(overhead_pct)),
        ("threshold_pct".into(), Json::Num(3.0)),
        ("pass".into(), Json::Bool(pass)),
    ]);
    write_bench_json("BENCH_obs.json", &json).expect("write BENCH_obs.json");
    println!("wrote BENCH_obs.json (smoke={})", smoke());
    assert!(pass, "tracing overhead {overhead_pct:.2}% exceeds the 3% budget");
}
