//! Bounded SPSC row FIFO — the software stand-in for the paper's §4.3
//! double-buffered inter-layer memory channel.
//!
//! Capacity comes from [`crate::fpga::channel::fifo_rows`]: `CHANNEL_SLOTS`
//! feature maps' worth of rows, so a producer stage can run at most one
//! full image ahead of its consumer — exactly the decoupling the ping-pong
//! memories provide on the device, and the property that bounds in-flight
//! memory no matter how many images are queued behind the pipeline.
//!
//! Endpoints are single-owner (no `Clone`), so the channel is SPSC by
//! construction.  Dropping the sender closes the stream (the receiver
//! drains what is buffered, then sees `None`); dropping the receiver
//! makes further sends fail fast, which is how shutdown propagates
//! *upstream* through a pipeline without poison messages racing full
//! queues.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct State<T> {
    buf: VecDeque<T>,
    sender_gone: bool,
    receiver_gone: bool,
}

struct Inner<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

/// Producer endpoint of a bounded SPSC row FIFO.
pub struct RowSender<T> {
    inner: Arc<Inner<T>>,
}

/// Consumer endpoint of a bounded SPSC row FIFO.
pub struct RowReceiver<T> {
    inner: Arc<Inner<T>>,
}

/// Create a bounded SPSC FIFO holding at most `capacity` items
/// (`capacity` is clamped to at least 1).
pub fn bounded<T>(capacity: usize) -> (RowSender<T>, RowReceiver<T>) {
    let inner = Arc::new(Inner {
        state: Mutex::new(State {
            buf: VecDeque::with_capacity(capacity.max(1)),
            sender_gone: false,
            receiver_gone: false,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity: capacity.max(1),
    });
    (RowSender { inner: Arc::clone(&inner) }, RowReceiver { inner })
}

impl<T> RowSender<T> {
    /// Blocking send: waits while the FIFO is full.  Returns the value
    /// back if the receiver is gone (the downstream stage exited), so the
    /// caller can stop producing.
    pub fn send(&self, value: T) -> Result<(), T> {
        let mut state = self.inner.state.lock().unwrap();
        loop {
            if state.receiver_gone {
                return Err(value);
            }
            if state.buf.len() < self.inner.capacity {
                state.buf.push_back(value);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            state = self.inner.not_full.wait(state).unwrap();
        }
    }

    /// Configured capacity (for the geometry-pinning tests).
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }
}

impl<T> Drop for RowSender<T> {
    fn drop(&mut self) {
        let mut state = self.inner.state.lock().unwrap();
        state.sender_gone = true;
        drop(state);
        // wake a receiver blocked on an empty queue so it observes EOS
        self.inner.not_empty.notify_all();
    }
}

impl<T> RowReceiver<T> {
    /// Blocking receive: waits while the FIFO is empty.  Returns `None`
    /// once the sender is gone *and* the buffer is drained — in-flight
    /// rows are always delivered before end-of-stream.
    pub fn recv(&self) -> Option<T> {
        let mut state = self.inner.state.lock().unwrap();
        loop {
            if let Some(value) = state.buf.pop_front() {
                drop(state);
                self.inner.not_full.notify_one();
                return Some(value);
            }
            if state.sender_gone {
                return None;
            }
            state = self.inner.not_empty.wait(state).unwrap();
        }
    }

    /// Configured capacity (for the geometry-pinning tests).
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }
}

impl<T> Drop for RowReceiver<T> {
    fn drop(&mut self) {
        let mut state = self.inner.state.lock().unwrap();
        state.receiver_gone = true;
        state.buf.clear();
        drop(state);
        // wake a producer blocked on a full queue so it sees the closure
        self.inner.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_eos() {
        let (tx, rx) = bounded(4);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        drop(tx);
        // buffered items drain before end-of-stream
        let got = (rx.recv(), rx.recv(), rx.recv(), rx.recv());
        assert_eq!(got, (Some(0), Some(1), Some(2), Some(3)));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_blocks_until_consumer_drains() {
        let (tx, rx) = bounded(1);
        tx.send(1u32).unwrap();
        let producer = std::thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until the 1 is consumed
            3u32
        });
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(producer.join().unwrap(), 3);
    }

    #[test]
    fn dropped_receiver_fails_sends_and_unblocks_producer() {
        let (tx, rx) = bounded(1);
        tx.send(7u32).unwrap(); // fifo now full
        let producer = std::thread::spawn(move || tx.send(8).err());
        // the producer may already be blocked on the full queue; dropping
        // the receiver must wake it with its value handed back
        drop(rx);
        assert_eq!(producer.join().unwrap(), Some(8));
    }

    #[test]
    fn capacity_clamped_to_one() {
        let (tx, _rx) = bounded::<u8>(0);
        assert_eq!(tx.capacity(), 1);
    }
}
