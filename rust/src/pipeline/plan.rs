//! Stage-parallelism plans: how many worker lanes each pipeline stage
//! runs (paper §4.3, executed).
//!
//! The paper reaches eq. 12's `FPS = freq / max_L(C_L)` only by giving
//! each layer a *different* spatial parallelism `P` until every stage's
//! cycle count is equal (Table 3) — FINN balances BNN dataflow pipelines
//! the same way, by per-layer compute folding.  A [`StagePlan`] is the
//! host-side counterpart: `lanes_per_layer[l]` channel-partitioned worker
//! lanes for stage `l` (see the partition notes on
//! [`crate::bcnn::engine::LayerStepper`]), chosen so per-stage service
//! time is as equal as the lane quantization allows.
//!
//! Two ways to get a balanced plan:
//!
//! * [`StagePlan::balanced`] — a quick host calibration pass
//!   ([`calibrate_image_costs`]) measures each stage's real per-image row
//!   cost on this machine, then water-fills lanes onto the measured
//!   bottlenecks.  This is what `--stage-plan auto` / `--stage-threads N`
//!   execute.
//! * [`StagePlan::from_plan`] — maps a §4.3 optimizer [`Plan`]'s
//!   per-layer work profile onto lanes.  The profile used is eq. 9's
//!   `cycle_conv` (the parallelism-independent work a host lane must
//!   grind through); the plan's `cycle_real` already has the device's
//!   `UF·P` folded in, so it is what a balanced pipeline should
//!   *equalize*, not the imbalance to correct — `repro optimize --json`
//!   emits both so the modeled balance can be diffed against the
//!   executed one.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::bcnn::engine::{RowRef, StepperOut};
use crate::bcnn::Engine;
use crate::model::LayerWeights;
use crate::optimizer::Plan;
use crate::util::SplitMix64;

/// Per-stage lane counts for a [`crate::pipeline::PipelineRuntime`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StagePlan {
    /// Worker lanes per layer stage, in model order.  Values are clamped
    /// to `[1, out_c]` when the runtime applies the plan (a layer cannot
    /// split finer than its output channels).
    pub lanes_per_layer: Vec<usize>,
}

impl StagePlan {
    /// The same lane count for every stage (`uniform(n, 1)` is the
    /// unbalanced one-thread-per-layer pipeline of PR 3).
    pub fn uniform(layers: usize, lanes: usize) -> Self {
        Self { lanes_per_layer: vec![lanes.max(1); layers] }
    }

    /// Total lanes (= stage threads) this plan asks for — the raw sum of
    /// `lanes_per_layer`.  The runtime clamps each stage to `[1, out_c]`
    /// when applying a plan, so size thread pools from the *executed*
    /// plan ([`crate::pipeline::PipelineRuntime::plan`]), which reports
    /// the clamped counts.
    pub fn total_lanes(&self) -> usize {
        self.lanes_per_layer.iter().sum()
    }

    /// Water-fill `budget` total lanes onto stages proportionally to
    /// their measured (or modeled) per-image `costs`: starting from one
    /// lane everywhere, repeatedly grant one lane to the stage with the
    /// largest per-lane cost `costs[i] / lanes[i]` until the budget is
    /// spent or every stage is at its cap — the discrete version of the
    /// paper's "choose P until all the layers have equal execution time".
    /// `caps[i]` bounds stage `i` (a layer cannot split finer than its
    /// output channels).  Deterministic: ties go to the earliest stage.
    pub fn from_costs(costs: &[f64], caps: &[usize], budget: usize) -> Self {
        let n = costs.len();
        let mut lanes = vec![1usize; n];
        if n == 0 {
            return Self { lanes_per_layer: lanes };
        }
        let mut spare = budget.saturating_sub(n);
        while spare > 0 {
            let mut best: Option<(usize, f64)> = None;
            for i in 0..n {
                if lanes[i] >= caps.get(i).copied().unwrap_or(usize::MAX).max(1) {
                    continue;
                }
                let per_lane = costs[i] / lanes[i] as f64;
                if best.map(|(_, c)| per_lane > c).unwrap_or(true) {
                    best = Some((i, per_lane));
                }
            }
            let Some((i, _)) = best else {
                break; // every stage is at its cap
            };
            lanes[i] += 1;
            spare -= 1;
        }
        Self { lanes_per_layer: lanes }
    }

    /// Measure each stage's per-image cost on this host
    /// ([`calibrate_image_costs`]) and water-fill `budget` total lanes
    /// onto the bottlenecks.  `budget <= layers` degenerates to the
    /// unbalanced one-lane-per-stage plan.
    pub fn balanced(engine: &Engine, budget: usize) -> Result<Self> {
        let costs = calibrate_image_costs(engine)?;
        let caps: Vec<usize> = engine.layer_shapes().iter().map(|s| s.out_c.max(1)).collect();
        Ok(Self::from_costs(&costs, &caps, budget))
    }

    /// Map a §4.3 optimizer [`Plan`] onto host lanes: water-fill `budget`
    /// lanes proportionally to each layer's eq. 9 work (`cycle_conv`) —
    /// see the module docs for why `cycle_real` is the balance *target*
    /// rather than the cost profile.  The plan must describe the same
    /// network the runtime will execute (same layer count and order).
    pub fn from_plan(plan: &Plan, budget: usize) -> Self {
        let costs: Vec<f64> = plan.layers.iter().map(|l| l.cycle_conv as f64).collect();
        let caps: Vec<usize> = plan.layers.iter().map(|l| l.geom.dep.max(1)).collect();
        Self::from_costs(&costs, &caps, budget)
    }
}

/// How long the calibration pass spends per stage, at most.  The costs
/// only need to be *relatively* right for water-filling, so a couple of
/// milliseconds per stage is plenty.
const CALIBRATE_BUDGET_PER_STAGE: Duration = Duration::from_millis(2);
/// Image-count bounds for one stage's calibration loop.
const CALIBRATE_MIN_IMAGES: u32 = 3;
const CALIBRATE_MAX_IMAGES: u32 = 256;

/// Measure each stage's single-lane cost of streaming one whole image
/// through its [`crate::bcnn::engine::LayerStepper`] (seconds per image,
/// in model order).  Inputs are deterministic pseudo-random rows — zeros
/// would let the first layer's zero-skip path cheat the measurement.
pub fn calibrate_image_costs(engine: &Engine) -> Result<Vec<f64>> {
    let shapes = engine.layer_shapes();
    let mut costs = Vec::with_capacity(shapes.len());
    for (i, shape) in shapes.iter().enumerate() {
        let mut stepper = engine.layer_stepper(i)?;
        let mut rng = SplitMix64::new(0xCA11_B8A7 ^ i as u64);
        // one synthetic input row, reused for every push of the image
        let int_row: Vec<i32>;
        let bits_row: Vec<u64>;
        let row: RowRef<'_> =
            if matches!(engine.model().layers[i], LayerWeights::FpConv { .. }) {
                int_row = (0..shape.in_hw * shape.in_c)
                    .map(|_| rng.range_i64(-31, 31) as i32)
                    .collect();
                RowRef::Int(&int_row)
            } else {
                bits_row = (0..shape.in_row_words()).map(|_| rng.next_u64()).collect();
                RowRef::Bits(&bits_row)
            };
        let mut sink = |out: StepperOut| {
            std::hint::black_box(&out);
        };
        // warm-up image (first-touch allocations, branch training)
        for _ in 0..shape.in_hw {
            stepper.push_row(row, &mut sink)?;
        }
        stepper.flush(&mut sink)?;
        let start = Instant::now();
        let mut images = 0u32;
        loop {
            for _ in 0..shape.in_hw {
                stepper.push_row(row, &mut sink)?;
            }
            stepper.flush(&mut sink)?;
            images += 1;
            if (start.elapsed() >= CALIBRATE_BUDGET_PER_STAGE && images >= CALIBRATE_MIN_IMAGES)
                || images >= CALIBRATE_MAX_IMAGES
            {
                break;
            }
        }
        costs.push(start.elapsed().as_secs_f64() / images as f64);
    }
    Ok(costs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BcnnModel, NetConfig};

    #[test]
    fn water_filling_feeds_the_bottleneck_first() {
        // stage 1 carries 8x the work of the others: every spare lane
        // lands there until per-lane costs level out
        let costs = [1.0, 8.0, 1.0];
        let caps = [64, 64, 64];
        let plan = StagePlan::from_costs(&costs, &caps, 7);
        assert_eq!(plan.lanes_per_layer, vec![1, 5, 1]);
        assert_eq!(plan.total_lanes(), 7);
        // budget at (or below) the stage count: unbalanced fallback
        let plan = StagePlan::from_costs(&costs, &caps, 3);
        assert_eq!(plan.lanes_per_layer, vec![1, 1, 1]);
        let plan = StagePlan::from_costs(&costs, &caps, 0);
        assert_eq!(plan.lanes_per_layer, vec![1, 1, 1]);
    }

    #[test]
    fn caps_bound_the_fill_and_spill_to_the_next_stage() {
        let costs = [1.0, 100.0, 2.0];
        let caps = [4, 2, 4];
        let plan = StagePlan::from_costs(&costs, &caps, 8);
        // the bottleneck is capped at 2 lanes; the remaining budget goes
        // to the next-most-expensive stages until their caps
        assert_eq!(plan.lanes_per_layer[1], 2);
        assert!(plan.total_lanes() <= 8);
        // all-capped: the fill stops early instead of looping forever
        let plan = StagePlan::from_costs(&costs, &[1, 1, 1], 100);
        assert_eq!(plan.lanes_per_layer, vec![1, 1, 1]);
    }

    #[test]
    fn from_plan_maps_the_optimizer_profile_onto_lanes() {
        // the optimizer plan and the engine describe the same network
        // layer-for-layer (conv rows then FC rows, classifier last), so
        // from_plan's lane vector drops straight into the runtime
        let cfg = NetConfig::tiny();
        let engine = Engine::new(BcnnModel::synthetic(&cfg, 3)).unwrap();
        let plan = crate::optimizer::optimize(&cfg, &crate::optimizer::OptimizeOptions::default())
            .unwrap();
        assert_eq!(plan.layers.len(), engine.layer_shapes().len());
        let stage_plan = StagePlan::from_plan(&plan, 6);
        assert_eq!(stage_plan.lanes_per_layer.len(), plan.layers.len());
        // eq. 9 work profile: conv2 (32 -> 32 at 8x8 pre-pool) dominates
        // tiny, so the spare lanes land there
        let bottleneck = plan
            .layers
            .iter()
            .enumerate()
            .max_by_key(|(_, l)| l.cycle_conv)
            .unwrap()
            .0;
        assert!(
            stage_plan.lanes_per_layer[bottleneck]
                > stage_plan.lanes_per_layer[(bottleneck + 1) % plan.layers.len()],
            "plan {stage_plan:?}"
        );
        // caps: no layer gets more lanes than it has output values deep
        for (lanes, l) in stage_plan.lanes_per_layer.iter().zip(&plan.layers) {
            assert!(*lanes >= 1 && *lanes <= l.geom.dep.max(1));
        }
    }

    #[test]
    fn calibration_finds_the_skewed_layer() {
        // conv2 (8 -> 256 channels) dwarfs the other stages; the measured
        // costs must rank it the bottleneck and `balanced` must give it
        // the spare lanes
        let cfg = NetConfig {
            name: "skew".into(),
            conv: vec![
                crate::model::ConvSpec { out_channels: 8, pool: false },
                crate::model::ConvSpec { out_channels: 256, pool: false },
            ],
            fc: vec![],
            classes: 10,
            input_hw: 8,
            input_channels: 3,
            input_bits: 6,
        };
        let engine = Engine::new(BcnnModel::synthetic(&cfg, 7)).unwrap();
        let costs = calibrate_image_costs(&engine).unwrap();
        assert_eq!(costs.len(), 3);
        let bottleneck = costs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(bottleneck, 1, "costs {costs:?}");
        let plan = StagePlan::balanced(&engine, 6).unwrap();
        assert_eq!(plan.lanes_per_layer.len(), 3);
        assert!(
            plan.lanes_per_layer[1] > plan.lanes_per_layer[0]
                && plan.lanes_per_layer[1] > plan.lanes_per_layer[2],
            "plan {plan:?} (costs {costs:?})"
        );
    }
}
