//! One pipeline stage: a *lane group* of threads wrapping channel-
//! partitioned [`LayerStepper`]s.
//!
//! A stage with one lane (the PR 3 shape) is a single thread consuming
//! input rows from its bounded FIFO, pushing them through the stepper and
//! forwarding every emitted output row downstream — concurrently active
//! with every other stage, the defining property of the paper's §4
//! streaming architecture.
//!
//! A stage with `L > 1` lanes (a [`crate::pipeline::StagePlan`] entry) is
//! the host analogue of giving that layer more spatial parallelism `P`:
//! the output channels are split into `L` contiguous partitions, each
//! computed by its own [`LayerStepper`] lane over the *same* input rows.
//! Every lane computes its partition with the engine's dispatched bitwise
//! SIMD kernel (see [`crate::util::kernels`]): the per-tap bank slices a
//! lane works on are contiguous `[lo, hi)` ranges of the tap-major layout,
//! so channel partitioning and vectorization compose without any
//! per-lane re-packing.
//! The lead lane (lane 0) owns the stage's FIFO endpoints: per input row
//! it broadcasts the row (an `Arc`, no copies) to the helper lanes,
//! computes its own partition, then pops exactly one partial result per
//! helper per emission and merges deterministically — partial packed rows
//! carry disjoint bit-ranges and OR together; partial classifier scores
//! concatenate in ascending lane order.  Emission schedules are identical
//! across partitions (they depend only on geometry), so the merge needs
//! no sequence numbers, and the lead's per-emission pops double as the
//! rate-match: a helper can never run more than one row ahead.  FIFO
//! geometry *between* stages stays pinned to the §4.3 channel model; the
//! tiny intra-group lane FIFOs are plumbing inside one stage, not an
//! inter-layer channel.
//!
//! Image boundaries are implicit: a stage knows its layer consumes exactly
//! `in_hw` rows per image, so after the `in_hw`-th row it flushes (bottom
//! border / FC compute) and resets for the next image.  No marker tokens
//! means no marker/poison races with full queues.
//!
//! Shutdown is edge-triggered in both directions:
//! * upstream closure (sender dropped) — the stage drains buffered rows,
//!   then exits and drops its own sender, cascading end-of-stream down
//!   the pipe (helper lanes exit when the lead drops their input senders);
//! * downstream closure (receiver dropped) — the stage's forward `send`
//!   fails, it exits and drops its receiver, cascading the closure up the
//!   pipe until the feeder observes it.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::bcnn::engine::{LayerStepper, RowRef, StepperOut};
use crate::bcnn::Engine;
use crate::obs::profile::StageWork;
use crate::obs::{self, StageTracer};
use crate::pipeline::fifo::{bounded, RowReceiver, RowSender};
use crate::util::faults;
use crate::util::sync::lock_recover;

/// A row in flight between stages: raw integers into the first layer,
/// packed bits everywhere else.
#[derive(Debug, Clone)]
pub enum PipeRow {
    Int(Vec<i32>),
    Bits(Vec<u64>),
}

/// Why an in-flight image could not complete — typed, so callers match on
/// variants instead of scraping message strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StageError {
    /// The pipeline shut down (or a stage exited mid-cascade) with the
    /// image in flight.  The submission itself was fine; resubmitting on
    /// a live pipeline would succeed.
    Shutdown,
    /// A stage's stepper rejected the row stream — impossible for rows
    /// produced by validated upstream stages, but never silently
    /// swallowed.
    Failed(String),
}

impl fmt::Display for StageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StageError::Shutdown => write!(f, "pipeline shut down with the image in flight"),
            StageError::Failed(msg) => write!(f, "pipeline stage failed: {msg}"),
        }
    }
}

impl std::error::Error for StageError {}

/// Per-image completion result delivered to a submit ticket.
pub type ScoreResult = Result<Vec<f32>, StageError>;

/// Live busy/stall counters for one stage, updated by its lead lane and
/// snapshotted by [`crate::pipeline::PipelineRuntime::stage_stats`].
/// `busy` covers stepper compute plus the lane broadcast/merge (waiting
/// on this stage's own lanes *is* the stage working); `stall_in` is time
/// blocked on the input FIFO (upstream starvation); `stall_out` is time
/// blocked forwarding downstream (backpressure from the next stage).
/// The bottleneck stage is the one with high `busy` while its neighbours
/// stall — visible instead of inferred.
#[derive(Debug, Default)]
pub struct StageCounters {
    busy_ns: AtomicU64,
    stall_in_ns: AtomicU64,
    stall_out_ns: AtomicU64,
    rows_in: AtomicU64,
    images: AtomicU64,
    // work ledger (crate::obs::profile): geometry-derived per-image
    // constants folded in once per flushed image when profiling is armed
    xor_words: AtomicU64,
    popcounts: AtomicU64,
    bytes_moved: AtomicU64,
}

impl StageCounters {
    fn add(cell: &AtomicU64, d: Duration) {
        cell.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Fold one image's ledger constants in (called at flush time by the
    /// stage's lead lane; the whole-stage work is accounted once, not per
    /// lane).
    fn add_image_work(&self, work: &StageWork) {
        self.xor_words.fetch_add(work.xor_words, Ordering::Relaxed);
        self.popcounts.fetch_add(work.popcounts, Ordering::Relaxed);
        self.bytes_moved.fetch_add(work.bytes_moved, Ordering::Relaxed);
    }

    /// Consistent-enough snapshot (counters only ever grow).
    pub fn snapshot(&self, layer: usize, lanes: usize) -> StageSnapshot {
        let ns = |cell: &AtomicU64| Duration::from_nanos(cell.load(Ordering::Relaxed));
        StageSnapshot {
            layer,
            lanes,
            busy: ns(&self.busy_ns),
            stall_in: ns(&self.stall_in_ns),
            stall_out: ns(&self.stall_out_ns),
            rows_in: self.rows_in.load(Ordering::Relaxed),
            images: self.images.load(Ordering::Relaxed),
            xor_words: self.xor_words.load(Ordering::Relaxed),
            popcounts: self.popcounts.load(Ordering::Relaxed),
            bytes_moved: self.bytes_moved.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of one stage's [`StageCounters`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StageSnapshot {
    /// Layer index (= stage position).
    pub layer: usize,
    /// Worker lanes the stage runs.
    pub lanes: usize,
    pub busy: Duration,
    pub stall_in: Duration,
    pub stall_out: Duration,
    /// Input rows consumed.
    pub rows_in: u64,
    /// Whole images flushed.
    pub images: u64,
    /// Packed 64-bit words XNOR'd ([`crate::obs::profile`] ledger; 0
    /// while profiling is disarmed).
    pub xor_words: u64,
    /// Popcounts retired (ledger; 0 while disarmed).
    pub popcounts: u64,
    /// Bytes moved: weights + input + output activations (ledger; 0
    /// while disarmed).
    pub bytes_moved: u64,
}

impl StageSnapshot {
    /// Fold another snapshot of the *same* stage into this one (shard
    /// aggregation across backend replicas).
    pub fn absorb(&mut self, other: &StageSnapshot) {
        self.lanes = self.lanes.max(other.lanes);
        self.busy += other.busy;
        self.stall_in += other.stall_in;
        self.stall_out += other.stall_out;
        self.rows_in += other.rows_in;
        self.images += other.images;
        self.xor_words += other.xor_words;
        self.popcounts += other.popcounts;
        self.bytes_moved += other.bytes_moved;
    }
}

/// FIFO-ordered reply senders for images in flight, plus the pipeline's
/// failure latch.  The feeder registers one sender per admitted image
/// *before* feeding its rows; the classifier stage pops one per completed
/// image.  The linear pipeline preserves image order, so front-of-queue
/// is always the next image to finish.
///
/// The latch makes "no ticket ever hangs" airtight: once the classifier
/// stage exits (shutdown drain or failure cascade) it calls
/// [`fail_pending`], which — atomically with [`register_reply`] — fails
/// every queued ticket AND every ticket registered afterwards.  Without
/// the latch, an image fed while a *mid*-pipeline stage was already dead
/// would vanish between live stages and its ticket would wait forever.
pub struct PendingState {
    queue: VecDeque<mpsc::Sender<ScoreResult>>,
    /// `Some(error)` once no new image can ever complete.
    failed: Option<StageError>,
}

/// Shared handle to the pending-reply state.
pub type PendingReplies = Arc<Mutex<PendingState>>;

/// Fresh pending-reply state (no images in flight, latch clear).
pub fn new_pending() -> PendingReplies {
    Arc::new(Mutex::new(PendingState { queue: VecDeque::new(), failed: None }))
}

/// Register an admitted image's reply sender.  If the pipeline has
/// already failed, the ticket is failed immediately instead of being
/// queued behind a classifier that will never pop it.
pub fn register_reply(pending: &PendingReplies, reply: mpsc::Sender<ScoreResult>) {
    let mut state = lock_recover(pending);
    match &state.failed {
        Some(error) => {
            let _ = reply.send(Err(error.clone()));
        }
        None => state.queue.push_back(reply),
    }
}

/// Latch the failure `error` (first caller wins) and fail every ticket
/// currently in flight.
pub fn fail_pending(pending: &PendingReplies, error: StageError) {
    let mut state = lock_recover(pending);
    if state.failed.is_none() {
        state.failed = Some(error);
    }
    let error = state.failed.clone().expect("latched above");
    for reply in state.queue.drain(..) {
        let _ = reply.send(Err(error.clone()));
    }
}

/// The latched failure, if any (readers: runtime health accessors and the
/// degrading [`crate::pipeline::PipelineBackend`]).
pub fn pending_failure(pending: &PendingReplies) -> Option<StageError> {
    lock_recover(pending).failed.clone()
}

/// Where a stage's emissions go: another stage's FIFO, or (for the
/// classifier stage) the pending-reply queue.
pub enum StageOutput {
    Rows(RowSender<PipeRow>),
    Scores(PendingReplies),
}

/// Capacity of the intra-group lane FIFOs (rows for a helper's input,
/// partial emissions for its output).  The lead's per-emission pops keep
/// occupancy at one row in flight; a little slack covers the pool
/// layers' emission-free row pairs.  NOT a §4.3 channel — those are the
/// inter-stage FIFOs, still sized by `fpga::channel::fifo_rows`.
const LANE_FIFO_SLACK: usize = 4;

/// A helper lane's partial result, or the stepper error that killed it.
type LanePartial = Result<StepperOut, String>;

/// Run one stage — possibly a multi-lane group — to completion.  Returns
/// when the input stream closes (normal drain) or the downstream side
/// disappears (abort cascade).  `lanes` is clamped to `[1, out_c]`.
pub fn run_stage_group(
    engine: &Engine,
    index: usize,
    lanes: usize,
    rx: RowReceiver<PipeRow>,
    tx: StageOutput,
    counters: &StageCounters,
    tracer: Option<&StageTracer>,
) {
    let shapes = engine.layer_shapes();
    let out_c = shapes[index].out_c.max(1);
    let lanes = lanes.clamp(1, out_c);
    // per-image ledger constants: derived from geometry once per stage
    // lifetime, folded in at image flush when profiling is armed
    let work = crate::obs::profile::stage_work(&engine.model().config())[index];
    if lanes == 1 {
        let mut stepper = engine.layer_stepper(index).expect("index validated at construction");
        run_single_lane(&mut stepper, work, rx, tx, counters, tracer);
        return;
    }
    // contiguous ascending channel partitions; lane 0 (the lead) keeps
    // the first so merged scores concatenate in class order
    let bounds: Vec<(usize, usize)> = lane_bounds(out_c, lanes);
    std::thread::scope(|scope| {
        let mut helpers_in: Vec<RowSender<Arc<PipeRow>>> = Vec::with_capacity(lanes - 1);
        let mut helpers_out: Vec<RowReceiver<LanePartial>> = Vec::with_capacity(lanes - 1);
        for &(lo, hi) in &bounds[1..] {
            let (in_tx, in_rx) = bounded::<Arc<PipeRow>>(LANE_FIFO_SLACK);
            let (out_tx, out_rx) = bounded::<LanePartial>(LANE_FIFO_SLACK);
            scope.spawn(move || run_helper_lane(engine, index, lo, hi, in_rx, out_tx));
            helpers_in.push(in_tx);
            helpers_out.push(out_rx);
        }
        run_lead_lane(
            engine, index, bounds[0], work, helpers_in, helpers_out, rx, tx, counters, tracer,
        );
        // scope join: helpers observe their dropped endpoints and exit
    });
}

/// Split `out_c` into `lanes` contiguous, ascending, non-empty ranges
/// (callers guarantee `1 <= lanes <= out_c`).
pub(crate) fn lane_bounds(out_c: usize, lanes: usize) -> Vec<(usize, usize)> {
    (0..lanes).map(|l| (l * out_c / lanes, (l + 1) * out_c / lanes)).collect()
}

/// The single-lane stage loop (one thread, no partitioning).
fn run_single_lane(
    stepper: &mut LayerStepper<'_>,
    work: StageWork,
    rx: RowReceiver<PipeRow>,
    tx: StageOutput,
    counters: &StageCounters,
    tracer: Option<&StageTracer>,
) {
    let in_hw = stepper.shape().in_hw;
    let mut rows_in_image = 0usize;
    let mut images_done = 0u64;
    let mut img_start_ns = 0u64;
    // a push emits at most one row and a flush at most one more, so the
    // staging buffer never grows past 2
    let mut emitted: Vec<StepperOut> = Vec::with_capacity(2);

    loop {
        let wait = Instant::now();
        let Some(row) = rx.recv() else { break };
        StageCounters::add(&counters.stall_in_ns, wait.elapsed());
        counters.rows_in.fetch_add(1, Ordering::Relaxed);
        if tracer.is_some() && rows_in_image == 0 {
            img_start_ns = obs::now_ns();
        }
        let busy = Instant::now();
        let rref = match &row {
            PipeRow::Int(v) => RowRef::Int(v),
            PipeRow::Bits(v) => RowRef::Bits(v),
        };
        if let Err(e) = stepper.push_row(rref, &mut |o| emitted.push(o)) {
            fail_stage(&tx, StageError::Failed(e.to_string()));
            return;
        }
        rows_in_image += 1;
        if rows_in_image == in_hw {
            rows_in_image = 0;
            counters.images.fetch_add(1, Ordering::Relaxed);
            if crate::obs::profile::enabled() {
                counters.add_image_work(&work);
            }
            if let Err(e) = stepper.flush(&mut |o| emitted.push(o)) {
                fail_stage(&tx, StageError::Failed(e.to_string()));
                return;
            }
            if let Some(t) = tracer {
                t.record_image(images_done, img_start_ns);
            }
            images_done += 1;
        }
        StageCounters::add(&counters.busy_ns, busy.elapsed());
        for out in emitted.drain(..) {
            let send = Instant::now();
            let ok = forward(&tx, out);
            StageCounters::add(&counters.stall_out_ns, send.elapsed());
            if !ok {
                finish_stage(&tx);
                return; // downstream gone: cascade the closure upstream
            }
        }
    }
    // input closed (shutdown drain or upstream failure): dropping rx/tx
    // cascades the closure; if this is the classifier, latch so nothing
    // registered from now on can wait on a stage that no longer runs
    finish_stage(&tx);
}

/// The lead lane of a multi-lane stage: owns the stage FIFOs, broadcasts
/// rows to the helpers, computes partition 0, merges partials in lane
/// order, forwards.
#[allow(clippy::too_many_arguments)]
fn run_lead_lane(
    engine: &Engine,
    index: usize,
    (lo, hi): (usize, usize),
    work: StageWork,
    helpers_in: Vec<RowSender<Arc<PipeRow>>>,
    helpers_out: Vec<RowReceiver<LanePartial>>,
    rx: RowReceiver<PipeRow>,
    tx: StageOutput,
    counters: &StageCounters,
    tracer: Option<&StageTracer>,
) {
    let mut stepper =
        engine.layer_stepper_part(index, lo, hi).expect("bounds derived from the shape");
    let in_hw = stepper.shape().in_hw;
    let mut rows_in_image = 0usize;
    let mut images_done = 0u64;
    let mut img_start_ns = 0u64;
    let mut emitted: Vec<StepperOut> = Vec::with_capacity(2);

    loop {
        let wait = Instant::now();
        let Some(row) = rx.recv() else { break };
        StageCounters::add(&counters.stall_in_ns, wait.elapsed());
        counters.rows_in.fetch_add(1, Ordering::Relaxed);
        if tracer.is_some() && rows_in_image == 0 {
            img_start_ns = obs::now_ns();
        }
        let busy = Instant::now();
        // broadcast first so the helpers overlap with the lead's own
        // partition compute
        let row = Arc::new(row);
        for (j, h) in helpers_in.iter().enumerate() {
            if h.send(Arc::clone(&row)).is_err() {
                // the lane died; its out-sender is gone too, so draining
                // its partial FIFO cannot block — recover the real
                // stepper error it left behind (a lane that erred on an
                // emission-free row has no other way to surface it)
                let mut error = StageError::Failed("stage lane exited".into());
                while let Some(partial) = helpers_out[j].recv() {
                    if let Err(msg) = partial {
                        error = StageError::Failed(msg);
                        break;
                    }
                }
                fail_stage(&tx, error);
                return;
            }
        }
        let rref = match &*row {
            PipeRow::Int(v) => RowRef::Int(v),
            PipeRow::Bits(v) => RowRef::Bits(v),
        };
        if let Err(e) = stepper.push_row(rref, &mut |o| emitted.push(o)) {
            fail_stage(&tx, StageError::Failed(e.to_string()));
            return;
        }
        rows_in_image += 1;
        if rows_in_image == in_hw {
            rows_in_image = 0;
            counters.images.fetch_add(1, Ordering::Relaxed);
            if crate::obs::profile::enabled() {
                counters.add_image_work(&work);
            }
            if let Err(e) = stepper.flush(&mut |o| emitted.push(o)) {
                fail_stage(&tx, StageError::Failed(e.to_string()));
                return;
            }
            if let Some(t) = tracer {
                t.record_image(images_done, img_start_ns);
            }
            images_done += 1;
        }
        // every lane emits the same schedule: pop exactly one partial per
        // helper per own emission and merge in ascending lane order
        let mut ready: Vec<StepperOut> = Vec::with_capacity(emitted.len());
        for mut out in emitted.drain(..) {
            for h in &helpers_out {
                match h.recv() {
                    Some(Ok(part)) => {
                        if let Err(msg) = merge_partial(&mut out, part) {
                            fail_stage(&tx, StageError::Failed(msg));
                            return;
                        }
                    }
                    Some(Err(msg)) => {
                        fail_stage(&tx, StageError::Failed(msg));
                        return;
                    }
                    None => {
                        fail_stage(&tx, StageError::Failed("stage lane exited".into()));
                        return;
                    }
                }
            }
            ready.push(out);
        }
        StageCounters::add(&counters.busy_ns, busy.elapsed());
        for out in ready {
            let send = Instant::now();
            let ok = forward(&tx, out);
            StageCounters::add(&counters.stall_out_ns, send.elapsed());
            if !ok {
                finish_stage(&tx);
                return;
            }
        }
    }
    // EOS only occurs at an emission boundary (every image ends in a
    // flush emission the lead has already popped partials for), so the
    // helpers are fully drained here; dropping their senders releases them
    finish_stage(&tx);
}

/// A helper lane: consumes broadcast rows, computes its channel
/// partition, sends every partial emission (or its stepper error) back to
/// the lead.  Exits when the lead drops either endpoint.
fn run_helper_lane(
    engine: &Engine,
    index: usize,
    lo: usize,
    hi: usize,
    rx: RowReceiver<Arc<PipeRow>>,
    tx: RowSender<LanePartial>,
) {
    let mut stepper =
        engine.layer_stepper_part(index, lo, hi).expect("bounds derived from the shape");
    let in_hw = stepper.shape().in_hw;
    let mut rows_in_image = 0usize;
    let mut emitted: Vec<StepperOut> = Vec::with_capacity(2);
    while let Some(row) = rx.recv() {
        let rref = match &*row {
            PipeRow::Int(v) => RowRef::Int(v),
            PipeRow::Bits(v) => RowRef::Bits(v),
        };
        if let Err(e) = stepper.push_row(rref, &mut |o| emitted.push(o)) {
            let _ = tx.send(Err(e.to_string()));
            return;
        }
        rows_in_image += 1;
        if rows_in_image == in_hw {
            rows_in_image = 0;
            if let Err(e) = stepper.flush(&mut |o| emitted.push(o)) {
                let _ = tx.send(Err(e.to_string()));
                return;
            }
        }
        for out in emitted.drain(..) {
            if tx.send(Ok(out)).is_err() {
                return; // lead gone: cascade teardown
            }
        }
    }
}

/// Fold a helper lane's partial emission into the lead's: packed rows
/// carry disjoint bit-ranges and OR together; classifier score slices
/// concatenate (helpers arrive in ascending class order).
fn merge_partial(into: &mut StepperOut, part: StepperOut) -> Result<(), String> {
    match (into, part) {
        (StepperOut::Row(a), StepperOut::Row(b)) => {
            if a.len() != b.len() {
                return Err(format!("lane row width mismatch: {} vs {} words", a.len(), b.len()));
            }
            for (x, &y) in a.iter_mut().zip(&b) {
                *x |= y;
            }
            Ok(())
        }
        (StepperOut::Scores(a), StepperOut::Scores(b)) => {
            a.extend_from_slice(&b);
            Ok(())
        }
        _ => Err("lane emission kind mismatch".into()),
    }
}

/// On classifier-stage exit (any reason), latch the pending queue: no
/// image can complete anymore, so in-flight and future tickets must fail
/// instead of waiting forever.  No-op for non-classifier stages.
fn finish_stage(tx: &StageOutput) {
    if let StageOutput::Scores(pending) = tx {
        fail_pending(pending, StageError::Shutdown);
    }
}

/// Forward one emission; `false` means the downstream side is gone.
/// The `stage_emit` fault site lives here: a deterministic injection plan
/// can panic or stall a stage exactly at the emission boundary, the point
/// where a real stepper bug would surface.
fn forward(tx: &StageOutput, out: StepperOut) -> bool {
    if faults::fire(faults::SITE_STAGE_EMIT) {
        return false; // deny: behave as if downstream vanished (cascade)
    }
    match (tx, out) {
        (StageOutput::Rows(tx), StepperOut::Row(row)) => tx.send(PipeRow::Bits(row)).is_ok(),
        (StageOutput::Scores(pending), StepperOut::Scores(scores)) => {
            let slot = lock_recover(pending).queue.pop_front();
            if let Some(reply) = slot {
                // the ticket holder may have given up; that's their right
                let _ = reply.send(Ok(scores));
            }
            true
        }
        // a non-classifier layer emitting into the score sink (or vice
        // versa) is a construction bug caught by PipelineRuntime::new
        (StageOutput::Rows(_), StepperOut::Scores(_))
        | (StageOutput::Scores(_), StepperOut::Row(_)) => {
            unreachable!("stage output kind mismatches layer kind")
        }
    }
}

/// A stage failure (stepper error or dead lane): if this is the
/// classifier stage, latch and fail everything in flight with the real
/// error; the upstream cascade (failed sends, then the feeder) handles
/// the rest.
fn fail_stage(tx: &StageOutput, error: StageError) {
    if let StageOutput::Scores(pending) = tx {
        fail_pending(pending, error);
    }
}
