//! One pipeline stage: a thread wrapping a [`LayerStepper`].
//!
//! The stage consumes input rows from its bounded FIFO as they arrive,
//! pushes them through the stepper, and forwards every emitted output row
//! downstream — so the stage is *concurrently active* with every other
//! stage, the defining property of the paper's §4 streaming architecture.
//! Image boundaries are implicit: a stage knows its layer consumes exactly
//! `in_hw` rows per image, so after the `in_hw`-th row it flushes (bottom
//! border / FC compute) and resets for the next image.  No marker tokens
//! means no marker/poison races with full queues.
//!
//! Shutdown is edge-triggered in both directions:
//! * upstream closure (sender dropped) — the stage drains buffered rows,
//!   then exits and drops its own sender, cascading end-of-stream down
//!   the pipe;
//! * downstream closure (receiver dropped) — the stage's forward `send`
//!   fails, it exits and drops its receiver, cascading the closure up the
//!   pipe until the feeder observes it.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::bcnn::engine::{LayerStepper, RowRef, StepperOut};

/// A row in flight between stages: raw integers into the first layer,
/// packed bits everywhere else.
#[derive(Debug, Clone)]
pub enum PipeRow {
    Int(Vec<i32>),
    Bits(Vec<u64>),
}

/// Per-image completion result delivered to a submit ticket.
pub type ScoreResult = Result<Vec<f32>, String>;

/// FIFO-ordered reply senders for images in flight, plus the pipeline's
/// failure latch.  The feeder registers one sender per admitted image
/// *before* feeding its rows; the classifier stage pops one per completed
/// image.  The linear pipeline preserves image order, so front-of-queue
/// is always the next image to finish.
///
/// The latch makes "no ticket ever hangs" airtight: once the classifier
/// stage exits (shutdown drain or failure cascade) it calls
/// [`fail_pending`], which — atomically with [`register_reply`] — fails
/// every queued ticket AND every ticket registered afterwards.  Without
/// the latch, an image fed while a *mid*-pipeline stage was already dead
/// would vanish between live stages and its ticket would wait forever.
pub struct PendingState {
    queue: VecDeque<mpsc::Sender<ScoreResult>>,
    /// `Some(reason)` once no new image can ever complete.
    failed: Option<String>,
}

/// Shared handle to the pending-reply state.
pub type PendingReplies = Arc<Mutex<PendingState>>;

/// Fresh pending-reply state (no images in flight, latch clear).
pub fn new_pending() -> PendingReplies {
    Arc::new(Mutex::new(PendingState { queue: VecDeque::new(), failed: None }))
}

/// Register an admitted image's reply sender.  If the pipeline has
/// already failed, the ticket is failed immediately instead of being
/// queued behind a classifier that will never pop it.
pub fn register_reply(pending: &PendingReplies, reply: mpsc::Sender<ScoreResult>) {
    let mut state = pending.lock().unwrap();
    match &state.failed {
        Some(reason) => {
            let _ = reply.send(Err(reason.clone()));
        }
        None => state.queue.push_back(reply),
    }
}

/// Latch the failure `reason` (first caller wins) and fail every ticket
/// currently in flight.
pub fn fail_pending(pending: &PendingReplies, reason: &str) {
    let mut state = pending.lock().unwrap();
    if state.failed.is_none() {
        state.failed = Some(reason.to_string());
    }
    let reason = state.failed.clone().expect("latched above");
    for reply in state.queue.drain(..) {
        let _ = reply.send(Err(reason.clone()));
    }
}

/// Where a stage's emissions go: another stage's FIFO, or (for the
/// classifier stage) the pending-reply queue.
pub enum StageOutput {
    Rows(super::fifo::RowSender<PipeRow>),
    Scores(PendingReplies),
}

/// Run one stage to completion.  Returns when the input stream closes
/// (normal drain) or the downstream side disappears (abort cascade).
pub fn run_stage(
    stepper: &mut LayerStepper<'_>,
    rx: super::fifo::RowReceiver<PipeRow>,
    tx: StageOutput,
) {
    let in_hw = stepper.shape().in_hw;
    let mut rows_in_image = 0usize;
    // a push emits at most one row and a flush at most one more, so the
    // staging buffer never grows past 2
    let mut emitted: Vec<StepperOut> = Vec::with_capacity(2);

    while let Some(row) = rx.recv() {
        let rref = match &row {
            PipeRow::Int(v) => RowRef::Int(v),
            PipeRow::Bits(v) => RowRef::Bits(v),
        };
        if let Err(e) = stepper.push_row(rref, &mut |o| emitted.push(o)) {
            fail_stage(&tx, &e);
            return;
        }
        rows_in_image += 1;
        if rows_in_image == in_hw {
            rows_in_image = 0;
            if let Err(e) = stepper.flush(&mut |o| emitted.push(o)) {
                fail_stage(&tx, &e);
                return;
            }
        }
        for out in emitted.drain(..) {
            if !forward(&tx, out) {
                finish_stage(&tx);
                return; // downstream gone: cascade the closure upstream
            }
        }
    }
    // input closed (shutdown drain or upstream failure): dropping rx/tx
    // cascades the closure; if this is the classifier, latch so nothing
    // registered from now on can wait on a stage that no longer runs
    finish_stage(&tx);
}

/// On classifier-stage exit (any reason), latch the pending queue: no
/// image can complete anymore, so in-flight and future tickets must fail
/// instead of waiting forever.  No-op for non-classifier stages.
fn finish_stage(tx: &StageOutput) {
    if let StageOutput::Scores(pending) = tx {
        fail_pending(pending, "pipeline shut down with the image in flight");
    }
}

/// Forward one emission; `false` means the downstream side is gone.
fn forward(tx: &StageOutput, out: StepperOut) -> bool {
    match (tx, out) {
        (StageOutput::Rows(tx), StepperOut::Row(row)) => tx.send(PipeRow::Bits(row)).is_ok(),
        (StageOutput::Scores(pending), StepperOut::Scores(scores)) => {
            let slot = pending.lock().unwrap().queue.pop_front();
            if let Some(reply) = slot {
                // the ticket holder may have given up; that's their right
                let _ = reply.send(Ok(scores));
            }
            true
        }
        // a non-classifier layer emitting into the score sink (or vice
        // versa) is a construction bug caught by PipelineRuntime::new
        (StageOutput::Rows(_), StepperOut::Scores(_))
        | (StageOutput::Scores(_), StepperOut::Row(_)) => {
            unreachable!("stage output kind mismatches layer kind")
        }
    }
}

/// A stepper error (impossible for rows produced by validated upstream
/// stages, but never silently swallowed): if this is the classifier
/// stage, latch and fail everything in flight with the real error; the
/// upstream cascade (failed sends, then the feeder) handles the rest.
fn fail_stage(tx: &StageOutput, error: &anyhow::Error) {
    if let StageOutput::Scores(pending) = tx {
        fail_pending(pending, &format!("pipeline stage failed: {error}"));
    }
}
