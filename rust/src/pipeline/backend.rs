//! [`PipelineBackend`] — the layer-pipeline runtime behind the
//! coordinator's [`Backend`] trait, so the sharded worker pool can serve
//! from a row-streaming pipeline instead of the sequential engine.
//!
//! `infer_batch` submits every image of the batch before waiting on any
//! of them, so the whole batch is in flight across the stages at once;
//! but unlike a batch-parallel device, the pipeline gains nothing *from*
//! the batching — single images submitted back-to-back through
//! [`PipelineRuntime::submit`] sustain the same throughput (the paper's
//! batch-insensitivity claim, measured in `benches/fig7_batch_sweep.rs`).
//!
//! Each backend replica owns its own runtime — with a *stage budget*
//! ([`PipelineBackend::with_stage_budget`]) the per-stage lane counts are
//! balanced by a host calibration pass ([`StagePlan::balanced`]), so the
//! bottleneck layer gets more channel-partitioned lanes exactly the way
//! the paper gives it more `P`.  A replica runs
//! `total lanes + 1 (feeder)` threads; size a sharded pool accordingly.

use anyhow::Result;

use crate::bcnn::Engine;
use crate::coordinator::backend::{Backend, BatchResult};
use crate::model::BcnnModel;
use crate::pipeline::plan::StagePlan;
use crate::pipeline::runtime::PipelineRuntime;
use crate::pipeline::stage::StageSnapshot;

/// Row-streaming layer-pipeline inference backend.
pub struct PipelineBackend {
    runtime: PipelineRuntime,
}

impl PipelineBackend {
    /// Validate the model and spawn the unbalanced (one lane per stage)
    /// pipeline.  `inflight` is the runtime's admission window (see
    /// [`PipelineRuntime::new`]).
    pub fn new(model: BcnnModel, inflight: usize) -> Result<Self> {
        Self::with_stage_budget(model, inflight, 0)
    }

    /// Like [`PipelineBackend::new`], but with `stage_budget > 0` the
    /// per-stage lane counts are throughput-balanced under that total
    /// lane budget (calibration + water-filling; `0` keeps one lane per
    /// stage).
    pub fn with_stage_budget(
        model: BcnnModel,
        inflight: usize,
        stage_budget: usize,
    ) -> Result<Self> {
        let engine = Engine::new(model)?;
        let runtime = if stage_budget == 0 {
            PipelineRuntime::new(engine, inflight)?
        } else {
            let plan = StagePlan::balanced(&engine, stage_budget)?;
            PipelineRuntime::with_plan(engine, inflight, plan)?
        };
        Ok(Self { runtime })
    }

    /// Spawn with an explicit, already-chosen [`StagePlan`].
    pub fn with_plan(model: BcnnModel, inflight: usize, plan: StagePlan) -> Result<Self> {
        let engine = Engine::new(model)?;
        Ok(Self { runtime: PipelineRuntime::with_plan(engine, inflight, plan)? })
    }

    pub fn runtime(&self) -> &PipelineRuntime {
        &self.runtime
    }
}

impl Backend for PipelineBackend {
    fn name(&self) -> &str {
        "pipeline"
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchResult> {
        // submit everything first: the whole batch streams through the
        // stages concurrently, tickets complete in submission order
        let mut tickets = Vec::with_capacity(images.len());
        for img in images {
            // the runtime's feeder slices rows on its own thread, so it
            // needs an owned copy (the only copy on this path)
            tickets.push(self.runtime.submit(img.to_vec())?);
        }
        let scores = tickets
            .into_iter()
            .map(|t| t.wait())
            .collect::<Result<Vec<_>>>()?;
        Ok(BatchResult { scores, modeled_device_time: None })
    }

    fn stage_stats(&self) -> Vec<StageSnapshot> {
        self.runtime.stage_stats()
    }

    fn kernel(&self) -> &'static str {
        self.runtime.kernel_name()
    }
}
