//! [`PipelineBackend`] — the layer-pipeline runtime behind the
//! coordinator's [`Backend`] trait, so the sharded worker pool can serve
//! from a row-streaming pipeline instead of the sequential engine.
//!
//! `infer_batch` submits every image of the batch before waiting on any
//! of them, so the whole batch is in flight across the stages at once;
//! but unlike a batch-parallel device, the pipeline gains nothing *from*
//! the batching — single images submitted back-to-back through
//! [`PipelineRuntime::submit`] sustain the same throughput (the paper's
//! batch-insensitivity claim, measured in `benches/fig7_batch_sweep.rs`).
//!
//! Each backend replica owns its own runtime — with a *stage budget*
//! ([`PipelineBackend::with_stage_budget`]) the per-stage lane counts are
//! balanced by a host calibration pass ([`StagePlan::balanced`]), so the
//! bottleneck layer gets more channel-partitioned lanes exactly the way
//! the paper gives it more `P`.  A replica runs
//! `total lanes + 1 (feeder)` threads; size a sharded pool accordingly.
//!
//! # Degradation
//!
//! A stage-lane death (panic contained by the runtime's per-stage
//! wrapper, or a stepper failure) permanently fails the runtime — by
//! design, since a linear pipeline with a dead stage can never complete
//! another image.  Rather than turning every subsequent request into an
//! error, the backend *degrades*: it tears the dead runtime down and
//! re-runs the affected batch — and serves all later ones — on the
//! bit-exact sequential [`Engine`] ([`NativeBackend`]).  Same weights,
//! same packed-u64 numerics, so clients see identical scores, only the
//! stage-level concurrency is lost.  The shard worker reads the
//! [`Backend::failovers`]/[`Backend::crashes`] deltas into its metrics,
//! making the degradation observable instead of silent.

use anyhow::{anyhow, Result};

use crate::bcnn::Engine;
use crate::coordinator::backend::{Backend, BatchResult, NativeBackend};
use crate::model::BcnnModel;
use crate::pipeline::plan::StagePlan;
use crate::pipeline::runtime::PipelineRuntime;
use crate::pipeline::stage::StageSnapshot;

/// Row-streaming layer-pipeline inference backend with engine fallback.
pub struct PipelineBackend {
    /// `None` once the pipeline has died and the backend degraded.
    runtime: Option<PipelineRuntime>,
    /// Kept to build the bit-exact fallback engine on demand.
    model: BcnnModel,
    /// The degraded path, built on first failover.
    fallback: Option<NativeBackend>,
    /// Last stage stats observed before the runtime was torn down, so
    /// observability survives degradation.
    last_stage_stats: Vec<StageSnapshot>,
    kernel: &'static str,
    failovers: u64,
    crashes: u64,
}

impl PipelineBackend {
    /// Validate the model and spawn the unbalanced (one lane per stage)
    /// pipeline.  `inflight` is the runtime's admission window (see
    /// [`PipelineRuntime::new`]).
    pub fn new(model: BcnnModel, inflight: usize) -> Result<Self> {
        Self::with_stage_budget(model, inflight, 0)
    }

    /// Like [`PipelineBackend::new`], but with `stage_budget > 0` the
    /// per-stage lane counts are throughput-balanced under that total
    /// lane budget (calibration + water-filling; `0` keeps one lane per
    /// stage).
    pub fn with_stage_budget(
        model: BcnnModel,
        inflight: usize,
        stage_budget: usize,
    ) -> Result<Self> {
        let engine = Engine::new(model.clone())?;
        let runtime = if stage_budget == 0 {
            PipelineRuntime::new(engine, inflight)?
        } else {
            let plan = StagePlan::balanced(&engine, stage_budget)?;
            PipelineRuntime::with_plan(engine, inflight, plan)?
        };
        Ok(Self::from_runtime(model, runtime))
    }

    /// Spawn with an explicit, already-chosen [`StagePlan`].
    pub fn with_plan(model: BcnnModel, inflight: usize, plan: StagePlan) -> Result<Self> {
        let engine = Engine::new(model.clone())?;
        let runtime = PipelineRuntime::with_plan(engine, inflight, plan)?;
        Ok(Self::from_runtime(model, runtime))
    }

    fn from_runtime(model: BcnnModel, runtime: PipelineRuntime) -> Self {
        let kernel = runtime.kernel_name();
        Self {
            runtime: Some(runtime),
            model,
            fallback: None,
            last_stage_stats: Vec::new(),
            kernel,
            failovers: 0,
            crashes: 0,
        }
    }

    /// The live pipeline runtime, or `None` once the backend has degraded
    /// to the sequential engine path.
    pub fn runtime(&self) -> Option<&PipelineRuntime> {
        self.runtime.as_ref()
    }

    /// True once a stage death has pushed this replica onto the
    /// sequential-engine fallback path.
    pub fn degraded(&self) -> bool {
        self.runtime.is_none()
    }

    /// Tear down the dead runtime (folding its crash count and final
    /// stage stats into ours) and build the sequential fallback.
    fn degrade(&mut self, why: &str) -> Result<()> {
        if let Some(rt) = self.runtime.take() {
            self.crashes += rt.crashes();
            self.last_stage_stats = rt.stage_stats();
            eprintln!("pipeline backend degrading to engine path: {why}");
            // rt drops here: joins stage threads, fails stragglers typed
        }
        if self.fallback.is_none() {
            self.fallback = Some(NativeBackend::new(self.model.clone())?);
        }
        Ok(())
    }
}

impl Backend for PipelineBackend {
    fn name(&self) -> &str {
        if self.degraded() {
            "pipeline-degraded"
        } else {
            "pipeline"
        }
    }

    fn infer_batch(&mut self, images: &[&[i32]]) -> Result<BatchResult> {
        self.infer_batch_traced(images, &[])
    }

    /// The traced entry point the coordinator's shard worker uses: each
    /// image keeps its request's trace ID, so per-stage spans in the
    /// runtime's `pipe{N}/stage{L}` rings correlate with the request's
    /// coordinator spans.  Images without an ID (direct `infer_batch`
    /// callers) get a freshly minted one.
    fn infer_batch_traced(&mut self, images: &[&[i32]], trace_ids: &[u64]) -> Result<BatchResult> {
        if let Some(runtime) = &self.runtime {
            // submit everything first: the whole batch streams through the
            // stages concurrently, tickets complete in submission order
            let mut tickets = Vec::with_capacity(images.len());
            let mut submit_err = None;
            for (i, img) in images.iter().enumerate() {
                let trace_id = match trace_ids.get(i).copied().filter(|&t| t != 0) {
                    Some(t) => t,
                    None => crate::obs::mint_trace_id(),
                };
                // the runtime's feeder slices rows on its own thread, so it
                // needs an owned copy (the only copy on this path)
                match runtime.submit_traced(img.to_vec(), trace_id) {
                    Ok(t) => tickets.push(t),
                    Err(e) => {
                        submit_err = Some(e);
                        break;
                    }
                }
            }
            let mut wait_err = None;
            let mut scores = Vec::with_capacity(images.len());
            if submit_err.is_none() {
                for t in tickets {
                    match t.wait() {
                        Ok(s) => scores.push(s),
                        Err(e) => {
                            wait_err = Some(e);
                            break;
                        }
                    }
                }
            }
            match (submit_err, wait_err) {
                (None, None) => {
                    return Ok(BatchResult { scores, modeled_device_time: None });
                }
                (Some(e), _) | (_, Some(e)) => {
                    // a stage died with this batch in flight: degrade and
                    // re-run the WHOLE batch on the bit-exact engine so
                    // the caller still gets every score
                    self.degrade(&e.to_string())?;
                }
            }
        }
        // everything from here on is served via the degradation path
        self.failovers += images.len() as u64;
        let fallback = self
            .fallback
            .as_mut()
            .ok_or_else(|| anyhow!("pipeline backend has no fallback engine"))?;
        fallback.infer_batch(images)
    }

    fn stage_stats(&self) -> Vec<StageSnapshot> {
        match &self.runtime {
            Some(rt) => rt.stage_stats(),
            None => self.last_stage_stats.clone(),
        }
    }

    fn kernel(&self) -> &'static str {
        self.kernel
    }

    fn failovers(&self) -> u64 {
        self.failovers
    }

    fn crashes(&self) -> u64 {
        self.crashes + self.runtime.as_ref().map_or(0, |rt| rt.crashes())
    }
}
