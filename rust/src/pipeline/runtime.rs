//! The layer-pipeline runtime: every layer a concurrently-active stage,
//! each stage a plan-sized lane group.
//!
//! ```text
//! submit(image) ─► admission queue (inflight) ─► feeder thread
//!     feeder: image -> rows ─► FIFO(2·hw₀ rows) ─► stage 0 (layer 0)
//!                                 │ rows stream row-by-row
//!                                 ▼
//!                              FIFO(2·hw₁) ─► stage 1 ─► … ─► classifier
//!                               lanes: P₁ channel partitions   stage
//!                               (StagePlan, §4.3 executed)       │ scores
//!                                                                ▼
//!                                              pending-reply queue ─► ticket
//! ```
//!
//! Each inter-stage FIFO holds [`crate::fpga::channel::CHANNEL_SLOTS`]
//! images' worth of rows ([`fifo_rows`]), mirroring the paper's §4.3
//! double-buffered channels: a stage can run at most one full feature map
//! ahead of its consumer, and *multiple images are in flight across the
//! stages simultaneously* — which is why throughput is set by the slowest
//! stage (eq. 12's `max(C_L)`), not by the sum of layers, and why it does
//! not depend on how requests are grouped into batches.  A [`StagePlan`]
//! then attacks `max(C_L)` itself: the bottleneck stage gets more
//! channel-partitioned lanes (the paper's per-layer `P`), so the slowest
//! stage's service time drops toward the balanced optimum.
//!
//! Shutdown has no poison tokens: dropping the runtime closes the
//! admission queue; the feeder finishes the images already admitted and
//! exits; end-of-stream then cascades stage by stage (each stage drains
//! its FIFO before observing closure; lane groups release their helper
//! lanes the same way), the classifier answers every completed image, and
//! the runtime joins all threads.  Tickets for images that can no longer
//! complete fail with a typed [`StageError`] — never a hang (see
//! `pipeline_integration.rs::drop_with_images_in_flight`).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::bcnn::engine::LayerShape;
use crate::bcnn::Engine;
use crate::fpga::channel::fifo_rows;
use crate::obs::{self, SpanRing, StageTracer, TraceLog};
use crate::pipeline::fifo::{bounded, RowSender};
use crate::pipeline::plan::StagePlan;
use crate::pipeline::stage::{
    fail_pending, new_pending, pending_failure, register_reply, run_stage_group, PendingReplies,
    PipeRow, ScoreResult, StageCounters, StageError, StageOutput, StageSnapshot,
};
use crate::util::sync::panic_message;

/// An admitted image on its way to the feeder: pixels, the request's
/// trace ID, and the reply sender.
type FeedMsg = (Vec<i32>, u64, mpsc::Sender<ScoreResult>);

/// Capacity of the feeder's image-index → trace-ID log.  Far above any
/// plausible in-flight image count (admission window + one image per
/// stage FIFO), so by the time a slot is overwritten the image that
/// owned it has long since left the pipe.
const TRACE_LOG_CAPACITY: usize = 1024;

/// Receipt for one submitted image; [`ScoreTicket::wait`] blocks for its
/// scores.  Tickets complete in submission order.
pub struct ScoreTicket {
    rx: mpsc::Receiver<ScoreResult>,
}

impl ScoreTicket {
    /// Block until the image's scores arrive (or the pipeline fails /
    /// shuts down — an error, never a hang).
    pub fn wait(self) -> Result<Vec<f32>> {
        self.wait_typed().map_err(anyhow::Error::new)
    }

    /// [`ScoreTicket::wait`] with the typed failure reason, so callers
    /// can distinguish shutdown-in-flight (resubmit elsewhere) from a
    /// stage failure (the image stream itself was rejected) without
    /// string-matching.
    pub fn wait_typed(self) -> std::result::Result<Vec<f32>, StageError> {
        match self.rx.recv() {
            Ok(result) => result,
            Err(_) => Err(StageError::Shutdown),
        }
    }

    /// Non-blocking probe (used by the open-window bench driver).
    pub fn try_wait(&self) -> Option<Result<Vec<f32>>> {
        match self.rx.try_recv() {
            Ok(Ok(scores)) => Some(Ok(scores)),
            Ok(Err(error)) => Some(Err(anyhow::Error::new(error))),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow::Error::new(StageError::Shutdown)))
            }
        }
    }
}

/// A running row-streaming layer pipeline over one [`Engine`].
pub struct PipelineRuntime {
    /// `None` once shutdown has begun (admission closed).
    feeder_tx: Option<RowSender<FeedMsg>>,
    threads: Vec<JoinHandle<()>>,
    pending: PendingReplies,
    shapes: Vec<LayerShape>,
    fifo_caps: Vec<usize>,
    /// The plan actually applied (lane counts clamped to `[1, out_c]`).
    plan: StagePlan,
    counters: Vec<Arc<StageCounters>>,
    inflight: usize,
    input_len: usize,
    /// Name of the bitwise SIMD kernel the engine dispatches to, captured
    /// at spawn (the engine itself moves into the stage threads).
    kernel: &'static str,
    /// Stage-thread panics contained by the per-stage `catch_unwind`
    /// wrappers (cumulative since spawn).
    crashes: Arc<AtomicU64>,
}

impl PipelineRuntime {
    /// Spawn the unbalanced pipeline: one lane per layer stage plus the
    /// feeder.  `inflight` is the admission-window depth: how many whole
    /// images may be queued for feeding beyond those already streaming
    /// through the stages (clamped to >= 1).
    pub fn new(engine: Engine, inflight: usize) -> Result<Self> {
        let layers = engine.layer_shapes().len();
        Self::with_plan(engine, inflight, StagePlan::uniform(layers, 1))
    }

    /// Spawn a plan-shaped pipeline: stage `l` runs
    /// `plan.lanes_per_layer[l]` channel-partitioned lanes (clamped to
    /// `[1, out_c]`).  The total thread count is
    /// `plan lanes + 1` (feeder); see [`StagePlan::balanced`] for
    /// choosing the lane counts under a thread budget.
    pub fn with_plan(engine: Engine, inflight: usize, plan: StagePlan) -> Result<Self> {
        let shapes = engine.layer_shapes();
        let n = shapes.len();
        match shapes.last() {
            None => bail!("model has no layers"),
            Some(last) if !last.scores => bail!("model's final layer is not a classifier"),
            _ => {}
        }
        if let Some(i) = shapes[..n - 1].iter().position(|s| s.scores) {
            bail!("classifier layer {i} is not last");
        }
        if plan.lanes_per_layer.len() != n {
            bail!(
                "stage plan covers {} layers, model has {n}",
                plan.lanes_per_layer.len()
            );
        }
        // the plan as executed: lane counts clamped to what the layer can
        // actually split across
        let plan = StagePlan {
            lanes_per_layer: plan
                .lanes_per_layer
                .iter()
                .zip(&shapes)
                .map(|(&l, s)| l.clamp(1, s.out_c.max(1)))
                .collect(),
        };

        let inflight = inflight.max(1);
        let input_len = shapes[0].in_hw * shapes[0].in_hw * shapes[0].in_c;
        let kernel = engine.kernel().name();
        let engine = Arc::new(engine);
        let pending = new_pending();
        let counters: Vec<Arc<StageCounters>> =
            (0..n).map(|_| Arc::new(StageCounters::default())).collect();
        let crashes = Arc::new(AtomicU64::new(0));
        // one tracing track per stage (`pipe{instance}/stage{i}`); the
        // feeder's trace log maps the k-th fed image to its trace ID so
        // every stage can label its per-image spans without the rows
        // carrying IDs
        let instance = obs::next_instance_id();
        let trace_log = Arc::new(TraceLog::new(TRACE_LOG_CAPACITY));
        let mut threads = Vec::with_capacity(n + 1);

        // build the inter-stage FIFOs front to back, then hand each stage
        // its receiver and the next stage's sender
        let fifo_caps: Vec<usize> = shapes.iter().map(|s| fifo_rows(s.in_hw)).collect();
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for &cap in &fifo_caps {
            let (tx, rx) = bounded::<PipeRow>(cap);
            senders.push(tx);
            receivers.push(rx);
        }
        // stage i sends into stage i+1's FIFO; the classifier stage sends
        // into the pending-reply queue.  Walk back to front so each
        // iteration can move the next stage's sender out of the vec.
        let mut next_tx: Option<RowSender<PipeRow>> = None;
        for i in (0..n).rev() {
            let rx = receivers.pop().expect("one receiver per stage");
            let tx = match next_tx.take() {
                Some(tx) => StageOutput::Rows(tx),
                None => StageOutput::Scores(Arc::clone(&pending)),
            };
            next_tx = senders.pop();
            let engine = Arc::clone(&engine);
            let lanes = plan.lanes_per_layer[i];
            let ctr = Arc::clone(&counters[i]);
            let pending = Arc::clone(&pending);
            let crash_ctr = Arc::clone(&crashes);
            // the ring's Arc lives inside the stage thread, so the track
            // deregisters from the global registry when the stage exits
            let tracer = StageTracer::new(
                SpanRing::new(format!("pipe{instance}/stage{i}"), obs::DEFAULT_RING_CAPACITY),
                Arc::clone(&trace_log),
                instance,
                i as u32,
            );
            threads.push(
                std::thread::Builder::new()
                    .name(format!("pipeline-stage-{i}"))
                    .spawn(move || {
                        // Contain stage-thread panics (a stepper bug, an
                        // injected fault): the unwind drops the stage's FIFO
                        // endpoints, cascading closure both ways, and the
                        // typed latch below guarantees every in-flight and
                        // future ticket fails instead of hanging.  A helper
                        // lane's panic re-raises through `thread::scope`
                        // into the lead, so one wrapper per stage covers
                        // the whole lane group.
                        let result = catch_unwind(AssertUnwindSafe(|| {
                            run_stage_group(&engine, i, lanes, rx, tx, &ctr, Some(&tracer))
                        }));
                        if let Err(payload) = result {
                            crash_ctr.fetch_add(1, Ordering::Relaxed);
                            fail_pending(
                                &pending,
                                StageError::Failed(format!(
                                    "stage {i} panicked: {}",
                                    panic_message(payload.as_ref())
                                )),
                            );
                        }
                    })
                    .expect("spawn pipeline stage"),
            );
        }
        let stage0_tx = next_tx.expect("stage 0 sender");

        // the feeder: admitted images -> rows into stage 0
        let (feeder_tx, feeder_rx) = bounded::<FeedMsg>(inflight);
        let feed_shape = shapes[0];
        threads.push(
            std::thread::Builder::new()
                .name("pipeline-feeder".into())
                .spawn({
                    let pending = Arc::clone(&pending);
                    let trace_log = Arc::clone(&trace_log);
                    move || {
                        let row_len = feed_shape.in_hw * feed_shape.in_c;
                        let mut fed = 0u64;
                        while let Some((image, trace_id, reply)) = feeder_rx.recv() {
                            // publish the image's trace ID BEFORE feeding
                            // any rows: stages index the log by completed-
                            // image count, which can never pass the feeder
                            trace_log.set(fed, trace_id);
                            fed += 1;
                            // register the reply BEFORE feeding any rows so
                            // the classifier pops replies in image order
                            // (and so an already-failed pipeline fails the
                            // ticket immediately instead of queueing it)
                            register_reply(&pending, reply);
                            let mut aborted = false;
                            for row in image.chunks(row_len) {
                                if stage0_tx.send(PipeRow::Int(row.to_vec())).is_err() {
                                    aborted = true;
                                    break;
                                }
                            }
                            if aborted {
                                // a stage exited: fail everything in flight
                                // and everything still being admitted
                                fail_pending(&pending, StageError::Shutdown);
                                while let Some((_image, _trace_id, reply)) = feeder_rx.recv() {
                                    let _ = reply.send(Err(StageError::Shutdown));
                                }
                                return;
                            }
                        }
                        // normal shutdown: dropping stage0_tx cascades
                        // end-of-stream down the stages
                    }
                })
                .expect("spawn pipeline feeder"),
        );

        Ok(Self {
            feeder_tx: Some(feeder_tx),
            threads,
            pending,
            shapes,
            fifo_caps,
            plan,
            counters,
            inflight,
            input_len,
            kernel,
            crashes,
        })
    }

    /// Submit one image (`hw*hw*c` NHWC values).  Blocks while the
    /// admission window is full — bounded memory, explicit backpressure —
    /// and returns a ticket that completes in submission order.  Mints a
    /// fresh trace ID; callers that already hold one (the coordinator's
    /// traced batch path) use [`PipelineRuntime::submit_traced`].
    pub fn submit(&self, image: Vec<i32>) -> Result<ScoreTicket> {
        self.submit_traced(image, obs::mint_trace_id())
    }

    /// [`PipelineRuntime::submit`] with a caller-supplied trace ID, so the
    /// image's per-stage spans correlate with the request's coordinator
    /// spans under one end-to-end identity.
    pub fn submit_traced(&self, image: Vec<i32>, trace_id: u64) -> Result<ScoreTicket> {
        if image.len() != self.input_len {
            bail!("image size {} != {}", image.len(), self.input_len);
        }
        let Some(feeder_tx) = &self.feeder_tx else {
            bail!("pipeline is shut down");
        };
        let (tx, rx) = mpsc::channel();
        feeder_tx
            .send((image, trace_id, tx))
            .map_err(|_| anyhow!("pipeline is shut down"))?;
        Ok(ScoreTicket { rx })
    }

    /// Per-layer I/O geometry (same order as the stages).
    pub fn shapes(&self) -> &[LayerShape] {
        &self.shapes
    }

    /// Input-FIFO row capacity per stage — derived from the §4.3 channel
    /// geometry ([`fifo_rows`]); the pinning test asserts this.  Lane
    /// counts do not change it: partitioned lanes share the stage's one
    /// inter-layer channel.
    pub fn stage_fifo_capacities(&self) -> &[usize] {
        &self.fifo_caps
    }

    /// The stage plan as executed (lane counts clamped to `[1, out_c]`).
    pub fn plan(&self) -> &StagePlan {
        &self.plan
    }

    /// Admission-window depth.
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    /// Name of the bitwise SIMD kernel every stage lane dispatches to
    /// (lanes share the spawning engine, so there is exactly one).
    pub fn kernel_name(&self) -> &'static str {
        self.kernel
    }

    /// Total threads: every stage's lanes plus the feeder.
    pub fn thread_count(&self) -> usize {
        self.plan.total_lanes() + 1
    }

    /// Stage-thread panics contained since spawn (0 on a healthy runtime).
    pub fn crashes(&self) -> u64 {
        self.crashes.load(Ordering::Relaxed)
    }

    /// The latched pipeline failure, if any: `Some` once no future image
    /// can complete on this runtime (a stage died or shutdown began).
    /// [`crate::pipeline::PipelineBackend`] polls this to decide when to
    /// degrade to the bit-exact engine path.
    pub fn failure(&self) -> Option<StageError> {
        pending_failure(&self.pending)
    }

    /// Live per-stage busy/stall snapshot — the bottleneck stage is the
    /// one with high `busy` while its neighbours stall (FIFO-wait).
    pub fn stage_stats(&self) -> Vec<StageSnapshot> {
        self.counters
            .iter()
            .enumerate()
            .map(|(i, c)| c.snapshot(i, self.plan.lanes_per_layer[i]))
            .collect()
    }

    /// Close admission, let the stages drain every admitted image, join
    /// all threads, and fail any ticket that could not complete.
    pub fn shutdown(mut self) {
        self.shutdown_now();
    }

    fn shutdown_now(&mut self) {
        // closing the admission queue makes the feeder exit after the
        // images it has already accepted; EOS then cascades through the
        // stages, which drain their FIFOs before exiting, and the
        // classifier latches the pending queue on its way out
        self.feeder_tx = None;
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // belt and braces: if the threads were already gone the latch is
        // set, but make sure no ticket can be left waiting either way
        fail_pending(&self.pending, StageError::Shutdown);
    }
}

impl Drop for PipelineRuntime {
    fn drop(&mut self) {
        self.shutdown_now();
    }
}
