//! Row-streaming layer-pipeline inference runtime (paper §4, fig. 4/7).
//!
//! The repo's `fpga::stream` simulator *models* the paper's headline
//! property — all layers concurrently active behind double-buffered
//! channels, so throughput is eq. 12's `max(C_L)` and independent of
//! batch size — but the serving engine executed layers sequentially per
//! image.  This module makes the property real on the host:
//!
//! * [`fifo`] — bounded SPSC row FIFOs sized from the §4.3 channel
//!   geometry ([`crate::fpga::channel::fifo_rows`]): the software
//!   equivalent of the ping-pong inter-layer memories.
//! * [`stage`] — one thread per layer wrapping the engine's row-granular
//!   [`crate::bcnn::engine::LayerStepper`]; a stage starts emitting
//!   output rows while its input image is still arriving.
//! * [`runtime`] — [`PipelineRuntime`]: feeder + stages + in-order score
//!   tickets, bounded admission, poison-free cascade shutdown.
//! * [`backend`] — [`PipelineBackend`]: the runtime behind the
//!   coordinator's `Backend` trait (`--backend pipeline` in the CLI).
//!
//! * [`plan`] — [`StagePlan`]: per-stage lane counts, balanced the way
//!   the paper balances per-layer `P` (§4.3, Table 3) — by calibration
//!   ([`StagePlan::balanced`]) or from the optimizer's plan
//!   ([`StagePlan::from_plan`]); stages become channel-partitioned lane
//!   groups so the bottleneck layer's service time drops toward the
//!   balanced optimum.
//!
//! The FINN-style dataflow scheduling (one compute engine per layer,
//! rate-matched by buffer depth) is what makes serving throughput
//! batch-insensitive: a stream of individual requests keeps every stage
//! busy just as well as a large batch does.  `benches/fig7_batch_sweep.rs`
//! measures exactly that signature — and, since the stage-balance PR, the
//! balanced-vs-unbalanced throughput delta on a deliberately skewed
//! model.

pub mod backend;
pub mod fifo;
pub mod plan;
pub mod runtime;
pub mod stage;

pub use backend::PipelineBackend;
pub use plan::StagePlan;
pub use runtime::{PipelineRuntime, ScoreTicket};
pub use stage::{PipeRow, StageError, StageSnapshot};
