//! BCNN network configurations — the shape algebra everything else
//! (engine, FPGA simulator, optimizer, GPU model) is derived from.
//!
//! `NetConfig::table2()` is the paper's Table 2 network verbatim; all conv
//! layers are 3x3, stride 1, 1-pixel zero padding (paper §2.5), max-pool is
//! 2x2/2 after layers 2, 4, 6.

/// One binary conv layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvSpec {
    pub out_channels: usize,
    /// 2x2/2 max-pool after this layer's convolution.
    pub pool: bool,
}

/// Resolved conv-layer geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    pub in_c: usize,
    pub out_c: usize,
    /// Spatial resolution the convolution runs at (pre-pool).
    pub in_hw: usize,
    /// Resolution after optional pooling.
    pub out_hw: usize,
    pub pool: bool,
}

/// A BCNN network description (paper Table 2 family).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetConfig {
    pub name: String,
    pub conv: Vec<ConvSpec>,
    /// Hidden fully-connected widths (the classifier layer is appended).
    pub fc: Vec<usize>,
    pub classes: usize,
    pub input_hw: usize,
    pub input_channels: usize,
    /// First-layer input precision; paper §3.1 rescales inputs to 6 bits.
    pub input_bits: usize,
}

impl NetConfig {
    /// The paper's Table 2 CIFAR-10 BCNN.
    pub fn table2() -> Self {
        Self {
            name: "cifar10-table2".into(),
            conv: vec![
                ConvSpec { out_channels: 128, pool: false },
                ConvSpec { out_channels: 128, pool: true },
                ConvSpec { out_channels: 256, pool: false },
                ConvSpec { out_channels: 256, pool: true },
                ConvSpec { out_channels: 512, pool: false },
                ConvSpec { out_channels: 512, pool: true },
            ],
            fc: vec![1024, 1024],
            classes: 10,
            input_hw: 32,
            input_channels: 3,
            input_bits: 6,
        }
    }

    /// Scaled-down variant used for the trained end-to-end run.
    pub fn small() -> Self {
        Self {
            name: "synthetic-small".into(),
            conv: vec![
                ConvSpec { out_channels: 32, pool: false },
                ConvSpec { out_channels: 32, pool: true },
                ConvSpec { out_channels: 64, pool: false },
                ConvSpec { out_channels: 64, pool: true },
                ConvSpec { out_channels: 128, pool: false },
                ConvSpec { out_channels: 128, pool: true },
            ],
            fc: vec![256, 256],
            classes: 10,
            input_hw: 32,
            input_channels: 3,
            input_bits: 6,
        }
    }

    /// Minimal configuration for fast tests.
    pub fn tiny() -> Self {
        Self {
            name: "tiny-test".into(),
            conv: vec![
                ConvSpec { out_channels: 32, pool: true },
                ConvSpec { out_channels: 32, pool: true },
            ],
            fc: vec![64],
            classes: 10,
            input_hw: 16,
            input_channels: 3,
            input_bits: 6,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "table2" => Some(Self::table2()),
            "small" => Some(Self::small()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// Total layer count (conv + hidden FC + classifier).
    pub fn num_layers(&self) -> usize {
        self.conv.len() + self.fc.len() + 1
    }

    /// Resolved conv-layer geometry, in order.
    pub fn conv_shapes(&self) -> Vec<ConvShape> {
        let mut shapes = Vec::with_capacity(self.conv.len());
        let mut hw = self.input_hw;
        let mut in_c = self.input_channels;
        for spec in &self.conv {
            let out_hw = if spec.pool { hw / 2 } else { hw };
            shapes.push(ConvShape {
                in_c,
                out_c: spec.out_channels,
                in_hw: hw,
                out_hw,
                pool: spec.pool,
            });
            in_c = spec.out_channels;
            hw = out_hw;
        }
        shapes
    }

    /// Flattened feature count entering the first FC layer ((h, w, c)).
    pub fn fc_in_features(&self) -> usize {
        let last = *self.conv_shapes().last().expect("at least one conv layer");
        last.out_c * last.out_hw * last.out_hw
    }

    /// FC layer dims `(in, out)` including the classifier.
    pub fn fc_shapes(&self) -> Vec<(usize, usize)> {
        let mut dims = vec![self.fc_in_features()];
        dims.extend_from_slice(&self.fc);
        dims.push(self.classes);
        dims.windows(2).map(|w| (w[0], w[1])).collect()
    }

    /// `cnum_l = FW*FH*FD` — XNOR ops per output value (paper eq. 6).
    /// `layer` is 1-based as in the paper.
    pub fn cnum(&self, layer: usize) -> usize {
        assert!(layer >= 1 && layer <= self.num_layers(), "layer {layer}");
        let conv_shapes = self.conv_shapes();
        if layer <= conv_shapes.len() {
            9 * conv_shapes[layer - 1].in_c
        } else {
            self.fc_shapes()[layer - conv_shapes.len() - 1].0
        }
    }

    /// MAC-equivalent operation count per image, x2 (multiply + add) — the
    /// paper's GOPS accounting (Table 5: 7663 GOPS = ops/image x 6218 FPS).
    pub fn ops_per_image(&self) -> u64 {
        let mut total: u64 = 0;
        for s in self.conv_shapes() {
            total += (s.in_hw * s.in_hw * s.out_c * 9 * s.in_c) as u64;
        }
        for (in_f, out_f) in self.fc_shapes() {
            total += (in_f * out_f) as u64;
        }
        2 * total
    }

    /// Binary weight bits across all layers (capacity driver for BRAM).
    pub fn weight_bits(&self) -> u64 {
        let mut total: u64 = 0;
        for (i, s) in self.conv_shapes().iter().enumerate() {
            let per_filter = 9 * s.in_c;
            // first layer weights are 2-bit signed in the paper's design
            let bits = if i == 0 { 2 * per_filter } else { per_filter };
            total += (s.out_c * bits) as u64;
        }
        for (in_f, out_f) in self.fc_shapes() {
            total += (in_f * out_f) as u64;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        let cfg = NetConfig::table2();
        let shapes = cfg.conv_shapes();
        let in_out: Vec<(usize, usize)> = shapes.iter().map(|s| (s.in_c, s.out_c)).collect();
        assert_eq!(
            in_out,
            vec![(3, 128), (128, 128), (128, 256), (256, 256), (256, 512), (512, 512)]
        );
        let out_hw: Vec<usize> = shapes.iter().map(|s| s.out_hw).collect();
        assert_eq!(out_hw, vec![32, 16, 16, 8, 8, 4]);
        assert_eq!(cfg.fc_shapes(), vec![(8192, 1024), (1024, 1024), (1024, 10)]);
        assert_eq!(cfg.num_layers(), 9);
    }

    #[test]
    fn table2_cnum() {
        let cfg = NetConfig::table2();
        assert_eq!(cfg.cnum(1), 27);
        assert_eq!(cfg.cnum(2), 9 * 128);
        assert_eq!(cfg.cnum(6), 9 * 512);
        assert_eq!(cfg.cnum(7), 8192);
        assert_eq!(cfg.cnum(9), 1024);
    }

    #[test]
    fn table2_gops_headline() {
        // paper §6.2: 7663 GOPS at 6218 FPS => ~1.233 GOP/image
        let ops = NetConfig::table2().ops_per_image();
        let gops = ops as f64 * 6218.0 / 1e9;
        assert!((gops - 7663.0).abs() / 7663.0 < 0.02, "gops {gops}");
    }

    #[test]
    fn by_name() {
        assert!(NetConfig::by_name("table2").is_some());
        assert!(NetConfig::by_name("nope").is_none());
    }

    #[test]
    #[should_panic(expected = "layer")]
    fn cnum_out_of_range_panics() {
        NetConfig::tiny().cnum(99);
    }
}
