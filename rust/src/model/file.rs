//! `.bcnn` weight-file reader — the interchange with the python compile
//! path (format spec in `python/compile/export.py`, version 2).
//!
//! Weights arrive already bit-packed (LSB-first `u64` words, `(kh, kw, c)`
//! patch order for conv, `(h, w, c)` flattening for FC) so the native
//! engine can use them in place.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::config::{ConvSpec, NetConfig};
use crate::util::bits::words_for;

pub const MAGIC: &[u8; 4] = b"BCNN";
pub const VERSION: u32 = 2;

/// One layer's folded inference parameters (paper §3: weights + the single
/// per-channel threshold that replaces BN + binarize).
#[derive(Debug, Clone)]
pub enum LayerWeights {
    /// First layer: 6-bit activations x ±1 weights, integer thresholds.
    FpConv {
        in_c: usize,
        out_c: usize,
        pool: bool,
        /// `[out_c][9*in_c]` in (kh, kw, c) order, values in {-1, +1}.
        weights: Vec<i8>,
        thresholds: Vec<i32>,
    },
    /// Hidden binary conv: packed weights + thresholds.
    BinConv {
        in_c: usize,
        out_c: usize,
        pool: bool,
        /// `[out_c]` rows of `words_for(9*in_c)` packed words.
        weights: Vec<u64>,
        words_per_row: usize,
        thresholds: Vec<i32>,
    },
    /// Hidden binary FC.
    BinFc {
        in_f: usize,
        out_f: usize,
        weights: Vec<u64>,
        words_per_row: usize,
        thresholds: Vec<i32>,
    },
    /// Classifier: affine Norm (paper fig. 3 output layer), no binarize.
    BinFcOut {
        in_f: usize,
        out_f: usize,
        weights: Vec<u64>,
        words_per_row: usize,
        scale: Vec<f32>,
        bias: Vec<f32>,
    },
}

impl LayerWeights {
    pub fn out_dim(&self) -> usize {
        match self {
            LayerWeights::FpConv { out_c, .. } | LayerWeights::BinConv { out_c, .. } => *out_c,
            LayerWeights::BinFc { out_f, .. } | LayerWeights::BinFcOut { out_f, .. } => *out_f,
        }
    }

    /// Packed weight row `n` for binary kinds.
    pub fn weight_row(&self, n: usize) -> &[u64] {
        match self {
            LayerWeights::BinConv { weights, words_per_row, .. }
            | LayerWeights::BinFc { weights, words_per_row, .. }
            | LayerWeights::BinFcOut { weights, words_per_row, .. } => {
                &weights[n * words_per_row..(n + 1) * words_per_row]
            }
            LayerWeights::FpConv { .. } => panic!("weight_row on FpConv"),
        }
    }
}

/// A fully-loaded BCNN model.
#[derive(Debug, Clone)]
pub struct BcnnModel {
    pub name: String,
    pub input_hw: usize,
    pub input_channels: usize,
    pub input_bits: usize,
    pub classes: usize,
    pub layers: Vec<LayerWeights>,
}

impl BcnnModel {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let data = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&data)
    }

    pub fn parse(data: &[u8]) -> Result<Self> {
        let mut r = Reader { data, off: 0 };
        if r.take(4)? != MAGIC {
            bail!("bad magic (not a .bcnn file)");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported .bcnn version {version} (want {VERSION})");
        }
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec()).context("model name")?;
        let input_hw = r.u32()? as usize;
        let input_channels = r.u32()? as usize;
        let input_bits = r.u32()? as usize;
        let classes = r.u32()? as usize;
        let n_layers = r.u32()? as usize;
        if n_layers == 0 || n_layers > 64 {
            bail!("implausible layer count {n_layers}");
        }
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            layers.push(read_layer(&mut r).with_context(|| format!("layer {i}"))?);
        }
        if r.off != data.len() {
            bail!("{} trailing bytes", data.len() - r.off);
        }
        Ok(Self { name, input_hw, input_channels, input_bits, classes, layers })
    }

    /// Serialize back to the `.bcnn` wire format (inverse of
    /// [`BcnnModel::parse`]; used by tests and by tooling that ships
    /// models to a serving host).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        if self.name.len() > u16::MAX as usize {
            // the format stores the name length as u16; truncating it
            // silently would produce an artifact that misparses far from
            // the cause
            bail!("model name too long to serialize ({} bytes)", self.name.len());
        }
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.name.len() as u16).to_le_bytes());
        out.extend_from_slice(self.name.as_bytes());
        for v in [self.input_hw, self.input_channels, self.input_bits, self.classes] {
            out.extend_from_slice(&(v as u32).to_le_bytes());
        }
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for layer in &self.layers {
            write_layer(&mut out, layer);
        }
        Ok(out)
    }

    /// Write the serialized model to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_bytes()?)
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    /// Reconstruct the `NetConfig` this model instantiates (used to drive
    /// the FPGA simulator / optimizer from a weight file alone).
    pub fn config(&self) -> NetConfig {
        let mut conv = Vec::new();
        let mut fc = Vec::new();
        for layer in &self.layers {
            match layer {
                LayerWeights::FpConv { out_c, pool, .. }
                | LayerWeights::BinConv { out_c, pool, .. } => {
                    conv.push(ConvSpec { out_channels: *out_c, pool: *pool })
                }
                LayerWeights::BinFc { out_f, .. } => fc.push(*out_f),
                LayerWeights::BinFcOut { .. } => {}
            }
        }
        NetConfig {
            name: self.name.clone(),
            conv,
            fc,
            classes: self.classes,
            input_hw: self.input_hw,
            input_channels: self.input_channels,
            input_bits: self.input_bits,
        }
    }
}

struct Reader<'a> {
    data: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.data.len() {
            bail!("truncated file at byte {}", self.off);
        }
        let s = &self.data[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u64_vec(&mut self, n: usize) -> Result<Vec<u64>> {
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

const KIND_FP_CONV: u8 = 0;
const KIND_BIN_CONV: u8 = 1;
const KIND_BIN_FC: u8 = 2;
const KIND_BIN_FC_OUT: u8 = 3;

fn write_layer(out: &mut Vec<u8>, layer: &LayerWeights) {
    match layer {
        LayerWeights::FpConv { in_c, out_c, pool, weights, thresholds } => {
            out.push(KIND_FP_CONV);
            out.extend_from_slice(&(*in_c as u32).to_le_bytes());
            out.extend_from_slice(&(*out_c as u32).to_le_bytes());
            out.push(u8::from(*pool));
            out.extend(weights.iter().map(|&w| w as u8));
            for t in thresholds {
                out.extend_from_slice(&t.to_le_bytes());
            }
        }
        LayerWeights::BinConv { in_c, out_c, pool, weights, thresholds, .. } => {
            out.push(KIND_BIN_CONV);
            out.extend_from_slice(&(*in_c as u32).to_le_bytes());
            out.extend_from_slice(&(*out_c as u32).to_le_bytes());
            out.push(u8::from(*pool));
            for w in weights {
                out.extend_from_slice(&w.to_le_bytes());
            }
            for t in thresholds {
                out.extend_from_slice(&t.to_le_bytes());
            }
        }
        LayerWeights::BinFc { in_f, out_f, weights, thresholds, .. } => {
            out.push(KIND_BIN_FC);
            out.extend_from_slice(&(*in_f as u32).to_le_bytes());
            out.extend_from_slice(&(*out_f as u32).to_le_bytes());
            for w in weights {
                out.extend_from_slice(&w.to_le_bytes());
            }
            for t in thresholds {
                out.extend_from_slice(&t.to_le_bytes());
            }
        }
        LayerWeights::BinFcOut { in_f, out_f, weights, scale, bias, .. } => {
            out.push(KIND_BIN_FC_OUT);
            out.extend_from_slice(&(*in_f as u32).to_le_bytes());
            out.extend_from_slice(&(*out_f as u32).to_le_bytes());
            for w in weights {
                out.extend_from_slice(&w.to_le_bytes());
            }
            for s in scale {
                out.extend_from_slice(&s.to_le_bytes());
            }
            for b in bias {
                out.extend_from_slice(&b.to_le_bytes());
            }
        }
    }
}

fn read_layer(r: &mut Reader) -> Result<LayerWeights> {
    let kind = r.u8()?;
    match kind {
        KIND_FP_CONV => {
            let in_c = r.u32()? as usize;
            let out_c = r.u32()? as usize;
            let pool = r.u8()? != 0;
            let raw = r.take(out_c * 9 * in_c)?;
            let weights: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
            if weights.iter().any(|&w| w != 1 && w != -1) {
                bail!("fp_conv weights must be ±1");
            }
            let thresholds = r.i32_vec(out_c)?;
            Ok(LayerWeights::FpConv { in_c, out_c, pool, weights, thresholds })
        }
        KIND_BIN_CONV => {
            let in_c = r.u32()? as usize;
            let out_c = r.u32()? as usize;
            let pool = r.u8()? != 0;
            let words_per_row = words_for(9 * in_c);
            let weights = r.u64_vec(out_c * words_per_row)?;
            let thresholds = r.i32_vec(out_c)?;
            Ok(LayerWeights::BinConv { in_c, out_c, pool, weights, words_per_row, thresholds })
        }
        KIND_BIN_FC => {
            let in_f = r.u32()? as usize;
            let out_f = r.u32()? as usize;
            let words_per_row = words_for(in_f);
            let weights = r.u64_vec(out_f * words_per_row)?;
            let thresholds = r.i32_vec(out_f)?;
            Ok(LayerWeights::BinFc { in_f, out_f, weights, words_per_row, thresholds })
        }
        KIND_BIN_FC_OUT => {
            let in_f = r.u32()? as usize;
            let out_f = r.u32()? as usize;
            let words_per_row = words_for(in_f);
            let weights = r.u64_vec(out_f * words_per_row)?;
            let scale = r.f32_vec(out_f)?;
            let bias = r.f32_vec(out_f)?;
            Ok(LayerWeights::BinFcOut { in_f, out_f, weights, words_per_row, scale, bias })
        }
        k => bail!("unknown layer kind {k}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::NetConfig;

    fn tiny_bytes() -> Vec<u8> {
        BcnnModel::synthetic(&NetConfig::tiny(), 0xF11E).to_bytes().unwrap()
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(BcnnModel::parse(b"NOPE\x02\0\0\0").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut data = Vec::new();
        data.extend_from_slice(MAGIC);
        data.extend_from_slice(&VERSION.to_le_bytes());
        data.extend_from_slice(&2u16.to_le_bytes());
        data.extend_from_slice(b"t");
        // missing the rest
        assert!(BcnnModel::parse(&data).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let mut data = Vec::new();
        data.extend_from_slice(MAGIC);
        data.extend_from_slice(&99u32.to_le_bytes());
        assert!(BcnnModel::parse(&data).is_err());
    }

    #[test]
    fn round_trips_through_bytes() {
        let model = BcnnModel::synthetic(&NetConfig::tiny(), 0xF11E);
        let bytes = model.to_bytes().unwrap();
        let parsed = BcnnModel::parse(&bytes).expect("own serialization parses");
        assert_eq!(parsed.name, model.name);
        assert_eq!(parsed.config(), model.config());
        assert_eq!(parsed.layers.len(), model.layers.len());
        // spot-check one packed tensor survives the trip bit-for-bit
        match (&parsed.layers[1], &model.layers[1]) {
            (
                LayerWeights::BinConv { weights: a, thresholds: ta, .. },
                LayerWeights::BinConv { weights: b, thresholds: tb, .. },
            ) => {
                assert_eq!(a, b);
                assert_eq!(ta, tb);
            }
            other => panic!("layer 1 should be BinConv on both sides: {other:?}"),
        }
    }

    #[test]
    fn truncation_at_every_boundary_is_a_typed_error_not_a_panic() {
        // every proper prefix must fail cleanly through the guarded
        // Reader::take path — no slice-index or try_into panic anywhere
        let data = tiny_bytes();
        let step = (data.len() / 257).max(1); // ~257 cut points incl. tensor interiors
        let mut cuts: Vec<usize> = (0..data.len()).step_by(step).collect();
        cuts.extend([1, 2, 3, 4, 5, 7, 8, 9, 13, 25, data.len() - 1]);
        for cut in cuts {
            let res = BcnnModel::parse(&data[..cut]);
            assert!(res.is_err(), "prefix of {cut} bytes parsed successfully");
        }
    }

    #[test]
    fn short_tensor_is_an_error() {
        // drop the final 4 bytes (inside the classifier bias vector)
        let data = tiny_bytes();
        let err = BcnnModel::parse(&data[..data.len() - 4]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn unknown_layer_kind_is_an_error() {
        let model = BcnnModel::synthetic(&NetConfig::tiny(), 0xF11E);
        let mut data = Vec::new();
        data.extend_from_slice(MAGIC);
        data.extend_from_slice(&VERSION.to_le_bytes());
        data.extend_from_slice(&(model.name.len() as u16).to_le_bytes());
        data.extend_from_slice(model.name.as_bytes());
        for v in [model.input_hw, model.input_channels, model.input_bits, model.classes] {
            data.extend_from_slice(&(v as u32).to_le_bytes());
        }
        data.extend_from_slice(&1u32.to_le_bytes());
        data.push(0x7F); // no such layer kind
        let err = BcnnModel::parse(&data).unwrap_err();
        assert!(format!("{err:#}").contains("unknown layer kind"), "{err:#}");
    }

    #[test]
    fn corrupt_fp_conv_weight_is_an_error() {
        // byte value 3 is not a ±1 weight; find the first fp_conv weight
        // byte (fixed offset: header + name + 5 u32 + kind + 2 u32 + pool)
        let model = BcnnModel::synthetic(&NetConfig::tiny(), 0xF11E);
        let mut data = model.to_bytes().unwrap();
        let off = 4 + 4 + 2 + model.name.len() + 4 * 4 + 4 + 1 + 4 + 4 + 1;
        data[off] = 3;
        let err = BcnnModel::parse(&data).unwrap_err();
        assert!(format!("{err:#}").contains("±1"), "{err:#}");
    }

    #[test]
    fn trailing_bytes_are_an_error() {
        let mut data = tiny_bytes();
        data.push(0);
        let err = BcnnModel::parse(&data).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn implausible_layer_count_is_an_error() {
        let data = tiny_bytes();
        // layer count sits right after magic+version+name+4 header u32s
        let model = BcnnModel::parse(&data).unwrap();
        let off = 4 + 4 + 2 + model.name.len() + 4 * 4;
        let mut data = data;
        data[off..off + 4].copy_from_slice(&10_000u32.to_le_bytes());
        let err = BcnnModel::parse(&data).unwrap_err();
        assert!(format!("{err:#}").contains("implausible"), "{err:#}");
    }

    #[test]
    fn oversized_name_is_a_serialization_error() {
        // the format stores the name length as u16; a longer name must be
        // a typed error, not a silently-corrupt artifact
        let mut model = BcnnModel::synthetic(&NetConfig::tiny(), 0xF11E);
        model.name = "x".repeat(70_000);
        assert!(model.to_bytes().is_err());
    }

    #[test]
    fn save_and_load_round_trip() {
        let model = BcnnModel::synthetic(&NetConfig::tiny(), 0xF11E);
        let dir = std::env::temp_dir().join("bcnn_file_roundtrip_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model_tiny.bcnn");
        model.save(&path).unwrap();
        let loaded = BcnnModel::load(&path).unwrap();
        assert_eq!(loaded.config(), model.config());
        std::fs::remove_file(&path).ok();
    }
}
