//! `.bcnn` weight-file reader — the interchange with the python compile
//! path (format spec in `python/compile/export.py`, version 2).
//!
//! Weights arrive already bit-packed (LSB-first `u64` words, `(kh, kw, c)`
//! patch order for conv, `(h, w, c)` flattening for FC) so the native
//! engine can use them in place.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::config::{ConvSpec, NetConfig};
use crate::util::bits::words_for;

pub const MAGIC: &[u8; 4] = b"BCNN";
pub const VERSION: u32 = 2;

/// One layer's folded inference parameters (paper §3: weights + the single
/// per-channel threshold that replaces BN + binarize).
#[derive(Debug, Clone)]
pub enum LayerWeights {
    /// First layer: 6-bit activations x ±1 weights, integer thresholds.
    FpConv {
        in_c: usize,
        out_c: usize,
        pool: bool,
        /// `[out_c][9*in_c]` in (kh, kw, c) order, values in {-1, +1}.
        weights: Vec<i8>,
        thresholds: Vec<i32>,
    },
    /// Hidden binary conv: packed weights + thresholds.
    BinConv {
        in_c: usize,
        out_c: usize,
        pool: bool,
        /// `[out_c]` rows of `words_for(9*in_c)` packed words.
        weights: Vec<u64>,
        words_per_row: usize,
        thresholds: Vec<i32>,
    },
    /// Hidden binary FC.
    BinFc {
        in_f: usize,
        out_f: usize,
        weights: Vec<u64>,
        words_per_row: usize,
        thresholds: Vec<i32>,
    },
    /// Classifier: affine Norm (paper fig. 3 output layer), no binarize.
    BinFcOut {
        in_f: usize,
        out_f: usize,
        weights: Vec<u64>,
        words_per_row: usize,
        scale: Vec<f32>,
        bias: Vec<f32>,
    },
}

impl LayerWeights {
    pub fn out_dim(&self) -> usize {
        match self {
            LayerWeights::FpConv { out_c, .. } | LayerWeights::BinConv { out_c, .. } => *out_c,
            LayerWeights::BinFc { out_f, .. } | LayerWeights::BinFcOut { out_f, .. } => *out_f,
        }
    }

    /// Packed weight row `n` for binary kinds.
    pub fn weight_row(&self, n: usize) -> &[u64] {
        match self {
            LayerWeights::BinConv { weights, words_per_row, .. }
            | LayerWeights::BinFc { weights, words_per_row, .. }
            | LayerWeights::BinFcOut { weights, words_per_row, .. } => {
                &weights[n * words_per_row..(n + 1) * words_per_row]
            }
            LayerWeights::FpConv { .. } => panic!("weight_row on FpConv"),
        }
    }
}

/// A fully-loaded BCNN model.
#[derive(Debug, Clone)]
pub struct BcnnModel {
    pub name: String,
    pub input_hw: usize,
    pub input_channels: usize,
    pub input_bits: usize,
    pub classes: usize,
    pub layers: Vec<LayerWeights>,
}

impl BcnnModel {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let data = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&data)
    }

    pub fn parse(data: &[u8]) -> Result<Self> {
        let mut r = Reader { data, off: 0 };
        if r.take(4)? != MAGIC {
            bail!("bad magic (not a .bcnn file)");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported .bcnn version {version} (want {VERSION})");
        }
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec()).context("model name")?;
        let input_hw = r.u32()? as usize;
        let input_channels = r.u32()? as usize;
        let input_bits = r.u32()? as usize;
        let classes = r.u32()? as usize;
        let n_layers = r.u32()? as usize;
        if n_layers == 0 || n_layers > 64 {
            bail!("implausible layer count {n_layers}");
        }
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            layers.push(read_layer(&mut r).with_context(|| format!("layer {i}"))?);
        }
        if r.off != data.len() {
            bail!("{} trailing bytes", data.len() - r.off);
        }
        Ok(Self { name, input_hw, input_channels, input_bits, classes, layers })
    }

    /// Reconstruct the `NetConfig` this model instantiates (used to drive
    /// the FPGA simulator / optimizer from a weight file alone).
    pub fn config(&self) -> NetConfig {
        let mut conv = Vec::new();
        let mut fc = Vec::new();
        for layer in &self.layers {
            match layer {
                LayerWeights::FpConv { out_c, pool, .. }
                | LayerWeights::BinConv { out_c, pool, .. } => {
                    conv.push(ConvSpec { out_channels: *out_c, pool: *pool })
                }
                LayerWeights::BinFc { out_f, .. } => fc.push(*out_f),
                LayerWeights::BinFcOut { .. } => {}
            }
        }
        NetConfig {
            name: self.name.clone(),
            conv,
            fc,
            classes: self.classes,
            input_hw: self.input_hw,
            input_channels: self.input_channels,
            input_bits: self.input_bits,
        }
    }
}

struct Reader<'a> {
    data: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.data.len() {
            bail!("truncated file at byte {}", self.off);
        }
        let s = &self.data[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u64_vec(&mut self, n: usize) -> Result<Vec<u64>> {
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

const KIND_FP_CONV: u8 = 0;
const KIND_BIN_CONV: u8 = 1;
const KIND_BIN_FC: u8 = 2;
const KIND_BIN_FC_OUT: u8 = 3;

fn read_layer(r: &mut Reader) -> Result<LayerWeights> {
    let kind = r.u8()?;
    match kind {
        KIND_FP_CONV => {
            let in_c = r.u32()? as usize;
            let out_c = r.u32()? as usize;
            let pool = r.u8()? != 0;
            let raw = r.take(out_c * 9 * in_c)?;
            let weights: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
            if weights.iter().any(|&w| w != 1 && w != -1) {
                bail!("fp_conv weights must be ±1");
            }
            let thresholds = r.i32_vec(out_c)?;
            Ok(LayerWeights::FpConv { in_c, out_c, pool, weights, thresholds })
        }
        KIND_BIN_CONV => {
            let in_c = r.u32()? as usize;
            let out_c = r.u32()? as usize;
            let pool = r.u8()? != 0;
            let words_per_row = words_for(9 * in_c);
            let weights = r.u64_vec(out_c * words_per_row)?;
            let thresholds = r.i32_vec(out_c)?;
            Ok(LayerWeights::BinConv { in_c, out_c, pool, weights, words_per_row, thresholds })
        }
        KIND_BIN_FC => {
            let in_f = r.u32()? as usize;
            let out_f = r.u32()? as usize;
            let words_per_row = words_for(in_f);
            let weights = r.u64_vec(out_f * words_per_row)?;
            let thresholds = r.i32_vec(out_f)?;
            Ok(LayerWeights::BinFc { in_f, out_f, weights, words_per_row, thresholds })
        }
        KIND_BIN_FC_OUT => {
            let in_f = r.u32()? as usize;
            let out_f = r.u32()? as usize;
            let words_per_row = words_for(in_f);
            let weights = r.u64_vec(out_f * words_per_row)?;
            let scale = r.f32_vec(out_f)?;
            let bias = r.f32_vec(out_f)?;
            Ok(LayerWeights::BinFcOut { in_f, out_f, weights, words_per_row, scale, bias })
        }
        k => bail!("unknown layer kind {k}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_bad_magic() {
        assert!(BcnnModel::parse(b"NOPE\x02\0\0\0").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut data = Vec::new();
        data.extend_from_slice(MAGIC);
        data.extend_from_slice(&VERSION.to_le_bytes());
        data.extend_from_slice(&2u16.to_le_bytes());
        data.extend_from_slice(b"t");
        // missing the rest
        assert!(BcnnModel::parse(&data).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let mut data = Vec::new();
        data.extend_from_slice(MAGIC);
        data.extend_from_slice(&99u32.to_le_bytes());
        assert!(BcnnModel::parse(&data).is_err());
    }
}
