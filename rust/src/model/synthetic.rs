//! Deterministic synthetic weights for any [`NetConfig`].
//!
//! The trained artifacts come from `make artifacts` (python/JAX); this
//! module lets every functional test, bench, and serving experiment run
//! *without* them: weights are random but valid (packed rows padded with
//! zero bits past the row's bit count, exactly like
//! `python/compile/packing.py`), and thresholds sit near each layer's
//! match-count median so activations stay balanced instead of saturating.
//!
//! Numerics-equivalence tests (engine vs scalar reference vs FPGA
//! simulator vs PE datapath) are as strong on synthetic weights as on
//! trained ones — both sides consume the same `BcnnModel`.  Only
//! *accuracy* assertions need the trained artifacts.

use crate::model::config::NetConfig;
use crate::model::file::{BcnnModel, LayerWeights};
use crate::util::bits::words_for;
use crate::util::SplitMix64;

/// Random packed ±1 rows: `rows x words_for(bits)` words, bits past
/// `bits` in each row's last word forced to zero (packing invariant).
fn packed_rows(rng: &mut SplitMix64, rows: usize, bits: usize) -> Vec<u64> {
    let wpr = words_for(bits);
    let tail = bits % 64;
    let mut out = Vec::with_capacity(rows * wpr);
    for _ in 0..rows {
        for w in 0..wpr {
            let mut word = rng.next_u64();
            if w == wpr - 1 && tail != 0 {
                word &= (1u64 << tail) - 1;
            }
            out.push(word);
        }
    }
    out
}

/// Thresholds near the match-count median `bits/2`, jittered by about one
/// standard deviation (`sqrt(bits)/2`) so channels differ.
fn match_thresholds(rng: &mut SplitMix64, n: usize, bits: usize) -> Vec<i32> {
    let mid = (bits / 2) as i64;
    let sd = ((bits as f64).sqrt() / 2.0).ceil() as i64;
    (0..n).map(|_| rng.range_i64(mid - sd, mid + sd) as i32).collect()
}

impl BcnnModel {
    /// Build a deterministic random model instantiating `config`.
    pub fn synthetic(config: &NetConfig, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut layers = Vec::with_capacity(config.num_layers());

        for (i, shape) in config.conv_shapes().iter().enumerate() {
            let k = 9 * shape.in_c;
            if i == 0 {
                // first layer: 6-bit ints x ±1 weights (paper eq. 7); the
                // accumulator is zero-mean with sd ~ sqrt(k * 31^2/3)
                let weights: Vec<i8> = (0..shape.out_c * k)
                    .map(|_| if rng.bit() { 1 } else { -1 })
                    .collect();
                let sd = (k as f64 * 31.0 * 31.0 / 3.0).sqrt().ceil() as i64;
                let thresholds: Vec<i32> = (0..shape.out_c)
                    .map(|_| rng.range_i64(-sd / 2, sd / 2) as i32)
                    .collect();
                layers.push(LayerWeights::FpConv {
                    in_c: shape.in_c,
                    out_c: shape.out_c,
                    pool: shape.pool,
                    weights,
                    thresholds,
                });
            } else {
                layers.push(LayerWeights::BinConv {
                    in_c: shape.in_c,
                    out_c: shape.out_c,
                    pool: shape.pool,
                    weights: packed_rows(&mut rng, shape.out_c, k),
                    words_per_row: words_for(k),
                    thresholds: match_thresholds(&mut rng, shape.out_c, k),
                });
            }
        }

        let fc_shapes = config.fc_shapes();
        for (i, &(in_f, out_f)) in fc_shapes.iter().enumerate() {
            let weights = packed_rows(&mut rng, out_f, in_f);
            if i + 1 == fc_shapes.len() {
                // classifier: affine Norm, no binarize
                let scale: Vec<f32> =
                    (0..out_f).map(|_| (0.05 + 0.1 * rng.f64()) as f32).collect();
                let bias: Vec<f32> =
                    (0..out_f).map(|_| (2.0 * rng.f64() - 1.0) as f32).collect();
                layers.push(LayerWeights::BinFcOut {
                    in_f,
                    out_f,
                    weights,
                    words_per_row: words_for(in_f),
                    scale,
                    bias,
                });
            } else {
                layers.push(LayerWeights::BinFc {
                    in_f,
                    out_f,
                    weights,
                    words_per_row: words_for(in_f),
                    thresholds: match_thresholds(&mut rng, out_f, in_f),
                });
            }
        }

        Self {
            name: config.name.clone(),
            input_hw: config.input_hw,
            input_channels: config.input_channels,
            input_bits: config.input_bits,
            classes: config.classes,
            layers,
        }
    }

    /// Load the named artifact if present, else fall back to a synthetic
    /// model for the named built-in config — the test/bench entry point.
    pub fn load_or_synthetic(name: &str, dir: &str, seed: u64) -> anyhow::Result<Self> {
        let path = format!("{dir}/model_{name}.bcnn");
        if let Ok(m) = Self::load(&path) {
            return Ok(m);
        }
        let config = NetConfig::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("no artifact at {path} and no built-in config {name:?}"))?;
        Ok(Self::synthetic(&config, seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic() {
        let cfg = NetConfig::tiny();
        let a = BcnnModel::synthetic(&cfg, 7);
        let b = BcnnModel::synthetic(&cfg, 7);
        assert_eq!(a.layers.len(), b.layers.len());
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            match (la, lb) {
                (
                    LayerWeights::BinConv { weights: wa, .. },
                    LayerWeights::BinConv { weights: wb, .. },
                ) => assert_eq!(wa, wb),
                (
                    LayerWeights::FpConv { weights: wa, .. },
                    LayerWeights::FpConv { weights: wb, .. },
                ) => assert_eq!(wa, wb),
                _ => {}
            }
        }
    }

    #[test]
    fn synthetic_matches_config_shape() {
        let cfg = NetConfig::tiny();
        let m = BcnnModel::synthetic(&cfg, 3);
        assert_eq!(m.layers.len(), cfg.num_layers());
        assert_eq!(m.config().conv_shapes(), cfg.conv_shapes());
        assert_eq!(m.config().fc_shapes(), cfg.fc_shapes());
    }

    #[test]
    fn synthetic_packed_rows_respect_padding() {
        // bits past each row's logical width must be zero (the engine and
        // the scalar reference both rely on it)
        let cfg = NetConfig::tiny();
        let m = BcnnModel::synthetic(&cfg, 9);
        for layer in &m.layers {
            let (weights, wpr, bits, rows) = match layer {
                LayerWeights::BinConv { weights, words_per_row, in_c, out_c, .. } => {
                    (weights, *words_per_row, 9 * in_c, *out_c)
                }
                LayerWeights::BinFc { weights, words_per_row, in_f, out_f, .. }
                | LayerWeights::BinFcOut { weights, words_per_row, in_f, out_f, .. } => {
                    (weights, *words_per_row, *in_f, *out_f)
                }
                LayerWeights::FpConv { .. } => continue,
            };
            let tail = bits % 64;
            if tail == 0 {
                continue;
            }
            for r in 0..rows {
                let last = weights[r * wpr + wpr - 1];
                assert_eq!(last >> tail, 0, "stray bits past row width");
            }
        }
    }

    #[test]
    fn synthetic_runs_through_engine() {
        let cfg = NetConfig::tiny();
        let m = BcnnModel::synthetic(&cfg, 11);
        let engine = crate::bcnn::Engine::new(m).expect("synthetic model is valid");
        let img = vec![5i32; cfg.input_hw * cfg.input_hw * cfg.input_channels];
        let scores = engine.infer(&img).unwrap();
        assert_eq!(scores.len(), cfg.classes);
    }
}
