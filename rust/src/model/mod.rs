//! Network description (paper Table 2) and the `.bcnn` weight file format
//! shared with the python compile path.

pub mod config;
pub mod file;
pub mod synthetic;
pub mod testset;

pub use config::{ConvShape, ConvSpec, NetConfig};
pub use file::{BcnnModel, LayerWeights};
pub use testset::TestSet;
