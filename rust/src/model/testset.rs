//! Labelled test-set file reader (`artifacts/testset_<cfg>.bin`), written
//! by `python/compile/train.py` for the rust end-to-end example.
//!
//! Format (little-endian): magic `BSET`; u32 n, hw, channels, classes;
//! then per sample `hw*hw*channels` int8 NHWC pixels + u8 label.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// A labelled evaluation set.
#[derive(Debug, Clone)]
pub struct TestSet {
    pub hw: usize,
    pub channels: usize,
    pub classes: usize,
    pub images: Vec<Vec<i32>>,
    pub labels: Vec<u8>,
}

impl TestSet {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let data = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        if data.len() < 20 || &data[..4] != b"BSET" {
            bail!("not a test-set file");
        }
        let u32_at = |off: usize| {
            u32::from_le_bytes(data[off..off + 4].try_into().unwrap()) as usize
        };
        let (n, hw, channels, classes) = (u32_at(4), u32_at(8), u32_at(12), u32_at(16));
        let per = hw * hw * channels;
        let expected = 20 + n * (per + 1);
        if data.len() != expected {
            bail!("test-set size {} != expected {}", data.len(), expected);
        }
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        let mut off = 20;
        for _ in 0..n {
            images.push(data[off..off + per].iter().map(|&b| b as i8 as i32).collect());
            off += per;
            labels.push(data[off]);
            off += 1;
        }
        Ok(Self { hw, channels, classes, images, labels })
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("repro_testset_garbage.bin");
        std::fs::write(&dir, b"NOPE").unwrap();
        assert!(TestSet::load(&dir).is_err());
        std::fs::write(&dir, b"BSET\x01\0\0\0\x02\0\0\0\x03\0\0\0\x0a\0\0\0").unwrap();
        assert!(TestSet::load(&dir).is_err()); // truncated body
        let _ = std::fs::remove_file(&dir);
    }
}
