//! Serving control plane (L4): multi-model registry, zero-downtime
//! hot-swap, and wire-level model routing over the sharded coordinator.
//!
//! The dataplane ([`crate::coordinator`], [`crate::pipeline`]) executes
//! one frozen model fast; a production service never runs one frozen
//! model.  This module adds the missing control plane:
//!
//! * [`registry`] — named, versioned [`registry::ModelEntry`]s, each
//!   owning its own coordinator pool (engine / pipeline / simulator
//!   backend per entry), with `deploy` / `undeploy` / `rollback` that
//!   build the replacement pool off to the side, swap the routing table
//!   in one epoch bump, and drain-then-join the old pool — no dropped or
//!   stalled requests across a swap.
//! * [`router`] — the epoch-tagged `Arc`-swapped routing table handlers
//!   resolve through.
//! * [`admin`] — protocol v2: request frames carry a model name, admin
//!   frames (`DEPLOY`/`UNDEPLOY`/`ROLLBACK`/`LIST`/`STATS`) manage the
//!   registry remotely, and protocol-v1 clients keep working against the
//!   default model.

pub mod admin;
pub mod registry;
pub mod router;

pub use admin::{
    serve_registry, serve_registry_frontend, serve_registry_threaded, ControlClient, InferOutcome,
    VersionedScores,
};
pub use registry::{BackendSpec, DeploySpec, ModelEntry, ModelRegistry, ModelSource, ModelStats};
pub use router::{RouteError, Router, RoutingTable};
