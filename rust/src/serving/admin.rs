//! Protocol v2: model-routed inference frames plus the admin plane
//! (`DEPLOY` / `UNDEPLOY` / `ROLLBACK` / `LIST` / `STATS`) over the same
//! TCP front-end.
//!
//! Wire format (little-endian).  The first `u32` of every frame is a tag.
//! Protocol-v1 clients are still served: a tag in `1..=MAX_WIRE_VALUES`
//! *is* a v1 request length, and is answered with a v1 reply on the
//! default model — so old clients keep working against a v2 server.
//!
//! ```text
//! tag 0                        close connection (v1 semantics)
//! tag 1..=MAX_WIRE_VALUES      v1 request: tag x i32 values -> u32 n, n x f32
//! OP_INFER    name, u32 n, n x i32   -> REPLY_SCORES, u64 version,
//!                                       u64 trace_id, u32 n, n x f32
//! OP_INFER_QOS name, u32 lane, u32 deadline_ms, u32 n, n x i32
//!                                    -> REPLY_SCORES (as above)
//!                                     | REPLY_EXPIRED, u32 len, msg bytes
//! OP_DEPLOY   name, source, backend, u32 workers, u32 queue_depth
//!                                    -> REPLY_OK, u64 version
//! OP_UNDEPLOY name                   -> REPLY_OK, u64 retired version
//! OP_ROLLBACK name                   -> REPLY_OK, u64 new version
//! OP_LIST                            -> REPLY_JSON, u32 len, bytes
//! OP_STATS                           -> REPLY_JSON, u32 len, bytes
//! OP_HEALTH                          -> REPLY_JSON, u32 len, bytes
//! OP_TRACE                           -> REPLY_JSON, u32 len, bytes
//! OP_PROFILE                         -> REPLY_JSON, u32 len, bytes
//! error (any op)                     -> 0xFFFF_FFFF, u32 len, msg bytes
//! ```
//!
//! `OP_INFER_QOS` is the two-lane admission frame: `lane` selects the
//! online (0) or offline (1) QoS class, `deadline_ms` bounds how long the
//! request may wait for dispatch (0 = the server's default for the lane).
//! A request shed because its deadline passed gets the *typed*
//! `REPLY_EXPIRED` frame — distinguishable from a backend error — and the
//! connection stays open.  Plain `OP_INFER` and v1 frames ride the online
//! lane with no explicit deadline.
//!
//! `OP_TRACE` returns the server's span rings as a Chrome trace-event
//! JSON document (load it in Perfetto / `chrome://tracing`); the
//! `trace_id` in every `REPLY_SCORES` frame correlates a reply with its
//! spans there.
//!
//! Strings are `u16 len + UTF-8 bytes`.  Error frames do **not** close
//! the connection (the next request may route to a healthy model); only
//! malformed framing does.
//!
//! Two server front-ends speak this protocol: the default epoll
//! [`reactor`](crate::coordinator::reactor) front-end
//! ([`serve_registry_frontend`] — multiplexed nonblocking connections,
//! incremental frame decode, pipelined requests, QoS admission) and the
//! legacy thread-per-connection fallback ([`serve_registry_threaded`],
//! used automatically off Linux).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::qos::{FrontendConfig, FrontendStats, Lane, QosAdmission};
use crate::coordinator::reactor::{
    reactor_supported, run_reactor, FrameOutcome, FrameService, ReplyTicket,
};
use crate::coordinator::request::{InferErrorKind, InferReply};
use crate::coordinator::server::{
    error_frame, reject_payload, scores_frame, serve_connections, write_error, MAX_DISCARD_BYTES,
    MAX_WIRE_VALUES, TCP_SUBMIT_DEADLINE, WIRE_ERROR,
};
use crate::coordinator::SubmitError;
use crate::model::BcnnModel;
use crate::serving::registry::{BackendSpec, DeploySpec, ModelEntry, ModelRegistry, ModelSource};
use crate::util::faults;
use crate::util::json::Json;

/// v2 frame tags.  All sit far above [`MAX_WIRE_VALUES`] (a v1 length)
/// and below [`WIRE_ERROR`], so the three frame families cannot collide.
pub const OP_INFER: u32 = 0xBC20_0001;
pub const OP_DEPLOY: u32 = 0xBC20_0002;
pub const OP_UNDEPLOY: u32 = 0xBC20_0003;
pub const OP_ROLLBACK: u32 = 0xBC20_0004;
pub const OP_LIST: u32 = 0xBC20_0005;
pub const OP_STATS: u32 = 0xBC20_0006;
pub const OP_HEALTH: u32 = 0xBC20_0007;
pub const OP_TRACE: u32 = 0xBC20_0008;
pub const OP_PROFILE: u32 = 0xBC20_0009;
/// QoS inference: lane-tagged, deadline-bounded (two-lane admission).
pub const OP_INFER_QOS: u32 = 0xBC20_000A;
pub const REPLY_SCORES: u32 = 0xBC20_0081;
pub const REPLY_OK: u32 = 0xBC20_0082;
pub const REPLY_JSON: u32 = 0xBC20_0083;
/// Typed deadline-expiry reply: the request was shed before dispatch
/// because its deadline passed.  The connection stays open.
pub const REPLY_EXPIRED: u32 = 0xBC20_0084;

/// How long a handler waits out backpressure before sending the client a
/// typed overload error instead of stalling the connection (shared with
/// the v1 front-end).
pub const SUBMIT_DEADLINE: Duration = TCP_SUBMIT_DEADLINE;

/// Serve the registry on a TCP listener until `stop` flips, on the
/// default front-end: the epoll reactor with two-lane QoS admission
/// ([`serve_registry_frontend`] with default config), falling back to
/// thread-per-connection where the reactor is unsupported.
pub fn serve_registry(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    serve_registry_frontend(listener, registry, stop, FrontendConfig::default())
}

/// Serve the registry on the event-driven front-end: a fixed pool of
/// reactor threads multiplexing nonblocking connections, incremental v2
/// frame decode (pipelined requests answered in order), and two-lane
/// weighted-deficit QoS admission with deadline shedding.  Registry
/// housekeeping (reaping drained retired pools, advancing telemetry
/// windows) runs on the accept thread's idle polls, so a hot-swapped-out
/// model's threads and weights are freed promptly even on a server that
/// only ever sees inference traffic after the swap.
pub fn serve_registry_frontend(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    stop: Arc<AtomicBool>,
    cfg: FrontendConfig,
) -> Result<()> {
    if !reactor_supported() {
        return serve_registry_threaded(listener, registry, stop);
    }
    let threads = cfg.resolved_threads();
    let stats = FrontendStats::new_registered();
    let qos = QosAdmission::new(cfg.qos, Arc::clone(&stats));
    let service: Arc<dyn FrameService> =
        Arc::new(V2Service { registry: Arc::clone(&registry), qos });
    run_reactor(listener, stop, service, threads, stats, move || {
        registry.reap_retired();
        registry.tick_windows();
    })
}

/// Thread-per-connection fallback front-end (one blocking handler thread
/// per accepted socket, sharing the v1 front-end's accept loop).  The
/// reactor front-end is the default; this path remains for platforms
/// without epoll and as the baseline the front-end benchmark compares
/// against.
pub fn serve_registry_threaded(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let handler: Arc<dyn Fn(TcpStream) + Send + Sync> = {
        let registry = Arc::clone(&registry);
        Arc::new(move |stream| {
            let _ = handle_conn(stream, &registry);
        })
    };
    serve_connections(listener, stop, handler, move || {
        registry.reap_retired();
        registry.tick_windows();
    })
}

// ---------------------------------------------------------------------------
// reactor service: incremental decode + QoS admission
// ---------------------------------------------------------------------------

/// Which wire dialect a pending inference replies in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ReplyStyle {
    /// Raw v1 reply: `u32 n, n x f32` (or a `WIRE_ERROR` frame).
    V1,
    /// Tagged v2 reply: `REPLY_SCORES` / `REPLY_EXPIRED` / `WIRE_ERROR`.
    V2,
}

/// Admin ops whose reply is a `REPLY_JSON` document.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum JsonOp {
    List,
    Stats,
    Health,
    Trace,
    Profile,
}

/// One decoded v2 frame (or the decode verdict for a malformed one).
#[derive(Debug, PartialEq)]
enum WireFrame {
    Close,
    Infer { name: String, lane: Lane, deadline_ms: u32, image: Vec<i32>, style: ReplyStyle },
    Deploy { name: String, source: String, backend: String, workers: usize, queue_depth: usize },
    Undeploy(String),
    Rollback(String),
    Admin(JsonOp),
    /// Framing stayed intact; reply with an error frame and carry on.
    Reject(String),
    /// Oversized-but-bounded payload: reply, swallow `skip` bytes, go on.
    Discard { skip: u64, message: String },
    /// Protocol garbage: reply with an error frame, then close.
    Fatal(String),
}

/// Incremental decoder + dispatcher for protocol v2 (including its v1
/// compatibility arm) on the epoll reactor.  Cheap admin ops (list,
/// stats, health, profile, undeploy, rollback) execute inline on the loop
/// thread; `DEPLOY` (loads weights, spawns a shard pool — seconds) and
/// `TRACE` (serializes every span ring — potentially megabytes) run on a
/// helper thread via [`reply_off_loop`] so the connections multiplexed on
/// that loop never stall behind them.  Inference frames go through the
/// two-lane QoS admission queue and reply asynchronously via their
/// [`ReplyTicket`].
struct V2Service {
    registry: Arc<ModelRegistry>,
    qos: Arc<QosAdmission>,
}

impl V2Service {
    #[allow(clippy::too_many_arguments)]
    fn admit_infer(
        &self,
        used: usize,
        name: String,
        lane: Lane,
        deadline_ms: u32,
        image: Vec<i32>,
        style: ReplyStyle,
        ticket: ReplyTicket,
    ) -> FrameOutcome {
        if faults::fire(faults::SITE_SERVER_READ) {
            // injected shed after the frame was consumed: the connection
            // stays framed and usable
            return FrameOutcome::Reply(
                used,
                error_frame("injected fault: request shed at server_read"),
            );
        }
        let sel = if name.is_empty() { None } else { Some(name.as_str()) };
        let entry = match self.registry.router().resolve_healthy(sel) {
            Ok(e) => e,
            Err(e) => return FrameOutcome::Reply(used, error_frame(&e.to_string())),
        };
        let deadline = (deadline_ms > 0).then(|| Duration::from_millis(u64::from(deadline_ms)));
        let trace_id = ticket.trace_id();
        let completion = v2_completion(ticket, style, entry.version);
        self.qos.admit(image, trace_id, lane, deadline, entry.client(), completion);
        FrameOutcome::Pending(used)
    }
}

impl FrameService for V2Service {
    fn on_frame(&self, buf: &[u8], ticket: ReplyTicket) -> FrameOutcome {
        let (frame, used) = match parse_frame(buf) {
            None => return FrameOutcome::Incomplete,
            Some(f) => f,
        };
        match frame {
            WireFrame::Close => FrameOutcome::Close(used),
            WireFrame::Fatal(msg) => FrameOutcome::Fatal(used, error_frame(&msg)),
            WireFrame::Reject(msg) => FrameOutcome::Reply(used, error_frame(&msg)),
            WireFrame::Discard { skip, message } => {
                FrameOutcome::Discard { consumed: used, skip, reply: error_frame(&message) }
            }
            WireFrame::Infer { name, lane, deadline_ms, image, style } => {
                self.admit_infer(used, name, lane, deadline_ms, image, style, ticket)
            }
            WireFrame::Deploy { name, source, backend, workers, queue_depth } => {
                let registry = Arc::clone(&self.registry);
                reply_off_loop("deploy", used, ticket, move || {
                    version_frame(deploy_from_wire(
                        &registry,
                        &name,
                        &source,
                        &backend,
                        workers,
                        queue_depth,
                    ))
                })
            }
            WireFrame::Undeploy(name) => {
                FrameOutcome::Reply(used, version_frame(self.registry.undeploy(&name)))
            }
            WireFrame::Rollback(name) => {
                FrameOutcome::Reply(used, version_frame(self.registry.rollback(&name)))
            }
            WireFrame::Admin(JsonOp::Trace) => {
                reply_off_loop("trace", used, ticket, || {
                    json_frame(&crate::obs::chrome_trace_json())
                })
            }
            WireFrame::Admin(op) => {
                FrameOutcome::Reply(used, json_frame(&admin_json(op, &self.registry)))
            }
        }
    }

    fn on_loop_tick(&self) -> bool {
        self.qos.pump()
    }

    fn on_shutdown(&self) {
        self.qos.drain_shutdown();
    }
}

/// Completion callback encoding an [`InferReply`] in the frame's reply
/// dialect and delivering it on the ticket.  The `server_write` fault
/// site fires here — the reactor's equivalent of dropping a reply at
/// write time.  Deadline-expired sheds become the typed `REPLY_EXPIRED`
/// frame on v2 (v1 has no typed tags, so they fall back to an error
/// frame there).
fn v2_completion(
    ticket: ReplyTicket,
    style: ReplyStyle,
    version: u64,
) -> Arc<dyn Fn(InferReply) + Send + Sync> {
    Arc::new(move |reply: InferReply| {
        let bytes = if faults::fire(faults::SITE_SERVER_WRITE) {
            error_frame("injected fault: reply dropped at server_write")
        } else {
            match (style, &reply.scores) {
                (ReplyStyle::V1, Ok(scores)) => scores_frame(scores),
                (ReplyStyle::V1, Err(e)) => error_frame(&e.message),
                (ReplyStyle::V2, Ok(scores)) => v2_scores_frame(version, reply.trace_id, scores),
                (ReplyStyle::V2, Err(e)) if e.kind == InferErrorKind::Expired => {
                    expired_frame(&e.message)
                }
                (ReplyStyle::V2, Err(e)) => error_frame(&e.message),
            }
        };
        ticket.deliver(bytes);
    })
}

/// Run `job` on a helper thread and deliver the frame it builds through
/// the ticket ([`FrameOutcome::Pending`]): slow admin ops must not execute
/// inline in `on_frame` — that runs on a reactor loop thread, so every
/// connection multiplexed there (including deadline-bound online-lane
/// traffic) would stall for the duration.  A reply frame is delivered on
/// every path — spawn failure and a panicking job included — because a
/// missing sequence number would wedge the connection's reorder stage
/// permanently.
fn reply_off_loop(
    name: &str,
    used: usize,
    ticket: ReplyTicket,
    job: impl FnOnce() -> Vec<u8> + Send + 'static,
) -> FrameOutcome {
    let fallback = ticket.clone();
    let spawned = std::thread::Builder::new().name(format!("admin-{name}")).spawn(move || {
        let bytes = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job))
            .unwrap_or_else(|p| {
                error_frame(&format!(
                    "admin op panicked: {}",
                    crate::util::sync::panic_message(&*p)
                ))
            });
        ticket.deliver(bytes);
    });
    if let Err(e) = spawned {
        fallback.deliver(error_frame(&format!("admin op failed: spawn helper thread: {e}")));
    }
    FrameOutcome::Pending(used)
}

// ---------------------------------------------------------------------------
// incremental frame parser
// ---------------------------------------------------------------------------

/// Cursor over one connection's buffered bytes.  Every reader returns
/// `None` while the buffer does not yet hold enough bytes — the
/// incremental-decode contract: a partial frame parses as "incomplete"
/// (never an error) and is simply retried when more bytes arrive.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }

    fn u16(&mut self) -> Option<u16> {
        Some(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// `u16 len + UTF-8 bytes`.  `Some(Err(_))` is a framing error (the
    /// bytes are all present but not UTF-8), distinct from `None`.
    fn string(&mut self) -> Option<std::result::Result<String, String>> {
        let len = self.u16()? as usize;
        let raw = self.take(len)?;
        Some(
            std::str::from_utf8(raw)
                .map(str::to_string)
                .map_err(|_| "string field is not UTF-8".to_string()),
        )
    }

    fn image(&mut self, n: usize) -> Option<Vec<i32>> {
        let raw = self.take(n.checked_mul(4)?)?;
        Some(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Classify an oversized-payload claim: bounded lengths are swallowed to
/// keep the connection framed, implausible ones close it (protocol
/// garbage is not worth draining gigabytes for).
fn oversize(skip: u64, message: String) -> WireFrame {
    if skip > MAX_DISCARD_BYTES as u64 {
        WireFrame::Fatal(message)
    } else {
        WireFrame::Discard { skip, message }
    }
}

/// Decode one frame off the front of `buf`.  `None` means the buffer does
/// not yet hold a complete frame; `Some((frame, consumed))` consumed
/// exactly `consumed` bytes.  Pure — all I/O stays in the reactor.
fn parse_frame(buf: &[u8]) -> Option<(WireFrame, usize)> {
    let mut cur = Cur::new(buf);
    macro_rules! wire_str {
        () => {
            match cur.string()? {
                Ok(s) => s,
                Err(msg) => return Some((WireFrame::Fatal(msg), cur.pos)),
            }
        };
    }
    let tag = cur.u32()?;
    match tag {
        0 => Some((WireFrame::Close, cur.pos)),
        // ---- protocol-v1 compatibility: the tag is the request length --
        n if (n as usize) <= MAX_WIRE_VALUES => {
            let image = cur.image(n as usize)?;
            let frame = WireFrame::Infer {
                name: String::new(),
                lane: Lane::Online,
                deadline_ms: 0,
                image,
                style: ReplyStyle::V1,
            };
            Some((frame, cur.pos))
        }
        // ---- oversized v1 length: not a v2 tag, not the error tag ------
        n if n != WIRE_ERROR && (n >> 24) != 0xBC => {
            Some((oversize(u64::from(n) * 4, format!("request too large: {n} values")), cur.pos))
        }
        OP_INFER | OP_INFER_QOS => {
            let name = wire_str!();
            let (lane_raw, deadline_ms) = if tag == OP_INFER_QOS {
                (cur.u32()?, cur.u32()?)
            } else {
                (Lane::Online.wire(), 0)
            };
            let n = cur.u32()? as usize;
            if n == 0 {
                return Some((WireFrame::Reject("invalid request size: 0 values".into()), cur.pos));
            }
            if n > MAX_WIRE_VALUES {
                let msg = format!("invalid request size: {n} values");
                return Some((oversize(n as u64 * 4, msg), cur.pos));
            }
            let image = cur.image(n)?;
            let lane = match Lane::from_wire(lane_raw) {
                Some(l) => l,
                None => {
                    return Some((WireFrame::Reject(format!("invalid lane {lane_raw}")), cur.pos))
                }
            };
            let style = ReplyStyle::V2;
            Some((WireFrame::Infer { name, lane, deadline_ms, image, style }, cur.pos))
        }
        OP_DEPLOY => {
            let name = wire_str!();
            let source = wire_str!();
            let backend = wire_str!();
            let workers = cur.u32()? as usize;
            let queue_depth = cur.u32()? as usize;
            Some((WireFrame::Deploy { name, source, backend, workers, queue_depth }, cur.pos))
        }
        OP_UNDEPLOY => {
            let name = wire_str!();
            Some((WireFrame::Undeploy(name), cur.pos))
        }
        OP_ROLLBACK => {
            let name = wire_str!();
            Some((WireFrame::Rollback(name), cur.pos))
        }
        OP_LIST => Some((WireFrame::Admin(JsonOp::List), cur.pos)),
        OP_STATS => Some((WireFrame::Admin(JsonOp::Stats), cur.pos)),
        OP_HEALTH => Some((WireFrame::Admin(JsonOp::Health), cur.pos)),
        OP_TRACE => Some((WireFrame::Admin(JsonOp::Trace), cur.pos)),
        OP_PROFILE => Some((WireFrame::Admin(JsonOp::Profile), cur.pos)),
        other => Some((WireFrame::Fatal(format!("unknown frame tag {other:#010x}")), cur.pos)),
    }
}

// ---------------------------------------------------------------------------
// threaded fallback handler
// ---------------------------------------------------------------------------

fn handle_conn(mut stream: TcpStream, registry: &ModelRegistry) -> Result<()> {
    stream.set_nodelay(true).ok();
    let router = registry.router();
    loop {
        let mut tag_buf = [0u8; 4];
        if stream.read_exact(&mut tag_buf).is_err() {
            return Ok(()); // peer closed
        }
        let tag = u32::from_le_bytes(tag_buf);
        match tag {
            0 => return Ok(()),
            // ---- protocol-v1 compatibility: tag is the request length --
            n if (n as usize) <= MAX_WIRE_VALUES => {
                let image = read_image(&mut stream, n as usize)?;
                let entry = match router.resolve_healthy(None) {
                    Ok(e) => e,
                    Err(e) => {
                        write_error(&mut stream, &e.to_string())?;
                        continue;
                    }
                };
                match infer_on(&entry, image) {
                    Ok((_trace_id, scores)) => stream.write_all(&scores_frame(&scores))?,
                    Err(msg) => write_error(&mut stream, &msg)?,
                }
            }
            // ---- oversized v1 length: discard payload, reject, go on ---
            // (bounded — an implausible length or a stalled peer closes
            // the connection instead of pinning this thread)
            n if n != WIRE_ERROR && (n >> 24) != 0xBC => {
                reject_payload(&mut stream, n as usize, &format!("request too large: {n} values"))?;
            }
            OP_INFER => {
                let name = read_string(&mut stream)?;
                let n = read_u32(&mut stream)? as usize;
                if n == 0 || n > MAX_WIRE_VALUES {
                    reject_payload(&mut stream, n, &format!("invalid request size: {n} values"))?;
                    continue;
                }
                let image = read_image(&mut stream, n)?;
                let sel = if name.is_empty() { None } else { Some(name.as_str()) };
                let entry = match router.resolve_healthy(sel) {
                    Ok(e) => e,
                    Err(e) => {
                        write_error(&mut stream, &e.to_string())?;
                        continue;
                    }
                };
                match infer_on(&entry, image) {
                    Ok((trace_id, scores)) => {
                        stream.write_all(&v2_scores_frame(entry.version, trace_id, &scores))?
                    }
                    Err(msg) => write_error(&mut stream, &msg)?,
                }
            }
            OP_INFER_QOS => {
                let name = read_string(&mut stream)?;
                let lane_raw = read_u32(&mut stream)?;
                let deadline_ms = read_u32(&mut stream)?;
                let n = read_u32(&mut stream)? as usize;
                if n == 0 || n > MAX_WIRE_VALUES {
                    reject_payload(&mut stream, n, &format!("invalid request size: {n} values"))?;
                    continue;
                }
                let image = read_image(&mut stream, n)?;
                if Lane::from_wire(lane_raw).is_none() {
                    write_error(&mut stream, &format!("invalid lane {lane_raw}"))?;
                    continue;
                }
                let sel = if name.is_empty() { None } else { Some(name.as_str()) };
                let entry = match router.resolve_healthy(sel) {
                    Ok(e) => e,
                    Err(e) => {
                        write_error(&mut stream, &e.to_string())?;
                        continue;
                    }
                };
                // The threaded path has no admission queue to wait in, so
                // the deadline bounds the submit backpressure wait.
                let result = match deadline_ms {
                    0 => infer_on(&entry, image).map_err(InferFail::Other),
                    ms => {
                        let d = Duration::from_millis(u64::from(ms)).min(SUBMIT_DEADLINE);
                        infer_deadline(&entry, image, d, true)
                    }
                };
                match result {
                    Ok((trace_id, scores)) => {
                        stream.write_all(&v2_scores_frame(entry.version, trace_id, &scores))?
                    }
                    Err(InferFail::Expired(msg)) => stream.write_all(&expired_frame(&msg))?,
                    Err(InferFail::Other(msg)) => write_error(&mut stream, &msg)?,
                }
            }
            OP_DEPLOY => {
                let name = read_string(&mut stream)?;
                let source = read_string(&mut stream)?;
                let backend = read_string(&mut stream)?;
                let workers = read_u32(&mut stream)? as usize;
                let queue_depth = read_u32(&mut stream)? as usize;
                let result =
                    deploy_from_wire(registry, &name, &source, &backend, workers, queue_depth);
                stream.write_all(&version_frame(result))?;
            }
            OP_UNDEPLOY => {
                let name = read_string(&mut stream)?;
                stream.write_all(&version_frame(registry.undeploy(&name)))?;
            }
            OP_ROLLBACK => {
                let name = read_string(&mut stream)?;
                stream.write_all(&version_frame(registry.rollback(&name)))?;
            }
            OP_LIST => stream.write_all(&json_frame(&list_json(registry)))?,
            OP_STATS => stream.write_all(&json_frame(&stats_json(registry)))?,
            OP_HEALTH => stream.write_all(&json_frame(&health_json(registry)))?,
            OP_TRACE => stream.write_all(&json_frame(&crate::obs::chrome_trace_json()))?,
            OP_PROFILE => stream.write_all(&json_frame(&profile_json(registry)))?,
            other => {
                let _ = write_error(&mut stream, &format!("unknown frame tag {other:#010x}"));
                bail!("unknown frame tag {other:#010x}");
            }
        }
    }
}

/// How a threaded-path inference failed: a typed deadline expiry (only
/// when the client sent an explicit deadline) or everything else.
enum InferFail {
    Expired(String),
    Other(String),
}

/// Submit to one entry's pool with a deadline; a saturated pool yields an
/// error instead of a stalled connection.  With `typed_expiry`, running
/// out the deadline in backpressure maps to [`InferFail::Expired`] so the
/// caller can send `REPLY_EXPIRED`.  Returns the reply's trace ID with
/// the scores so v2 frames can carry it (the coordinator records every
/// span *before* sending the reply, so a client that sees this ID will
/// find its spans in `OP_TRACE`).
fn infer_deadline(
    entry: &ModelEntry,
    image: Vec<i32>,
    deadline: Duration,
    typed_expiry: bool,
) -> std::result::Result<(u64, Vec<f32>), InferFail> {
    let rx = entry.client().submit_deadline(image, deadline).map_err(|e| match e {
        SubmitError::QueueFull { .. } if typed_expiry => InferFail::Expired(format!(
            "deadline expired after {}ms waiting for model {:?}",
            deadline.as_millis(),
            entry.name
        )),
        SubmitError::QueueFull { .. } => {
            InferFail::Other(format!("model {:?} overloaded: all shard queues full", entry.name))
        }
        SubmitError::Shutdown => InferFail::Other(format!("model {:?} pool shut down", entry.name)),
        SubmitError::ShardDown { .. } => InferFail::Other(format!(
            "model {:?} pool down: all shards crashed or breaker-open",
            entry.name
        )),
    })?;
    let reply = rx.recv().map_err(|_| {
        InferFail::Other(format!("model {:?} pool shut down before replying", entry.name))
    })?;
    let trace_id = reply.trace_id;
    reply.scores.map(|s| (trace_id, s)).map_err(|e| {
        if e.kind == InferErrorKind::Expired {
            InferFail::Expired(e.message)
        } else {
            InferFail::Other(e.message)
        }
    })
}

fn infer_on(entry: &ModelEntry, image: Vec<i32>) -> std::result::Result<(u64, Vec<f32>), String> {
    infer_deadline(entry, image, SUBMIT_DEADLINE, false).map_err(|f| match f {
        InferFail::Expired(m) | InferFail::Other(m) => m,
    })
}

/// Build the deploy spec for a wire `DEPLOY`.  Unset fields (empty
/// backend string, `workers`/`queue_depth` of 0) inherit the pool
/// parameters of the version currently serving under `name`, so a
/// hot-swap does not silently reset a tuned pool to defaults; a fresh
/// name falls back to [`DeploySpec::new`]'s defaults.
fn deploy_from_wire(
    registry: &ModelRegistry,
    name: &str,
    source: &str,
    backend: &str,
    workers: usize,
    queue_depth: usize,
) -> Result<u64> {
    let model: BcnnModel = ModelSource::parse(source)?.load()?;
    let mut spec = DeploySpec::new(model);
    if let Some((b, w, q, p)) = registry.current_params(name) {
        spec = spec.with_backend(b).with_workers(w).with_queue_depth(q).with_policy(p);
    }
    if !backend.is_empty() {
        spec = spec.with_backend(BackendSpec::parse(backend)?);
    }
    if workers > 0 {
        spec = spec.with_workers(workers);
    }
    if queue_depth > 0 {
        spec = spec.with_queue_depth(queue_depth);
    }
    registry.deploy(name, spec)
}

// ---------------------------------------------------------------------------
// reply frame builders (shared by both front-ends)
// ---------------------------------------------------------------------------

/// `REPLY_SCORES` frame bytes: version, trace ID, count, f32 LE values.
fn v2_scores_frame(version: u64, trace_id: u64, scores: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(24 + scores.len() * 4);
    out.extend_from_slice(&REPLY_SCORES.to_le_bytes());
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
    out.extend_from_slice(&(scores.len() as u32).to_le_bytes());
    for s in scores {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out
}

/// `REPLY_EXPIRED` frame bytes (tag, length, message).
fn expired_frame(msg: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + msg.len());
    out.extend_from_slice(&REPLY_EXPIRED.to_le_bytes());
    out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    out.extend_from_slice(msg.as_bytes());
    out
}

/// `REPLY_OK` + version on success, an error frame otherwise.
fn version_frame(result: Result<u64>) -> Vec<u8> {
    match result {
        Ok(version) => {
            let mut out = Vec::with_capacity(12);
            out.extend_from_slice(&REPLY_OK.to_le_bytes());
            out.extend_from_slice(&version.to_le_bytes());
            out
        }
        Err(e) => error_frame(&format!("{e:#}")),
    }
}

/// `REPLY_JSON` frame bytes (tag, length, serialized document).
fn json_frame(json: &Json) -> Vec<u8> {
    let text = json.to_string();
    let mut out = Vec::with_capacity(8 + text.len());
    out.extend_from_slice(&REPLY_JSON.to_le_bytes());
    out.extend_from_slice(&(text.len() as u32).to_le_bytes());
    out.extend_from_slice(text.as_bytes());
    out
}

fn admin_json(op: JsonOp, registry: &ModelRegistry) -> Json {
    match op {
        JsonOp::List => list_json(registry),
        JsonOp::Stats => stats_json(registry),
        JsonOp::Health => health_json(registry),
        JsonOp::Trace => crate::obs::chrome_trace_json(),
        JsonOp::Profile => profile_json(registry),
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// `LIST` payload: the routing table as JSON.
pub fn list_json(registry: &ModelRegistry) -> Json {
    let router = registry.router();
    let table = router.snapshot();
    let models: Vec<Json> = table
        .entries
        .values()
        .map(|e| {
            obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("version", Json::Num(e.version as f64)),
                ("backend", Json::Str(e.backend.clone())),
                ("config", Json::Str(e.config.name.clone())),
                ("classes", Json::Num(e.config.classes as f64)),
                ("workers", Json::Num(e.workers() as f64)),
                ("age_s", Json::Num(e.deployed.elapsed().as_secs_f64())),
                ("default", Json::Bool(table.default.as_deref() == Some(e.name.as_str()))),
            ])
        })
        .collect();
    obj(vec![("epoch", Json::Num(table.epoch as f64)), ("models", Json::Arr(models))])
}

/// `STATS` payload: per-model serving metrics across versions, the
/// rolling windowed telemetry under `"windows"` (advanced here so a
/// stats poller is itself enough to keep the windows fresh), and the
/// front-end's per-lane QoS admission counters under `"frontend"`
/// (all-zero when the threaded fallback is serving).
pub fn stats_json(registry: &ModelRegistry) -> Json {
    registry.tick_windows();
    let rows: Vec<Json> = registry
        .stats()
        .into_iter()
        .map(|s| {
            obj(vec![
                ("name", Json::Str(s.name)),
                ("version", Json::Num(s.version as f64)),
                ("live", Json::Bool(s.live)),
                ("backend", Json::Str(s.backend)),
                ("config", Json::Str(s.config)),
                ("metrics", s.metrics.to_json()),
            ])
        })
        .collect();
    obj(vec![
        ("epoch", Json::Num(registry.epoch() as f64)),
        ("frontend", crate::coordinator::frontend_json()),
        ("models", Json::Arr(rows)),
        ("windows", registry.windows_json()),
    ])
}

/// `HEALTH` payload: per-model pool supervision state — ready/degraded/
/// down plus per-shard crash/restart counters.  The admin-plane view of
/// the degradation ladder: a "degraded" model is still serving on its
/// surviving shards, a "down" model only answers via router failover.
pub fn health_json(registry: &ModelRegistry) -> Json {
    let models: Vec<Json> = registry
        .list()
        .into_iter()
        .map(|e| {
            let health = e.health();
            let shards: Vec<Json> = health
                .shards
                .iter()
                .map(|s| {
                    obj(vec![
                        ("state", Json::Str(s.state.label().to_string())),
                        ("crashes", Json::Num(s.crashes as f64)),
                        ("restarts", Json::Num(s.restarts as f64)),
                    ])
                })
                .collect();
            obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("version", Json::Num(e.version as f64)),
                ("state", Json::Str(health.label().to_string())),
                ("shards", Json::Arr(shards)),
            ])
        })
        .collect();
    obj(vec![("epoch", Json::Num(registry.epoch() as f64)), ("models", Json::Arr(models))])
}

/// `PROFILE` payload: the performance-accounting report per staged
/// model — each pipeline-backed entry's cumulative work ledger reconciled
/// against eqs. 9–12 ([`crate::obs::account::reconcile`]).  Raw counters
/// travel with the derived fields so a poller (`repro profile
/// --duration`) can difference two frames into a windowed view.
/// Engine-backed entries have no stage ledger and are skipped.
pub fn profile_json(registry: &ModelRegistry) -> Json {
    let models: Vec<Json> = registry
        .list()
        .into_iter()
        .filter_map(|e| {
            let metrics = e.metrics();
            if metrics.stages.is_empty() {
                return None;
            }
            let report = match crate::obs::account::reconcile(&e.config, &metrics.stages) {
                Ok(r) => r.to_json(),
                Err(err) => obj(vec![("error", Json::Str(err.to_string()))]),
            };
            Some(obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("version", Json::Num(e.version as f64)),
                ("backend", Json::Str(e.backend.clone())),
                ("kernel", Json::Str(metrics.kernel.clone())),
                ("report", report),
            ]))
        })
        .collect();
    obj(vec![("epoch", Json::Num(registry.epoch() as f64)), ("models", Json::Arr(models))])
}

// ---------------------------------------------------------------------------
// frame primitives
// ---------------------------------------------------------------------------

fn read_u32(stream: &mut TcpStream) -> Result<u32> {
    let mut buf = [0u8; 4];
    stream.read_exact(&mut buf).context("reading u32")?;
    Ok(u32::from_le_bytes(buf))
}

fn read_u64(stream: &mut TcpStream) -> Result<u64> {
    let mut buf = [0u8; 8];
    stream.read_exact(&mut buf).context("reading u64")?;
    Ok(u64::from_le_bytes(buf))
}

fn read_string(stream: &mut TcpStream) -> Result<String> {
    let mut len = [0u8; 2];
    stream.read_exact(&mut len).context("reading string length")?;
    let mut buf = vec![0u8; u16::from_le_bytes(len) as usize];
    stream.read_exact(&mut buf).context("reading string")?;
    String::from_utf8(buf).context("string is not UTF-8")
}

fn read_image(stream: &mut TcpStream, n: usize) -> Result<Vec<i32>> {
    let mut raw = vec![0u8; n * 4];
    stream.read_exact(&mut raw).context("reading image payload")?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn push_string(out: &mut Vec<u8>, s: &str) -> Result<()> {
    if s.len() > u16::MAX as usize {
        bail!("string too long for wire ({} bytes)", s.len());
    }
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// A v2 inference reply: the scores plus which model *version* served it
/// (the hot-swap observability hook: clients can pin replies to versions)
/// and the request's end-to-end trace ID (its key into the `OP_TRACE`
/// span export; 0 means the server recorded no spans).
#[derive(Debug, Clone, PartialEq)]
pub struct VersionedScores {
    pub version: u64,
    pub trace_id: u64,
    pub scores: Vec<f32>,
}

/// Typed outcome of a QoS-lane inference: scores, or a server-side
/// deadline expiry (the request was shed before dispatch; the connection
/// stays usable — retry or fall back as the SLO dictates).
#[derive(Debug, Clone, PartialEq)]
pub enum InferOutcome {
    Scores(VersionedScores),
    Expired(String),
}

/// Blocking protocol-v2 client (inference + admin plane).  Server-sent
/// error frames surface as `Err` but leave the connection usable.
pub struct ControlClient {
    stream: TcpStream,
}

impl ControlClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream })
    }

    /// Classify one image on `model` (empty = server's default model).
    pub fn infer(&mut self, model: &str, image: &[i32]) -> Result<VersionedScores> {
        let mut out = Vec::with_capacity(10 + model.len() + image.len() * 4);
        out.extend_from_slice(&OP_INFER.to_le_bytes());
        push_string(&mut out, model)?;
        out.extend_from_slice(&(image.len() as u32).to_le_bytes());
        for v in image {
            out.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&out)?;
        self.expect(REPLY_SCORES)?;
        self.read_scores()
    }

    /// Classify one image on `model` with an explicit QoS class.  `lane`
    /// picks the admission lane (online = latency-bound, offline =
    /// throughput); `deadline` bounds how long the request may wait for
    /// dispatch (`None` = the server's default for the lane).  A request
    /// the server shed on deadline comes back as
    /// [`InferOutcome::Expired`] — a typed outcome, not an error — and
    /// the connection stays usable.
    pub fn infer_qos(
        &mut self,
        model: &str,
        lane: Lane,
        deadline: Option<Duration>,
        image: &[i32],
    ) -> Result<InferOutcome> {
        let deadline_ms = deadline.map_or(0u32, |d| d.as_millis().min(u128::from(u32::MAX)) as u32);
        let mut out = Vec::with_capacity(18 + model.len() + image.len() * 4);
        out.extend_from_slice(&OP_INFER_QOS.to_le_bytes());
        push_string(&mut out, model)?;
        out.extend_from_slice(&lane.wire().to_le_bytes());
        out.extend_from_slice(&deadline_ms.to_le_bytes());
        out.extend_from_slice(&(image.len() as u32).to_le_bytes());
        for v in image {
            out.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&out)?;
        let tag = read_u32(&mut self.stream)?;
        if tag == REPLY_EXPIRED {
            let len = read_u32(&mut self.stream)? as usize;
            let mut msg = vec![0u8; len];
            self.stream.read_exact(&mut msg)?;
            return Ok(InferOutcome::Expired(String::from_utf8_lossy(&msg).into_owned()));
        }
        if tag == WIRE_ERROR {
            let len = read_u32(&mut self.stream)? as usize;
            let mut msg = vec![0u8; len];
            self.stream.read_exact(&mut msg)?;
            bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        if tag != REPLY_SCORES {
            bail!("unexpected reply tag {tag:#010x} (wanted {REPLY_SCORES:#010x})");
        }
        Ok(InferOutcome::Scores(self.read_scores()?))
    }

    /// Decode the body of a `REPLY_SCORES` frame (tag already consumed).
    fn read_scores(&mut self) -> Result<VersionedScores> {
        let version = read_u64(&mut self.stream)?;
        let trace_id = read_u64(&mut self.stream)?;
        let n = read_u32(&mut self.stream)? as usize;
        let mut raw = vec![0u8; n * 4];
        self.stream.read_exact(&mut raw)?;
        let scores = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(VersionedScores { version, trace_id, scores })
    }

    /// Deploy (or hot-swap) `name` from `source` (a server-side `.bcnn`
    /// path or `synthetic:<config>[:<seed>]`).  An empty `backend` and
    /// `workers`/`queue_depth` of 0 inherit the currently-deployed
    /// pool's parameters (or the server defaults for a fresh name).
    /// Returns the new version.
    pub fn deploy(
        &mut self,
        name: &str,
        source: &str,
        backend: &str,
        workers: usize,
        queue_depth: usize,
    ) -> Result<u64> {
        let mut out = Vec::new();
        out.extend_from_slice(&OP_DEPLOY.to_le_bytes());
        push_string(&mut out, name)?;
        push_string(&mut out, source)?;
        push_string(&mut out, backend)?;
        out.extend_from_slice(&(workers as u32).to_le_bytes());
        out.extend_from_slice(&(queue_depth as u32).to_le_bytes());
        self.stream.write_all(&out)?;
        self.expect(REPLY_OK)?;
        read_u64(&mut self.stream)
    }

    pub fn undeploy(&mut self, name: &str) -> Result<u64> {
        self.name_op(OP_UNDEPLOY, name)
    }

    pub fn rollback(&mut self, name: &str) -> Result<u64> {
        self.name_op(OP_ROLLBACK, name)
    }

    fn name_op(&mut self, op: u32, name: &str) -> Result<u64> {
        let mut out = Vec::new();
        out.extend_from_slice(&op.to_le_bytes());
        push_string(&mut out, name)?;
        self.stream.write_all(&out)?;
        self.expect(REPLY_OK)?;
        read_u64(&mut self.stream)
    }

    pub fn list(&mut self) -> Result<Json> {
        self.json_op(OP_LIST)
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.json_op(OP_STATS)
    }

    /// Per-model pool health (supervision state + shard crash/restart
    /// counters).
    pub fn health(&mut self) -> Result<Json> {
        self.json_op(OP_HEALTH)
    }

    /// The server's span rings as a Chrome trace-event JSON document —
    /// write it to a file and load it in Perfetto / `chrome://tracing`.
    pub fn trace(&mut self) -> Result<Json> {
        self.json_op(OP_TRACE)
    }

    /// The performance-accounting report: per staged model, the work
    /// ledger reconciled against the paper's eqs. 9–12 (utilization,
    /// roofline bound class, measured-vs-predicted bottleneck).
    pub fn profile(&mut self) -> Result<Json> {
        self.json_op(OP_PROFILE)
    }

    fn json_op(&mut self, op: u32) -> Result<Json> {
        self.stream.write_all(&op.to_le_bytes())?;
        self.expect(REPLY_JSON)?;
        let len = read_u32(&mut self.stream)? as usize;
        let mut raw = vec![0u8; len];
        self.stream.read_exact(&mut raw)?;
        Json::parse(std::str::from_utf8(&raw).context("JSON reply is not UTF-8")?)
    }

    /// Read a reply tag; decode an error frame into `Err` (connection
    /// stays usable), fail hard on an unexpected tag.
    fn expect(&mut self, want: u32) -> Result<()> {
        let tag = read_u32(&mut self.stream)?;
        if tag == want {
            return Ok(());
        }
        if tag == WIRE_ERROR {
            let len = read_u32(&mut self.stream)? as usize;
            let mut msg = vec![0u8; len];
            self.stream.read_exact(&mut msg)?;
            bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        bail!("unexpected reply tag {tag:#010x} (wanted {want:#010x})");
    }

    pub fn close(mut self) -> Result<()> {
        self.stream.write_all(&0u32.to_le_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn infer_qos_bytes(name: &str, lane: u32, deadline_ms: u32, image: &[i32]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&OP_INFER_QOS.to_le_bytes());
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&lane.to_le_bytes());
        out.extend_from_slice(&deadline_ms.to_le_bytes());
        out.extend_from_slice(&(image.len() as u32).to_le_bytes());
        for v in image {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn parse_is_incremental_at_every_split_point() {
        let frame = infer_qos_bytes("m", 1, 250, &[1, -2, 3]);
        for cut in 0..frame.len() {
            assert!(
                parse_frame(&frame[..cut]).is_none(),
                "prefix of {cut}/{} bytes must parse as incomplete",
                frame.len()
            );
        }
        let (parsed, used) = parse_frame(&frame).expect("complete frame parses");
        assert_eq!(used, frame.len());
        match parsed {
            WireFrame::Infer { name, lane, deadline_ms, image, style } => {
                assert_eq!(name, "m");
                assert_eq!(lane, Lane::Offline);
                assert_eq!(deadline_ms, 250);
                assert_eq!(image, vec![1, -2, 3]);
                assert_eq!(style, ReplyStyle::V2);
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn parse_v1_close_and_pipelined_frames() {
        // two v1 frames (length tags) then a close, back to back
        let mut buf = Vec::new();
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&5i32.to_le_bytes());
        buf.extend_from_slice(&(-7i32).to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&9i32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());

        let (f1, u1) = parse_frame(&buf).unwrap();
        match f1 {
            WireFrame::Infer { image, style, lane, .. } => {
                assert_eq!(image, vec![5, -7]);
                assert_eq!(style, ReplyStyle::V1);
                assert_eq!(lane, Lane::Online);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        let (f2, u2) = parse_frame(&buf[u1..]).unwrap();
        assert!(matches!(f2, WireFrame::Infer { ref image, .. } if *image == vec![9]));
        let (f3, _) = parse_frame(&buf[u1 + u2..]).unwrap();
        assert_eq!(f3, WireFrame::Close);
    }

    #[test]
    fn parse_classifies_oversize_and_garbage() {
        // bounded oversize: discard-and-continue
        let n = (MAX_WIRE_VALUES + 1) as u32;
        let (frame, used) = parse_frame(&n.to_le_bytes()).unwrap();
        assert_eq!(used, 4);
        match frame {
            WireFrame::Discard { skip, message } => {
                assert_eq!(skip, u64::from(n) * 4);
                assert!(message.contains("too large"), "{message}");
            }
            other => panic!("wrong frame: {other:?}"),
        }
        // implausible length: protocol garbage, close
        let (frame, _) = parse_frame(&0xFEFF_FFFFu32.to_le_bytes()).unwrap();
        assert!(matches!(frame, WireFrame::Fatal(ref m) if m.contains("too large")), "{frame:?}");
        // unknown v2 tag: close
        let (frame, _) = parse_frame(&0xBC20_00FFu32.to_le_bytes()).unwrap();
        assert!(matches!(frame, WireFrame::Fatal(ref m) if m.contains("unknown frame tag")));
    }

    #[test]
    fn parse_rejects_bad_lane_and_zero_size_without_closing() {
        let frame = infer_qos_bytes("m", 7, 0, &[1]);
        let (parsed, used) = parse_frame(&frame).unwrap();
        assert_eq!(used, frame.len());
        assert!(matches!(parsed, WireFrame::Reject(ref m) if m.contains("invalid lane")));

        let mut zero = Vec::new();
        zero.extend_from_slice(&OP_INFER.to_le_bytes());
        zero.extend_from_slice(&0u16.to_le_bytes());
        zero.extend_from_slice(&0u32.to_le_bytes());
        let (parsed, used) = parse_frame(&zero).unwrap();
        assert_eq!(used, zero.len());
        assert!(matches!(parsed, WireFrame::Reject(ref m) if m.contains("invalid request size")));
    }

    #[test]
    fn parse_admin_ops_and_deploy() {
        for (op, want) in [
            (OP_LIST, JsonOp::List),
            (OP_STATS, JsonOp::Stats),
            (OP_HEALTH, JsonOp::Health),
            (OP_TRACE, JsonOp::Trace),
            (OP_PROFILE, JsonOp::Profile),
        ] {
            let (frame, used) = parse_frame(&op.to_le_bytes()).unwrap();
            assert_eq!((frame, used), (WireFrame::Admin(want), 4));
        }
        let mut dep = Vec::new();
        dep.extend_from_slice(&OP_DEPLOY.to_le_bytes());
        for s in ["m", "synthetic:tiny", ""] {
            dep.extend_from_slice(&(s.len() as u16).to_le_bytes());
            dep.extend_from_slice(s.as_bytes());
        }
        dep.extend_from_slice(&2u32.to_le_bytes());
        dep.extend_from_slice(&8u32.to_le_bytes());
        assert!(parse_frame(&dep[..dep.len() - 1]).is_none());
        let (frame, used) = parse_frame(&dep).unwrap();
        assert_eq!(used, dep.len());
        assert_eq!(
            frame,
            WireFrame::Deploy {
                name: "m".into(),
                source: "synthetic:tiny".into(),
                backend: String::new(),
                workers: 2,
                queue_depth: 8,
            }
        );
    }
}
