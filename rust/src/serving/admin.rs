//! Protocol v2: model-routed inference frames plus the admin plane
//! (`DEPLOY` / `UNDEPLOY` / `ROLLBACK` / `LIST` / `STATS`) over the same
//! TCP front-end.
//!
//! Wire format (little-endian).  The first `u32` of every frame is a tag.
//! Protocol-v1 clients are still served: a tag in `1..=MAX_WIRE_VALUES`
//! *is* a v1 request length, and is answered with a v1 reply on the
//! default model — so old clients keep working against a v2 server.
//!
//! ```text
//! tag 0                        close connection (v1 semantics)
//! tag 1..=MAX_WIRE_VALUES      v1 request: tag x i32 values -> u32 n, n x f32
//! OP_INFER    name, u32 n, n x i32   -> REPLY_SCORES, u64 version,
//!                                       u64 trace_id, u32 n, n x f32
//! OP_DEPLOY   name, source, backend, u32 workers, u32 queue_depth
//!                                    -> REPLY_OK, u64 version
//! OP_UNDEPLOY name                   -> REPLY_OK, u64 retired version
//! OP_ROLLBACK name                   -> REPLY_OK, u64 new version
//! OP_LIST                            -> REPLY_JSON, u32 len, bytes
//! OP_STATS                           -> REPLY_JSON, u32 len, bytes
//! OP_HEALTH                          -> REPLY_JSON, u32 len, bytes
//! OP_TRACE                           -> REPLY_JSON, u32 len, bytes
//! OP_PROFILE                         -> REPLY_JSON, u32 len, bytes
//! error (any op)                     -> 0xFFFF_FFFF, u32 len, msg bytes
//! ```
//!
//! `OP_TRACE` returns the server's span rings as a Chrome trace-event
//! JSON document (load it in Perfetto / `chrome://tracing`); the
//! `trace_id` in every `REPLY_SCORES` frame correlates a reply with its
//! spans there.
//!
//! Strings are `u16 len + UTF-8 bytes`.  Error frames do **not** close
//! the connection (the next request may route to a healthy model); only
//! malformed framing does.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::server::{
    reject_payload, serve_connections, write_error, MAX_WIRE_VALUES, TCP_SUBMIT_DEADLINE,
    WIRE_ERROR,
};
use crate::coordinator::SubmitError;
use crate::model::BcnnModel;
use crate::serving::registry::{BackendSpec, DeploySpec, ModelEntry, ModelRegistry, ModelSource};
use crate::util::json::Json;

/// v2 frame tags.  All sit far above [`MAX_WIRE_VALUES`] (a v1 length)
/// and below [`WIRE_ERROR`], so the three frame families cannot collide.
pub const OP_INFER: u32 = 0xBC20_0001;
pub const OP_DEPLOY: u32 = 0xBC20_0002;
pub const OP_UNDEPLOY: u32 = 0xBC20_0003;
pub const OP_ROLLBACK: u32 = 0xBC20_0004;
pub const OP_LIST: u32 = 0xBC20_0005;
pub const OP_STATS: u32 = 0xBC20_0006;
pub const OP_HEALTH: u32 = 0xBC20_0007;
pub const OP_TRACE: u32 = 0xBC20_0008;
pub const OP_PROFILE: u32 = 0xBC20_0009;
pub const REPLY_SCORES: u32 = 0xBC20_0081;
pub const REPLY_OK: u32 = 0xBC20_0082;
pub const REPLY_JSON: u32 = 0xBC20_0083;

/// How long a handler waits out backpressure before sending the client a
/// typed overload error instead of stalling the connection (shared with
/// the v1 front-end).
pub const SUBMIT_DEADLINE: Duration = TCP_SUBMIT_DEADLINE;

/// Serve the registry on a TCP listener until `stop` flips (thread per
/// connection, sharing the v1 front-end's accept loop).  Idle accept
/// polls reap drained retired pools, so a hot-swapped-out model's
/// threads and weights are freed promptly even on a server that only
/// ever sees inference traffic after the swap.
pub fn serve_registry(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let handler: Arc<dyn Fn(TcpStream) + Send + Sync> = {
        let registry = Arc::clone(&registry);
        Arc::new(move |stream| {
            let _ = handle_conn(stream, &registry);
        })
    };
    serve_connections(listener, stop, handler, move || {
        registry.reap_retired();
        registry.tick_windows();
    })
}

fn handle_conn(mut stream: TcpStream, registry: &ModelRegistry) -> Result<()> {
    stream.set_nodelay(true).ok();
    let router = registry.router();
    loop {
        let mut tag_buf = [0u8; 4];
        if stream.read_exact(&mut tag_buf).is_err() {
            return Ok(()); // peer closed
        }
        let tag = u32::from_le_bytes(tag_buf);
        match tag {
            0 => return Ok(()),
            // ---- protocol-v1 compatibility: tag is the request length --
            n if (n as usize) <= MAX_WIRE_VALUES => {
                let image = read_image(&mut stream, n as usize)?;
                let entry = match router.resolve_healthy(None) {
                    Ok(e) => e,
                    Err(e) => {
                        write_error(&mut stream, &e.to_string())?;
                        continue;
                    }
                };
                match infer_on(&entry, image) {
                    Ok((_trace_id, scores)) => {
                        let mut out = Vec::with_capacity(4 + scores.len() * 4);
                        out.extend_from_slice(&(scores.len() as u32).to_le_bytes());
                        for s in &scores {
                            out.extend_from_slice(&s.to_le_bytes());
                        }
                        stream.write_all(&out)?;
                    }
                    Err(msg) => write_error(&mut stream, &msg)?,
                }
            }
            // ---- oversized v1 length: discard payload, reject, go on ---
            // (bounded — an implausible length or a stalled peer closes
            // the connection instead of pinning this thread)
            n if n != WIRE_ERROR && (n >> 24) != 0xBC => {
                reject_payload(&mut stream, n as usize, &format!("request too large: {n} values"))?;
            }
            OP_INFER => {
                let name = read_string(&mut stream)?;
                let n = read_u32(&mut stream)? as usize;
                if n == 0 || n > MAX_WIRE_VALUES {
                    reject_payload(&mut stream, n, &format!("invalid request size: {n} values"))?;
                    continue;
                }
                let image = read_image(&mut stream, n)?;
                let sel = if name.is_empty() { None } else { Some(name.as_str()) };
                let entry = match router.resolve_healthy(sel) {
                    Ok(e) => e,
                    Err(e) => {
                        write_error(&mut stream, &e.to_string())?;
                        continue;
                    }
                };
                match infer_on(&entry, image) {
                    Ok((trace_id, scores)) => {
                        let mut out = Vec::with_capacity(24 + scores.len() * 4);
                        out.extend_from_slice(&REPLY_SCORES.to_le_bytes());
                        out.extend_from_slice(&entry.version.to_le_bytes());
                        out.extend_from_slice(&trace_id.to_le_bytes());
                        out.extend_from_slice(&(scores.len() as u32).to_le_bytes());
                        for s in &scores {
                            out.extend_from_slice(&s.to_le_bytes());
                        }
                        stream.write_all(&out)?;
                    }
                    Err(msg) => write_error(&mut stream, &msg)?,
                }
            }
            OP_DEPLOY => {
                let name = read_string(&mut stream)?;
                let source = read_string(&mut stream)?;
                let backend = read_string(&mut stream)?;
                let workers = read_u32(&mut stream)? as usize;
                let queue_depth = read_u32(&mut stream)? as usize;
                let result =
                    deploy_from_wire(registry, &name, &source, &backend, workers, queue_depth);
                reply_version(&mut stream, result)?;
            }
            OP_UNDEPLOY => {
                let name = read_string(&mut stream)?;
                reply_version(&mut stream, registry.undeploy(&name))?;
            }
            OP_ROLLBACK => {
                let name = read_string(&mut stream)?;
                reply_version(&mut stream, registry.rollback(&name))?;
            }
            OP_LIST => {
                let json = list_json(registry);
                write_json(&mut stream, &json)?;
            }
            OP_STATS => {
                let json = stats_json(registry);
                write_json(&mut stream, &json)?;
            }
            OP_HEALTH => {
                let json = health_json(registry);
                write_json(&mut stream, &json)?;
            }
            OP_TRACE => {
                let json = crate::obs::chrome_trace_json();
                write_json(&mut stream, &json)?;
            }
            OP_PROFILE => {
                let json = profile_json(registry);
                write_json(&mut stream, &json)?;
            }
            other => {
                let _ = write_error(&mut stream, &format!("unknown frame tag {other:#010x}"));
                bail!("unknown frame tag {other:#010x}");
            }
        }
    }
}

/// Submit to one entry's pool with a deadline; a saturated pool yields an
/// error string (sent as an error frame) instead of a stalled connection.
/// Returns the reply's trace ID with the scores so v2 frames can carry
/// it (the coordinator records every span *before* sending the reply, so
/// a client that sees this ID will find its spans in `OP_TRACE`).
fn infer_on(entry: &ModelEntry, image: Vec<i32>) -> std::result::Result<(u64, Vec<f32>), String> {
    let rx = entry
        .client()
        .submit_deadline(image, SUBMIT_DEADLINE)
        .map_err(|e| match e {
            SubmitError::QueueFull { .. } => {
                format!("model {:?} overloaded: all shard queues full", entry.name)
            }
            SubmitError::Shutdown => format!("model {:?} pool shut down", entry.name),
            SubmitError::ShardDown { .. } => {
                format!("model {:?} pool down: all shards crashed or breaker-open", entry.name)
            }
        })?;
    let reply = rx
        .recv()
        .map_err(|_| format!("model {:?} pool shut down before replying", entry.name))?;
    let trace_id = reply.trace_id;
    reply.scores.map(|s| (trace_id, s)).map_err(|e| e.message)
}

/// Build the deploy spec for a wire `DEPLOY`.  Unset fields (empty
/// backend string, `workers`/`queue_depth` of 0) inherit the pool
/// parameters of the version currently serving under `name`, so a
/// hot-swap does not silently reset a tuned pool to defaults; a fresh
/// name falls back to [`DeploySpec::new`]'s defaults.
fn deploy_from_wire(
    registry: &ModelRegistry,
    name: &str,
    source: &str,
    backend: &str,
    workers: usize,
    queue_depth: usize,
) -> Result<u64> {
    let model: BcnnModel = ModelSource::parse(source)?.load()?;
    let mut spec = DeploySpec::new(model);
    if let Some((b, w, q, p)) = registry.current_params(name) {
        spec = spec.with_backend(b).with_workers(w).with_queue_depth(q).with_policy(p);
    }
    if !backend.is_empty() {
        spec = spec.with_backend(BackendSpec::parse(backend)?);
    }
    if workers > 0 {
        spec = spec.with_workers(workers);
    }
    if queue_depth > 0 {
        spec = spec.with_queue_depth(queue_depth);
    }
    registry.deploy(name, spec)
}

fn reply_version(stream: &mut TcpStream, result: Result<u64>) -> std::io::Result<()> {
    match result {
        Ok(version) => {
            let mut out = Vec::with_capacity(12);
            out.extend_from_slice(&REPLY_OK.to_le_bytes());
            out.extend_from_slice(&version.to_le_bytes());
            stream.write_all(&out)
        }
        Err(e) => write_error(stream, &format!("{e:#}")),
    }
}

fn write_json(stream: &mut TcpStream, json: &Json) -> std::io::Result<()> {
    let text = json.to_string();
    let mut out = Vec::with_capacity(8 + text.len());
    out.extend_from_slice(&REPLY_JSON.to_le_bytes());
    out.extend_from_slice(&(text.len() as u32).to_le_bytes());
    out.extend_from_slice(text.as_bytes());
    stream.write_all(&out)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// `LIST` payload: the routing table as JSON.
pub fn list_json(registry: &ModelRegistry) -> Json {
    let router = registry.router();
    let table = router.snapshot();
    let models: Vec<Json> = table
        .entries
        .values()
        .map(|e| {
            obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("version", Json::Num(e.version as f64)),
                ("backend", Json::Str(e.backend.clone())),
                ("config", Json::Str(e.config.name.clone())),
                ("classes", Json::Num(e.config.classes as f64)),
                ("workers", Json::Num(e.workers() as f64)),
                ("age_s", Json::Num(e.deployed.elapsed().as_secs_f64())),
                ("default", Json::Bool(table.default.as_deref() == Some(e.name.as_str()))),
            ])
        })
        .collect();
    obj(vec![("epoch", Json::Num(table.epoch as f64)), ("models", Json::Arr(models))])
}

/// `STATS` payload: per-model serving metrics across versions, plus the
/// rolling windowed telemetry under `"windows"` (advanced here so a
/// stats poller is itself enough to keep the windows fresh).
pub fn stats_json(registry: &ModelRegistry) -> Json {
    registry.tick_windows();
    let rows: Vec<Json> = registry
        .stats()
        .into_iter()
        .map(|s| {
            obj(vec![
                ("name", Json::Str(s.name)),
                ("version", Json::Num(s.version as f64)),
                ("live", Json::Bool(s.live)),
                ("backend", Json::Str(s.backend)),
                ("config", Json::Str(s.config)),
                ("metrics", s.metrics.to_json()),
            ])
        })
        .collect();
    obj(vec![
        ("epoch", Json::Num(registry.epoch() as f64)),
        ("models", Json::Arr(rows)),
        ("windows", registry.windows_json()),
    ])
}

/// `HEALTH` payload: per-model pool supervision state — ready/degraded/
/// down plus per-shard crash/restart counters.  The admin-plane view of
/// the degradation ladder: a "degraded" model is still serving on its
/// surviving shards, a "down" model only answers via router failover.
pub fn health_json(registry: &ModelRegistry) -> Json {
    let models: Vec<Json> = registry
        .list()
        .into_iter()
        .map(|e| {
            let health = e.health();
            let shards: Vec<Json> = health
                .shards
                .iter()
                .map(|s| {
                    obj(vec![
                        ("state", Json::Str(s.state.label().to_string())),
                        ("crashes", Json::Num(s.crashes as f64)),
                        ("restarts", Json::Num(s.restarts as f64)),
                    ])
                })
                .collect();
            obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("version", Json::Num(e.version as f64)),
                ("state", Json::Str(health.label().to_string())),
                ("shards", Json::Arr(shards)),
            ])
        })
        .collect();
    obj(vec![("epoch", Json::Num(registry.epoch() as f64)), ("models", Json::Arr(models))])
}

/// `PROFILE` payload: the performance-accounting report per staged
/// model — each pipeline-backed entry's cumulative work ledger reconciled
/// against eqs. 9–12 ([`crate::obs::account::reconcile`]).  Raw counters
/// travel with the derived fields so a poller (`repro profile
/// --duration`) can difference two frames into a windowed view.
/// Engine-backed entries have no stage ledger and are skipped.
pub fn profile_json(registry: &ModelRegistry) -> Json {
    let models: Vec<Json> = registry
        .list()
        .into_iter()
        .filter_map(|e| {
            let metrics = e.metrics();
            if metrics.stages.is_empty() {
                return None;
            }
            let report = match crate::obs::account::reconcile(&e.config, &metrics.stages) {
                Ok(r) => r.to_json(),
                Err(err) => obj(vec![("error", Json::Str(err.to_string()))]),
            };
            Some(obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("version", Json::Num(e.version as f64)),
                ("backend", Json::Str(e.backend.clone())),
                ("kernel", Json::Str(metrics.kernel.clone())),
                ("report", report),
            ]))
        })
        .collect();
    obj(vec![("epoch", Json::Num(registry.epoch() as f64)), ("models", Json::Arr(models))])
}

// ---------------------------------------------------------------------------
// frame primitives
// ---------------------------------------------------------------------------

fn read_u32(stream: &mut TcpStream) -> Result<u32> {
    let mut buf = [0u8; 4];
    stream.read_exact(&mut buf).context("reading u32")?;
    Ok(u32::from_le_bytes(buf))
}

fn read_u64(stream: &mut TcpStream) -> Result<u64> {
    let mut buf = [0u8; 8];
    stream.read_exact(&mut buf).context("reading u64")?;
    Ok(u64::from_le_bytes(buf))
}

fn read_string(stream: &mut TcpStream) -> Result<String> {
    let mut len = [0u8; 2];
    stream.read_exact(&mut len).context("reading string length")?;
    let mut buf = vec![0u8; u16::from_le_bytes(len) as usize];
    stream.read_exact(&mut buf).context("reading string")?;
    String::from_utf8(buf).context("string is not UTF-8")
}

fn read_image(stream: &mut TcpStream, n: usize) -> Result<Vec<i32>> {
    let mut raw = vec![0u8; n * 4];
    stream.read_exact(&mut raw).context("reading image payload")?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn push_string(out: &mut Vec<u8>, s: &str) -> Result<()> {
    if s.len() > u16::MAX as usize {
        bail!("string too long for wire ({} bytes)", s.len());
    }
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// A v2 inference reply: the scores plus which model *version* served it
/// (the hot-swap observability hook: clients can pin replies to versions)
/// and the request's end-to-end trace ID (its key into the `OP_TRACE`
/// span export; 0 means the server recorded no spans).
#[derive(Debug, Clone, PartialEq)]
pub struct VersionedScores {
    pub version: u64,
    pub trace_id: u64,
    pub scores: Vec<f32>,
}

/// Blocking protocol-v2 client (inference + admin plane).  Server-sent
/// error frames surface as `Err` but leave the connection usable.
pub struct ControlClient {
    stream: TcpStream,
}

impl ControlClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream })
    }

    /// Classify one image on `model` (empty = server's default model).
    pub fn infer(&mut self, model: &str, image: &[i32]) -> Result<VersionedScores> {
        let mut out = Vec::with_capacity(10 + model.len() + image.len() * 4);
        out.extend_from_slice(&OP_INFER.to_le_bytes());
        push_string(&mut out, model)?;
        out.extend_from_slice(&(image.len() as u32).to_le_bytes());
        for v in image {
            out.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&out)?;
        self.expect(REPLY_SCORES)?;
        let version = read_u64(&mut self.stream)?;
        let trace_id = read_u64(&mut self.stream)?;
        let n = read_u32(&mut self.stream)? as usize;
        let mut raw = vec![0u8; n * 4];
        self.stream.read_exact(&mut raw)?;
        let scores = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(VersionedScores { version, trace_id, scores })
    }

    /// Deploy (or hot-swap) `name` from `source` (a server-side `.bcnn`
    /// path or `synthetic:<config>[:<seed>]`).  An empty `backend` and
    /// `workers`/`queue_depth` of 0 inherit the currently-deployed
    /// pool's parameters (or the server defaults for a fresh name).
    /// Returns the new version.
    pub fn deploy(
        &mut self,
        name: &str,
        source: &str,
        backend: &str,
        workers: usize,
        queue_depth: usize,
    ) -> Result<u64> {
        let mut out = Vec::new();
        out.extend_from_slice(&OP_DEPLOY.to_le_bytes());
        push_string(&mut out, name)?;
        push_string(&mut out, source)?;
        push_string(&mut out, backend)?;
        out.extend_from_slice(&(workers as u32).to_le_bytes());
        out.extend_from_slice(&(queue_depth as u32).to_le_bytes());
        self.stream.write_all(&out)?;
        self.expect(REPLY_OK)?;
        read_u64(&mut self.stream)
    }

    pub fn undeploy(&mut self, name: &str) -> Result<u64> {
        self.name_op(OP_UNDEPLOY, name)
    }

    pub fn rollback(&mut self, name: &str) -> Result<u64> {
        self.name_op(OP_ROLLBACK, name)
    }

    fn name_op(&mut self, op: u32, name: &str) -> Result<u64> {
        let mut out = Vec::new();
        out.extend_from_slice(&op.to_le_bytes());
        push_string(&mut out, name)?;
        self.stream.write_all(&out)?;
        self.expect(REPLY_OK)?;
        read_u64(&mut self.stream)
    }

    pub fn list(&mut self) -> Result<Json> {
        self.json_op(OP_LIST)
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.json_op(OP_STATS)
    }

    /// Per-model pool health (supervision state + shard crash/restart
    /// counters).
    pub fn health(&mut self) -> Result<Json> {
        self.json_op(OP_HEALTH)
    }

    /// The server's span rings as a Chrome trace-event JSON document —
    /// write it to a file and load it in Perfetto / `chrome://tracing`.
    pub fn trace(&mut self) -> Result<Json> {
        self.json_op(OP_TRACE)
    }

    /// The performance-accounting report: per staged model, the work
    /// ledger reconciled against the paper's eqs. 9–12 (utilization,
    /// roofline bound class, measured-vs-predicted bottleneck).
    pub fn profile(&mut self) -> Result<Json> {
        self.json_op(OP_PROFILE)
    }

    fn json_op(&mut self, op: u32) -> Result<Json> {
        self.stream.write_all(&op.to_le_bytes())?;
        self.expect(REPLY_JSON)?;
        let len = read_u32(&mut self.stream)? as usize;
        let mut raw = vec![0u8; len];
        self.stream.read_exact(&mut raw)?;
        Json::parse(std::str::from_utf8(&raw).context("JSON reply is not UTF-8")?)
    }

    /// Read a reply tag; decode an error frame into `Err` (connection
    /// stays usable), fail hard on an unexpected tag.
    fn expect(&mut self, want: u32) -> Result<()> {
        let tag = read_u32(&mut self.stream)?;
        if tag == want {
            return Ok(());
        }
        if tag == WIRE_ERROR {
            let len = read_u32(&mut self.stream)? as usize;
            let mut msg = vec![0u8; len];
            self.stream.read_exact(&mut msg)?;
            bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        bail!("unexpected reply tag {tag:#010x} (wanted {want:#010x})");
    }

    pub fn close(mut self) -> Result<()> {
        self.stream.write_all(&0u32.to_le_bytes())?;
        Ok(())
    }
}
