//! Wire-level model routing: an epoch-tagged, atomically-swapped routing
//! table.
//!
//! The registry *publishes* immutable [`RoutingTable`] snapshots; request
//! handlers *resolve* through a [`Router`], which clones the table `Arc`
//! under a read lock and then works lock-free on the snapshot.  A
//! `deploy`/`undeploy`/`rollback` builds the successor table off to the
//! side and swaps it in one write — readers never observe a half-updated
//! table, and requests that resolved the *old* table keep their
//! `Arc<ModelEntry>` alive until they finish, which is exactly the
//! drain-before-join guarantee the hot-swap needs.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, RwLock};

use crate::serving::registry::ModelEntry;
use crate::util::sync::read_recover;

/// One immutable routing snapshot.  `epoch` increments on every publish,
/// so clients can detect (and log) that a swap happened between requests.
#[derive(Clone, Default)]
pub struct RoutingTable {
    pub epoch: u64,
    pub entries: BTreeMap<String, Arc<ModelEntry>>,
    /// Model that serves protocol-v1 frames (no name field on the wire).
    pub default: Option<String>,
}

/// Routing failure, surfaced to the wire as an error frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    /// Named model is not deployed.
    Unknown(String),
    /// Request named no model and no default is deployed.
    NoDefault,
    /// The routed model's pool is down (circuit breaker open on every
    /// shard) and no compatible healthy entry exists to fail over to.
    Degraded(String),
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::Unknown(name) => write!(f, "no model {name:?} deployed"),
            RouteError::NoDefault => write!(f, "no models deployed"),
            RouteError::Degraded(name) => {
                write!(f, "model {name:?} is down and no compatible healthy model is deployed")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// Shared slot the registry publishes tables into.  A plain
/// `RwLock<Arc<_>>` (no arc-swap crate offline): the write section is two
/// pointer stores, so readers are never blocked for longer than a snapshot
/// clone.
pub(crate) type TableSlot = RwLock<Arc<RoutingTable>>;

/// Read-side handle: cheap to clone, safe to use from any number of
/// connection handler threads.
#[derive(Clone)]
pub struct Router {
    slot: Arc<TableSlot>,
}

impl Router {
    pub(crate) fn new(slot: Arc<TableSlot>) -> Self {
        Self { slot }
    }

    /// Current table snapshot (immutable; holds its entries alive).
    pub fn snapshot(&self) -> Arc<RoutingTable> {
        Arc::clone(&read_recover(&self.slot))
    }

    /// Epoch of the current table.
    pub fn epoch(&self) -> u64 {
        read_recover(&self.slot).epoch
    }

    /// Resolve a request to a model entry.  `None` (or `Some("")`) routes
    /// to the default model — the protocol-v1 compatibility path.
    pub fn resolve(&self, name: Option<&str>) -> Result<Arc<ModelEntry>, RouteError> {
        let table = self.snapshot();
        match name {
            Some(n) if !n.is_empty() => table
                .entries
                .get(n)
                .cloned()
                .ok_or_else(|| RouteError::Unknown(n.to_string())),
            _ => {
                let d = table.default.as_deref().ok_or(RouteError::NoDefault)?;
                table
                    .entries
                    .get(d)
                    .cloned()
                    .ok_or_else(|| RouteError::Unknown(d.to_string()))
            }
        }
    }

    /// [`Router::resolve`] plus pool-health failover: if the routed
    /// entry's pool is down (every shard crashed or breaker-open), route
    /// to another *serviceable* entry serving the same network config —
    /// same input geometry, same classes, bit-exact scores — before
    /// giving up with [`RouteError::Degraded`].  A healthy primary is
    /// always used directly, so failover never steals traffic.
    pub fn resolve_healthy(&self, name: Option<&str>) -> Result<Arc<ModelEntry>, RouteError> {
        let primary = self.resolve(name)?;
        if primary.is_serviceable() {
            return Ok(primary);
        }
        let table = self.snapshot();
        let standby = table.entries.values().find(|e| {
            e.name != primary.name
                && e.config.name == primary.config.name
                && e.is_serviceable()
        });
        match standby {
            Some(entry) => Ok(Arc::clone(entry)),
            None => Err(RouteError::Degraded(primary.name.clone())),
        }
    }

    /// Deployed model names, in table order.
    pub fn names(&self) -> Vec<String> {
        self.snapshot().entries.keys().cloned().collect()
    }
}
