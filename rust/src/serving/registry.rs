//! Multi-model registry: named, versioned model entries, each owning its
//! own sharded [`Coordinator`] pool, with zero-downtime replacement.
//!
//! Deploy flow (`deploy`/`rollback`):
//!
//! 1. the replacement pool is built *off to the side* (weights transposed,
//!    workers spawned) while the old version keeps serving;
//! 2. the routing table is swapped (one epoch bump) — new resolutions land
//!    on the new pool;
//! 3. the old entry moves to the retired list.  Handlers that resolved it
//!    before the swap still hold its `Arc`, so it is only reaped — queue
//!    drained via the coordinator's poison-free shutdown, workers joined,
//!    metrics folded into the model's lineage — once its strong count
//!    falls back to one.  No request is dropped or served by a
//!    half-initialized pool.
//!
//! Per-model serving metrics survive the swap: `stats()` merges the
//! lineage accumulator (reaped pools), still-draining retired pools, and
//! the live pool, so counts always sum to the requests actually served.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::bcnn::Engine;
use crate::coordinator::{
    Backend, BackendFactory, BatchPolicy, Client, Coordinator, CoordinatorConfig, FpgaSimBackend,
    GpuSimBackend, Metrics, NativeBackend, PipelineBackend, PoolHealth, RestartPolicy,
};
use crate::gpu::GpuKernel;
use crate::model::{BcnnModel, NetConfig};
use crate::obs::WindowTracker;
use crate::pipeline::StagePlan;
use crate::serving::router::{Router, RoutingTable, TableSlot};
use crate::util::json::Json;
use crate::util::sync::{lock_recover, read_recover, write_recover};

/// Which backend a model entry's pool replicates (paper backends plus the
/// row-streaming pipeline; see `crate::coordinator::backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendSpec {
    /// Sequential tap-major engine, `lanes` intra-batch threads.
    Engine { lanes: usize },
    /// Row-streaming layer pipeline: `inflight` admission window,
    /// `stage_threads` total stage-lane budget for the calibrated
    /// throughput-balancing plan (`0` = one lane per stage, the
    /// unbalanced pipeline).
    Pipeline { inflight: usize, stage_threads: usize },
    FpgaSim,
    GpuSim,
}

impl BackendSpec {
    /// Parse `engine`, `engine:4`, `pipeline`, `pipeline:8`,
    /// `pipeline:8:12` (inflight, then the stage-lane budget),
    /// `fpga-sim`, `gpu-sim` (the wire/CLI encoding).
    pub fn parse(s: &str) -> Result<Self> {
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        let num = |a: &str| -> Result<usize> {
            a.parse::<usize>()
                .with_context(|| format!("backend parameter {a:?} in {s:?}"))
        };
        match kind {
            "engine" | "native" => Ok(BackendSpec::Engine {
                lanes: arg.map(num).transpose()?.unwrap_or(1).max(1),
            }),
            "pipeline" => {
                let (inflight, stage_threads) = match arg {
                    None => (8, 0),
                    Some(a) => match a.split_once(':') {
                        None => (num(a)?.max(1), 0),
                        Some((i, t)) => (num(i)?.max(1), num(t)?),
                    },
                };
                Ok(BackendSpec::Pipeline { inflight, stage_threads })
            }
            "fpga-sim" => Ok(BackendSpec::FpgaSim),
            "gpu-sim" => Ok(BackendSpec::GpuSim),
            other => bail!("unknown backend {other:?} (engine|pipeline|fpga-sim|gpu-sim)"),
        }
    }

    /// Stable wire/CLI label (round-trips through [`BackendSpec::parse`]).
    pub fn label(&self) -> String {
        match self {
            BackendSpec::Engine { lanes } => format!("engine:{lanes}"),
            BackendSpec::Pipeline { inflight, stage_threads: 0 } => format!("pipeline:{inflight}"),
            BackendSpec::Pipeline { inflight, stage_threads } => {
                format!("pipeline:{inflight}:{stage_threads}")
            }
            BackendSpec::FpgaSim => "fpga-sim".to_string(),
            BackendSpec::GpuSim => "gpu-sim".to_string(),
        }
    }

    /// Per-worker replica factory for this backend kind over `model`.
    ///
    /// A balanced pipeline pool calibrates its [`StagePlan`] **once**:
    /// the first replica measures and water-fills, later replicas reuse
    /// the same plan — every shard runs identical lane counts (the
    /// per-stage metrics aggregation sums like with like), and the
    /// timing-sensitive calibration never runs while sibling replicas
    /// are already saturating the cores.
    pub fn factory(&self, model: BcnnModel) -> BackendFactory {
        let spec = *self;
        let shared_plan: Arc<Mutex<Option<StagePlan>>> = Arc::new(Mutex::new(None));
        Arc::new(move || -> Result<Box<dyn Backend>> {
            Ok(match spec {
                BackendSpec::Engine { lanes } => {
                    Box::new(NativeBackend::with_lanes(model.clone(), lanes)?)
                }
                BackendSpec::Pipeline { inflight, stage_threads: 0 } => {
                    Box::new(PipelineBackend::new(model.clone(), inflight)?)
                }
                BackendSpec::Pipeline { inflight, stage_threads } => {
                    let plan = {
                        let mut slot = lock_recover(&shared_plan);
                        match &*slot {
                            Some(plan) => plan.clone(),
                            None => {
                                let engine = Engine::new(model.clone())?;
                                let plan = StagePlan::balanced(&engine, stage_threads)?;
                                *slot = Some(plan.clone());
                                plan
                            }
                        }
                    };
                    Box::new(PipelineBackend::with_plan(model.clone(), inflight, plan)?)
                }
                BackendSpec::FpgaSim => Box::new(FpgaSimBackend::new(model.clone())?),
                BackendSpec::GpuSim => {
                    Box::new(GpuSimBackend::new(model.clone(), GpuKernel::Xnor)?)
                }
            })
        })
    }
}

/// Where a model's weights come from — the wire/CLI encoding used by
/// `--models name=source` and the `DEPLOY` admin frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ModelSource {
    /// A `.bcnn` artifact on the server's filesystem.
    File(PathBuf),
    /// Deterministic synthetic weights for a built-in config
    /// (`synthetic:<config>[:<seed>]`).
    Synthetic { config: String, seed: u64 },
}

impl ModelSource {
    pub fn parse(s: &str) -> Result<Self> {
        if s == "synthetic" {
            bail!("model source \"synthetic\" needs a config: synthetic:<config>[:<seed>]");
        }
        if let Some(rest) = s.strip_prefix("synthetic:") {
            let (config, seed) = match rest.split_once(':') {
                Some((c, seed)) => {
                    (c, seed.parse::<u64>().with_context(|| format!("seed {seed:?} in {s:?}"))?)
                }
                None => (rest, 0xB_C0DE),
            };
            if config.is_empty() {
                bail!("empty config in model source {s:?}");
            }
            Ok(ModelSource::Synthetic { config: config.to_string(), seed })
        } else if s.is_empty() {
            bail!("empty model source");
        } else {
            Ok(ModelSource::File(PathBuf::from(s)))
        }
    }

    pub fn load(&self) -> Result<BcnnModel> {
        match self {
            ModelSource::File(path) => BcnnModel::load(path),
            ModelSource::Synthetic { config, seed } => {
                let cfg = NetConfig::by_name(config)
                    .ok_or_else(|| anyhow!("unknown built-in config {config:?}"))?;
                Ok(BcnnModel::synthetic(&cfg, *seed))
            }
        }
    }

    pub fn describe(&self) -> String {
        match self {
            ModelSource::File(p) => p.display().to_string(),
            ModelSource::Synthetic { config, seed } => format!("synthetic:{config}:{seed}"),
        }
    }
}

/// Everything needed to (re)build one model version's pool — kept in the
/// lineage history so `rollback` re-instantiates the previous version.
#[derive(Clone)]
pub struct DeploySpec {
    pub model: BcnnModel,
    pub backend: BackendSpec,
    pub workers: usize,
    pub queue_depth: usize,
    pub policy: BatchPolicy,
}

impl DeploySpec {
    /// Engine backend, one worker, default queueing.
    pub fn new(model: BcnnModel) -> Self {
        Self {
            model,
            backend: BackendSpec::Engine { lanes: 1 },
            workers: 1,
            queue_depth: 256,
            policy: BatchPolicy::default(),
        }
    }

    pub fn with_backend(mut self, backend: BackendSpec) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth.max(1);
        self
    }

    pub fn with_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }
}

/// One live (or draining) model version: its pool plus identity metadata.
pub struct ModelEntry {
    pub name: String,
    /// Registry-global, monotonically increasing deployment version.
    pub version: u64,
    pub backend: String,
    pub config: NetConfig,
    pub deployed: Instant,
    coordinator: Coordinator,
}

impl ModelEntry {
    /// Submission handle into this version's pool.
    pub fn client(&self) -> Client {
        self.coordinator.client()
    }

    /// Live metrics snapshot of this version's pool.
    pub fn metrics(&self) -> Metrics {
        self.coordinator.metrics()
    }

    pub fn workers(&self) -> usize {
        self.coordinator.workers()
    }

    /// Per-shard supervision health of this version's pool.
    pub fn health(&self) -> PoolHealth {
        self.coordinator.health()
    }

    /// True while at least one shard can still accept work — the router's
    /// failover predicate.
    pub fn is_serviceable(&self) -> bool {
        self.health().serviceable()
    }
}

/// `stats()` row: one model name across all its versions.
#[derive(Debug, Clone)]
pub struct ModelStats {
    pub name: String,
    /// Version currently serving, or the last retired version.
    pub version: u64,
    pub live: bool,
    pub backend: String,
    pub config: String,
    pub metrics: Metrics,
}

/// Per-name bookkeeping that outlives individual pools.
#[derive(Default)]
struct Lineage {
    /// Metrics folded in from reaped (fully drained + joined) pools.
    retired_metrics: Metrics,
    /// Specs of superseded versions, oldest first (rollback pops).
    history: Vec<DeploySpec>,
    /// Spec of the currently-deployed version.
    current: Option<DeploySpec>,
    /// Last version number issued for this name.
    last_version: u64,
    /// Backend label of the last deployment (for retired-only stats rows).
    last_backend: String,
    last_config: String,
}

/// How many superseded specs to keep per model for `rollback`.
const HISTORY_DEPTH: usize = 4;

/// A pool that has been unpublished but may still hold in-flight work.
struct Retired {
    name: String,
    entry: Arc<ModelEntry>,
}

struct RegState {
    next_version: u64,
    lineage: BTreeMap<String, Lineage>,
    retired: Vec<Retired>,
}

/// The serving control plane: named, versioned model entries over the
/// sharded coordinator, with zero-downtime hot-swap.
pub struct ModelRegistry {
    state: Mutex<RegState>,
    slot: Arc<TableSlot>,
    /// Rolling per-second telemetry over the registry-wide cumulative
    /// metrics (see [`WindowTracker`]); advanced from the TCP front-end's
    /// idle loop and from `STATS` requests.
    windows: Mutex<WindowTracker>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self {
            state: Mutex::new(RegState {
                next_version: 0,
                lineage: BTreeMap::new(),
                retired: Vec::new(),
            }),
            slot: Arc::new(RwLock::new(Arc::new(RoutingTable::default()))),
            windows: Mutex::new(WindowTracker::with_defaults()),
        }
    }

    /// Read-side routing handle (cheap clone, share with handler threads).
    pub fn router(&self) -> Router {
        Router::new(Arc::clone(&self.slot))
    }

    /// Deploy (or replace) `name`.  Returns the new version.  The old
    /// version, if any, keeps serving everything submitted before the
    /// swap and is joined only once drained.
    pub fn deploy(&self, name: &str, spec: DeploySpec) -> Result<u64> {
        if name.is_empty() {
            bail!("model name must be non-empty");
        }
        // the expensive part — weight transposition, worker spawn — runs
        // before any lock is taken, so routing, stats, and the accept
        // loop never stall behind a pool build
        let pool = build_pool(name, &spec)?;
        let mut st = lock_recover(&self.state);
        let version = self.publish_locked(&mut st, name, spec, pool, true);
        reap(&mut st);
        Ok(version)
    }

    /// Remove `name` from the routing table.  In-flight requests finish;
    /// the pool is joined once drained.  Returns the retired version.
    pub fn undeploy(&self, name: &str) -> Result<u64> {
        let mut st = lock_recover(&self.state);
        let old = self.swap_table(|table| match table.entries.remove(name) {
            Some(old) => {
                if table.default.as_deref() == Some(name) {
                    table.default = table.entries.keys().next().cloned();
                }
                Ok(Some(old))
            }
            None => bail!("no model {name:?} deployed"),
        })?;
        let old = old.expect("undeploy removed an entry");
        let version = old.version;
        let lin = st.lineage.entry(name.to_string()).or_default();
        if let Some(cur) = lin.current.take() {
            push_history(lin, cur);
        }
        st.retired.push(Retired { name: name.to_string(), entry: old });
        reap(&mut st);
        Ok(version)
    }

    /// Redeploy the previous version of `name` (zero-downtime, like
    /// `deploy`).  Returns the new version number it serves under.
    ///
    /// Unlike `deploy`, the pool build runs *under* the state lock: the
    /// peek-build-pop of the history stack must be atomic against racing
    /// admin operations on the same name, rollbacks are rare, and the
    /// accept loop never blocks on this lock (`reap_retired` try-locks).
    /// A failed build leaves the rollback point in place for a retry.
    pub fn rollback(&self, name: &str) -> Result<u64> {
        let mut st = lock_recover(&self.state);
        let spec = st
            .lineage
            .get(name)
            .and_then(|l| l.history.last())
            .cloned()
            .ok_or_else(|| anyhow!("no previous version of {name:?} to roll back to"))?;
        let pool = build_pool(name, &spec)?;
        let version = self.publish_locked(&mut st, name, spec, pool, false);
        st.lineage
            .get_mut(name)
            .expect("lineage row exists for a rolled-back model")
            .history
            .pop();
        reap(&mut st);
        Ok(version)
    }

    /// Pool parameters (backend, workers, queue depth, batch policy) of
    /// the currently-deployed version of `name` — wire deploys inherit
    /// these for any field the frame leaves unset, so a hot-swap does not
    /// silently reset a tuned pool to defaults.
    pub fn current_params(&self, name: &str) -> Option<(BackendSpec, usize, usize, BatchPolicy)> {
        let st = lock_recover(&self.state);
        st.lineage
            .get(name)
            .and_then(|l| l.current.as_ref())
            .map(|s| (s.backend, s.workers, s.queue_depth, s.policy))
    }

    /// Make `name` the protocol-v1 default route.
    pub fn set_default(&self, name: &str) -> Result<()> {
        let _st = lock_recover(&self.state);
        self.swap_table(|table| {
            if !table.entries.contains_key(name) {
                bail!("no model {name:?} deployed");
            }
            table.default = Some(name.to_string());
            Ok(None)
        })?;
        Ok(())
    }

    /// Current routing epoch (bumps on every deploy/undeploy/rollback).
    pub fn epoch(&self) -> u64 {
        read_recover(&self.slot).epoch
    }

    /// Deployed entries, in name order.
    pub fn list(&self) -> Vec<Arc<ModelEntry>> {
        read_recover(&self.slot).entries.values().cloned().collect()
    }

    /// Per-model serving stats across versions: lineage accumulator
    /// (reaped pools) + still-draining retired pools + the live pool.
    pub fn stats(&self) -> Vec<ModelStats> {
        let mut st = lock_recover(&self.state);
        reap(&mut st);
        let table = Arc::clone(&read_recover(&self.slot));
        let mut rows: BTreeMap<String, ModelStats> = BTreeMap::new();
        for (name, lin) in &st.lineage {
            rows.insert(
                name.clone(),
                ModelStats {
                    name: name.clone(),
                    version: lin.last_version,
                    live: false,
                    backend: lin.last_backend.clone(),
                    config: lin.last_config.clone(),
                    metrics: lin.retired_metrics.clone(),
                },
            );
        }
        for r in &st.retired {
            if let Some(row) = rows.get_mut(&r.name) {
                let snap = r.entry.metrics();
                row.metrics.merge(&snap);
                row.metrics.wall += snap.wall;
            }
        }
        for (name, entry) in &table.entries {
            let row = rows.entry(name.clone()).or_insert_with(|| ModelStats {
                name: name.clone(),
                version: entry.version,
                live: true,
                backend: entry.backend.clone(),
                config: entry.config.name.clone(),
                metrics: Metrics::new(),
            });
            row.version = entry.version;
            row.live = true;
            row.backend = entry.backend.clone();
            row.config = entry.config.name.clone();
            let snap = entry.metrics();
            row.metrics.merge(&snap);
            // merge() skips `wall` by design; sum pool lifetimes so the
            // row's throughput() is defined across versions
            row.metrics.wall += snap.wall;
        }
        rows.into_values().collect()
    }

    /// Advance the windowed-telemetry clock if a window boundary has
    /// passed: snapshot the registry-wide cumulative metrics and close
    /// the elapsed window(s).  Cheap when nothing is due (one try-lock +
    /// one Instant compare), so the TCP front-end calls it from its idle
    /// accept loop; `STATS` requests call it too so a windowless poller
    /// still sees fresh rows.  Non-blocking: if another thread holds the
    /// tracker, skip — it is already ticking.
    pub fn tick_windows(&self) {
        let due = match self.windows.try_lock() {
            Ok(w) => w.due(Instant::now()),
            Err(_) => false,
        };
        if !due {
            return;
        }
        // snapshot *outside* the tracker lock: stats() takes the state
        // lock and can reap, neither of which should serialize pollers
        let cumulative = self.cumulative_metrics();
        if let Ok(mut w) = self.windows.try_lock() {
            w.tick(Instant::now(), &cumulative);
        }
    }

    /// The rolling windows as a JSON array (oldest first) — folded into
    /// the `STATS` payload under `"windows"`.
    pub fn windows_json(&self) -> Json {
        lock_recover(&self.windows).to_json()
    }

    /// Registry-wide cumulative metrics: every model row (live + retired
    /// lineage) merged into one accumulator — the series the window
    /// tracker differentiates.
    pub fn cumulative_metrics(&self) -> Metrics {
        let mut total = Metrics::new();
        for row in self.stats() {
            total.merge(&row.metrics);
            // merge() skips `wall` by design; sum so throughput stays
            // defined over the aggregate
            total.wall += row.metrics.wall;
        }
        total
    }

    /// Opportunistic reap of drained retired pools.  Also called from
    /// the TCP front-end's idle loop, so an inference-only server frees
    /// a displaced pool's threads and weights moments after its last
    /// in-flight request finishes instead of at the next admin call.
    /// Non-blocking: if an admin operation holds the state lock, skip —
    /// the accept loop must never park behind the control plane.
    pub fn reap_retired(&self) {
        if let Ok(mut st) = self.state.try_lock() {
            reap(&mut st);
        }
    }

    /// Wait until every retired pool has drained and been joined.
    pub fn drain_retired(&self, timeout: Duration) -> Result<()> {
        let start = Instant::now();
        loop {
            {
                let mut st = lock_recover(&self.state);
                reap(&mut st);
                if st.retired.is_empty() {
                    return Ok(());
                }
            }
            if start.elapsed() >= timeout {
                bail!("retired pools still draining after {timeout:?}");
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Publish an already-built pool as a new version of `name`.  Caller
    /// holds the state lock (control operations serialize; router reads
    /// never touch this lock, and nothing slow happens here).
    fn publish_locked(
        &self,
        st: &mut RegState,
        name: &str,
        spec: DeploySpec,
        pool: Coordinator,
        push_current_to_history: bool,
    ) -> u64 {
        st.next_version += 1;
        let version = st.next_version;
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            version,
            backend: spec.backend.label(),
            config: spec.model.config(),
            deployed: Instant::now(),
            coordinator: pool,
        });
        let lin = st.lineage.entry(name.to_string()).or_default();
        lin.last_version = version;
        lin.last_backend = entry.backend.clone();
        lin.last_config = entry.config.name.clone();
        let prev = lin.current.replace(spec);
        if push_current_to_history {
            if let Some(prev) = prev {
                push_history(lin, prev);
            }
        }
        let old = self
            .swap_table(|table| {
                let old = table.entries.insert(name.to_string(), Arc::clone(&entry));
                if table.default.is_none() {
                    table.default = Some(name.to_string());
                }
                Ok(old)
            })
            .expect("publish mutation is infallible");
        if let Some(old) = old {
            st.retired.push(Retired { name: name.to_string(), entry: old });
        }
        version
    }

    /// Copy-on-write table swap: build the successor off the current
    /// snapshot, bump the epoch, publish atomically.
    fn swap_table<F>(&self, mutate: F) -> Result<Option<Arc<ModelEntry>>>
    where
        F: FnOnce(&mut RoutingTable) -> Result<Option<Arc<ModelEntry>>>,
    {
        let mut slot = write_recover(&self.slot);
        let mut next: RoutingTable = (**slot).clone();
        next.epoch += 1;
        let displaced = mutate(&mut next)?;
        *slot = Arc::new(next);
        Ok(displaced)
    }
}

/// Build one version's coordinator pool.  Deliberately a free function
/// taking no registry state: callers run it *before* locking, so a slow
/// build (weight transposition, worker spawn) never blocks routing,
/// stats, or the accept loop.
fn build_pool(name: &str, spec: &DeploySpec) -> Result<Coordinator> {
    Coordinator::start_sharded(
        spec.backend.factory(spec.model.clone()),
        CoordinatorConfig {
            policy: spec.policy,
            workers: spec.workers,
            queue_depth: spec.queue_depth,
            restart: RestartPolicy::default(),
        },
    )
    .with_context(|| format!("building pool for model {name:?}"))
}

fn push_history(lin: &mut Lineage, spec: DeploySpec) {
    lin.history.push(spec);
    if lin.history.len() > HISTORY_DEPTH {
        lin.history.remove(0);
    }
}

/// Join every retired pool whose last external reference is gone: its
/// queue is drained by the coordinator's poison-free shutdown, the worker
/// threads are joined, and the final metrics are folded into the lineage.
fn reap(st: &mut RegState) {
    let mut i = 0;
    while i < st.retired.len() {
        if Arc::strong_count(&st.retired[i].entry) != 1 {
            i += 1;
            continue;
        }
        let r = st.retired.swap_remove(i);
        match Arc::try_unwrap(r.entry) {
            Ok(entry) => {
                let finals = entry.coordinator.shutdown();
                let lin = st.lineage.entry(r.name).or_default();
                lin.retired_metrics.merge(&finals);
                // merge() deliberately skips `wall`; per-model wall is
                // the sum of pool lifetimes so throughput stays defined
                lin.retired_metrics.wall += finals.wall;
            }
            // a reader raced us between the count check and the unwrap;
            // put it back and try again on the next reap
            Err(entry) => {
                st.retired.push(Retired { name: r.name, entry });
                i += 1;
            }
        }
    }
}

impl Drop for ModelRegistry {
    fn drop(&mut self) {
        // live pools: unpublish everything so their queues poison cleanly
        let entries: Vec<Arc<ModelEntry>> = {
            let mut slot = write_recover(&self.slot);
            let old = Arc::clone(&slot);
            *slot = Arc::new(RoutingTable {
                epoch: old.epoch + 1,
                entries: BTreeMap::new(),
                default: None,
            });
            old.entries.values().cloned().collect()
        };
        {
            let mut st = lock_recover(&self.state);
            for entry in entries {
                let name = entry.name.clone();
                st.retired.push(Retired { name, entry });
            }
        }
        // bounded wait for handler threads to release their entry refs;
        // anything still referenced after the deadline is leaked rather
        // than blocking process teardown forever
        let _ = self.drain_retired(Duration::from_secs(10));
    }
}
