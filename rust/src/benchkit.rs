//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! `cargo bench` runs the `harness = false` binaries under `rust/benches/`,
//! each of which uses this module: warmup, adaptive iteration count,
//! median/mean/p95 over wall-clock samples, aligned table output.
//!
//! Every `BENCH_*.json` artifact opens with the shared [`envelope`]
//! (schema version, bench name, git commit, config fingerprint) so the
//! perf trajectory is self-describing and diffable across commits, and
//! [`check_baseline`] compares a fresh run against the committed
//! `rust/BENCH_baseline.json` inside per-metric tolerance bands
//! (`repro bench --check`, the CI perf gate).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Statistics of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_s(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Per-second rate for a unit of work done once per iteration.
    pub fn per_second(&self) -> f64 {
        1e9 / self.median_ns
    }
}

/// Time `f` adaptively: warm up, then collect ~`samples` timing samples of
/// batches sized so each batch takes >= 1 ms.
pub fn bench<F: FnMut()>(mut f: F) -> Stats {
    bench_with(BenchOpts::default(), &mut f)
}

#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup: Duration,
    pub samples: usize,
    pub min_batch_time: Duration,
    /// Hard cap on total measuring time.
    pub budget: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(100),
            samples: 20,
            min_batch_time: Duration::from_millis(1),
            budget: Duration::from_secs(5),
        }
    }
}

pub fn bench_with<F: FnMut()>(opts: BenchOpts, f: &mut F) -> Stats {
    // warmup + batch sizing
    let mut batch = 1u64;
    let warm_start = Instant::now();
    loop {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t.elapsed();
        if dt >= opts.min_batch_time || warm_start.elapsed() >= opts.warmup {
            if dt < opts.min_batch_time && dt.as_nanos() > 0 {
                let scale = (opts.min_batch_time.as_nanos() as f64 / dt.as_nanos() as f64).ceil();
                batch = (batch as f64 * scale).min(1e9) as u64;
            }
            break;
        }
        batch *= 2;
    }

    let mut samples_ns: Vec<f64> = Vec::with_capacity(opts.samples);
    let start = Instant::now();
    let mut iters = 0u64;
    for _ in 0..opts.samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
        iters += batch;
        if start.elapsed() > opts.budget {
            break;
        }
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    Stats {
        iters,
        mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
        median_ns: samples_ns[n / 2],
        p95_ns: samples_ns[(n as f64 * 0.95) as usize % n.max(1)],
        min_ns: samples_ns[0],
    }
}

/// Minimal JSON value for machine-readable `BENCH_*.json` artifacts
/// (serde is not in the offline crate cache).  Non-finite numbers render
/// as `null` so the output always parses.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_json_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (key, value)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(key, out);
                    out.push(':');
                    value.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Write a `BENCH_*.json` artifact.  Cargo runs bench binaries with the
/// *package* root as working directory, so a bare file name lands in
/// `rust/` (e.g. `rust/BENCH_engine.json`) — the perf-trajectory
/// artifact CI archives and diffs across commits.
pub fn write_bench_json(path: &str, value: &Json) -> std::io::Result<()> {
    std::fs::write(path, value.render() + "\n")
}

/// Schema version of the shared bench-artifact envelope.  Bump when an
/// envelope key changes meaning; consumers (`repro bench --list`/
/// `--merge`/`--check`) key off it.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// The commit the artifact was produced at: `GITHUB_SHA` in CI, else
/// `git rev-parse HEAD`, else `"unknown"` (tarball checkouts still
/// produce a valid artifact).
pub fn git_commit() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        let sha = sha.trim().to_string();
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// The shared artifact envelope, as leading key/value pairs to prepend
/// *flatly* to a bench's own `Json::Obj` fields (flat so existing
/// consumers that grep top-level keys keep working).
pub fn envelope(bench: &str, config_fingerprint: &str) -> Vec<(String, Json)> {
    vec![
        ("schema_version".to_string(), Json::Num(BENCH_SCHEMA_VERSION as f64)),
        ("bench".to_string(), Json::Str(bench.to_string())),
        ("git_commit".to_string(), Json::Str(git_commit())),
        ("config_fingerprint".to_string(), Json::Str(config_fingerprint.to_string())),
    ]
}

/// One metric's verdict from [`check_baseline`].  Metrics are
/// lower-is-better (ns, ratios); `limit = baseline * (1 + pct/100)`.
#[derive(Debug, Clone, PartialEq)]
pub struct GateResult {
    pub metric: String,
    pub baseline: f64,
    /// `None` when the fresh run did not produce this metric (fails the
    /// gate if the metric is gated — a silently vanished metric is a
    /// regression of the harness itself).
    pub measured: Option<f64>,
    pub limit: f64,
    /// Ungated metrics are informational: recorded, never failing.
    pub gated: bool,
    pub pass: bool,
}

/// Compare fresh measurements against a committed baseline document
/// (`rust/BENCH_baseline.json`: `{schema_version, bench, metrics:
/// {name: {value, max_regression_pct, gate}}}`).  Returns one
/// [`GateResult`] per baseline metric; the caller fails if any gated
/// metric's `pass` is false.
pub fn check_baseline(
    baseline: &crate::util::json::Json,
    measured: &BTreeMap<String, f64>,
) -> anyhow::Result<Vec<GateResult>> {
    let version = baseline.get("schema_version")?.as_f64()? as u64;
    if version != BENCH_SCHEMA_VERSION {
        anyhow::bail!("baseline schema_version {version} != supported {BENCH_SCHEMA_VERSION}");
    }
    let metrics = baseline.get("metrics")?.as_obj()?;
    let mut out = Vec::with_capacity(metrics.len());
    for (name, spec) in metrics {
        let base = spec.get("value")?.as_f64()?;
        let pct = spec.get("max_regression_pct")?.as_f64()?;
        let gated = spec.get("gate")?.as_bool()?;
        let limit = base * (1.0 + pct / 100.0);
        let m = measured.get(name).copied();
        let pass = !gated || m.is_some_and(|v| v.is_finite() && v <= limit);
        out.push(GateResult { metric: name.clone(), baseline: base, measured: m, limit, gated, pass });
    }
    Ok(out)
}

/// Pretty duration for reports.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Aligned two-column+ table printer used by every bench binary.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:>w$}", c, w = widths[i]));
            }
            out.push('\n');
        };
        line(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let mut x = 0u64;
        let stats = bench_with(
            BenchOpts {
                warmup: Duration::from_millis(5),
                samples: 5,
                min_batch_time: Duration::from_micros(50),
                budget: Duration::from_millis(200),
            },
            &mut || {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                std::hint::black_box(x);
            },
        );
        assert!(stats.iters > 0);
        assert!(stats.median_ns > 0.0);
        assert!(stats.min_ns <= stats.median_ns);
        assert!(stats.median_ns <= stats.p95_ns * 1.001);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["layer", "cycles"]);
        t.row(&["Conv 1".into(), "4096".into()]);
        t.row(&["Conv 22".into(), "12288".into()]);
        let s = t.to_string();
        assert!(s.contains("Conv 22"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].chars().all(|c| c == '-'), true);
    }

    #[test]
    fn json_renders_nested_values() {
        let v = Json::Obj(vec![
            ("name".into(), Json::Str("engine \"hot\"\npath".into())),
            ("smoke".into(), Json::Bool(false)),
            ("nan".into(), Json::Num(f64::NAN)),
            (
                "layers".into(),
                Json::Arr(vec![
                    Json::Obj(vec![("ns".into(), Json::Num(1234.5))]),
                    Json::Null,
                ]),
            ),
        ]);
        assert_eq!(
            v.render(),
            r#"{"name":"engine \"hot\"\npath","smoke":false,"nan":null,"layers":[{"ns":1234.5},null]}"#
        );
    }

    #[test]
    fn envelope_is_flat_and_pinned() {
        let env = envelope("engine_hotpath", "tiny");
        let keys: Vec<&str> = env.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["schema_version", "bench", "git_commit", "config_fingerprint"]);
        assert_eq!(env[0].1, Json::Num(BENCH_SCHEMA_VERSION as f64));
        assert_eq!(env[1].1, Json::Str("engine_hotpath".into()));
        // git_commit never errors, even outside a checkout
        assert!(matches!(&env[2].1, Json::Str(s) if !s.is_empty()));
    }

    #[test]
    fn baseline_gate_verdicts() {
        let baseline = crate::util::json::Json::parse(
            r#"{
                "schema_version": 1,
                "bench": "baseline",
                "metrics": {
                    "ratio_ok":   {"value": 5.0, "max_regression_pct": 25, "gate": true},
                    "ratio_bad":  {"value": 1.0, "max_regression_pct": 25, "gate": true},
                    "info_only":  {"value": 100.0, "max_regression_pct": 25, "gate": false},
                    "missing":    {"value": 2.0, "max_regression_pct": 25, "gate": true}
                }
            }"#,
        )
        .unwrap();
        let mut measured = BTreeMap::new();
        measured.insert("ratio_ok".to_string(), 6.0); // <= 6.25: pass
        measured.insert("ratio_bad".to_string(), 1.3); // > 1.25: fail
        measured.insert("info_only".to_string(), 1e9); // ungated: pass
        let results = check_baseline(&baseline, &measured).unwrap();
        let by_name = |n: &str| results.iter().find(|r| r.metric == n).unwrap();
        assert!(by_name("ratio_ok").pass);
        assert!((by_name("ratio_ok").limit - 6.25).abs() < 1e-9);
        assert!(!by_name("ratio_bad").pass);
        assert!(by_name("info_only").pass, "ungated metrics never fail");
        assert!(!by_name("missing").pass, "vanished gated metric fails");

        let wrong_version =
            crate::util::json::Json::parse(r#"{"schema_version": 99, "metrics": {}}"#).unwrap();
        assert!(check_baseline(&wrong_version, &measured).is_err());
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1500.0).ends_with("µs"));
        assert!(fmt_ns(2.5e6).ends_with("ms"));
        assert!(fmt_ns(3.0e9).ends_with(" s"));
    }
}
