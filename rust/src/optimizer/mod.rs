//! Throughput optimization model (paper §4.3) — regenerates Table 3.
//!
//! The paper's design rule: (1) fully unfold the FW and FD dimensions
//! (§6: "the operations along the FW and the FD dimensions are fully
//! unfolded"), i.e. `UF = FW*FD` for the hidden conv layers and the whole
//! filter for the small first layer; (2) choose the spatial parallelism
//! `P` of every layer so that `Cycle_est` is balanced across layers
//! ("system throughput is maximized ... when all the layers have equal
//! execution time") subject to the device's resource budget.
//!
//! [`optimize`] implements that as a minimize-the-bottleneck search: binary
//! search over the target phase length T; for each T pick the smallest
//! power-of-two `P` meeting it per layer; feasibility = the Table-4
//! resource model fits the device.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::fpga::resource::{self, Device, ResourceReport};
use crate::fpga::timing::{cycle_conv, cycle_est, cycle_real, LayerParams, PipelineModel};
use crate::fpga::{layer_geometry, LayerGeom};
use crate::model::NetConfig;
use crate::util::json::Json;

/// One planned layer.
#[derive(Debug, Clone)]
pub struct PlanLayer {
    pub geom: LayerGeom,
    pub params: LayerParams,
    pub cycle_conv: u64,
    pub cycle_est: u64,
    pub cycle_real: u64,
}

/// A full accelerator plan.
#[derive(Debug, Clone)]
pub struct Plan {
    pub layers: Vec<PlanLayer>,
    pub resources: ResourceReport,
    pub bottleneck_est: u64,
    pub bottleneck_real: u64,
    pub fps: f64,
}

impl Plan {
    /// Machine-readable §4.3 plan (`repro optimize --json`): per-layer
    /// `UF`/`P`/cycles, the resource totals, and the eq. 12 fps — stable
    /// keys, so plans can be diffed against each other and against the
    /// executed host [`StagePlan`] (the bench records both).
    ///
    /// [`StagePlan`]: crate::pipeline::StagePlan
    pub fn to_json(&self) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                let mut o: BTreeMap<String, Json> = BTreeMap::new();
                o.insert("name".into(), Json::Str(l.geom.name.clone()));
                o.insert("is_conv".into(), Json::Bool(l.geom.is_conv));
                o.insert("outputs".into(), num(l.geom.outputs()));
                o.insert("cnum".into(), num(l.geom.cnum as u64));
                o.insert("uf".into(), num(l.params.uf as u64));
                o.insert("p".into(), num(l.params.p as u64));
                o.insert("cycle_conv".into(), num(l.cycle_conv));
                o.insert("cycle_est".into(), num(l.cycle_est));
                o.insert("cycle_real".into(), num(l.cycle_real));
                Json::Obj(o)
            })
            .collect();
        let r = &self.resources.total;
        let mut res: BTreeMap<String, Json> = BTreeMap::new();
        res.insert("luts".into(), num(r.luts));
        res.insert("registers".into(), num(r.registers));
        res.insert("brams".into(), num(r.brams));
        res.insert("dsps".into(), num(r.dsps));
        res.insert("fits".into(), Json::Bool(self.resources.fits()));
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("layers".into(), Json::Arr(layers));
        o.insert("resources".into(), Json::Obj(res));
        o.insert("bottleneck_est".into(), num(self.bottleneck_est));
        o.insert("bottleneck_real".into(), num(self.bottleneck_real));
        o.insert("fps".into(), Json::Num(self.fps));
        Json::Obj(o)
    }
}

/// Search options.
#[derive(Debug, Clone)]
pub struct OptimizeOptions {
    pub device: Device,
    pub freq_hz: f64,
    /// Usable fraction of the device's LUTs (routing headroom; the paper
    /// lands at 79% utilization).
    pub lut_headroom: f64,
    /// Multiplier on the paper's UF rule, for the unfolding ablation
    /// (1.0 = the paper's full FW*FD unroll).
    pub uf_scale: f64,
    pub pipeline: PipelineModel,
}

impl Default for OptimizeOptions {
    fn default() -> Self {
        Self {
            device: resource::VIRTEX7_690T,
            freq_hz: crate::fpga::DEFAULT_FREQ_HZ,
            lut_headroom: 0.82,
            uf_scale: 1.0,
            pipeline: PipelineModel::default(),
        }
    }
}

/// The paper's UF rule for a layer (§6), scaled for ablation.
pub fn paper_uf(geom: &LayerGeom, uf_scale: f64) -> usize {
    let base = if geom.is_conv {
        if geom.fixed_point {
            geom.cnum // small first filter: fully unfolded (27)
        } else {
            geom.cnum / 3 // FW * FD (drop the FH dimension of the 3x3 filter)
        }
    } else {
        geom.cnum.min(1024) // FC: bounded by BRAM read bandwidth
    };
    ((base as f64 * uf_scale).round() as usize).clamp(1, geom.cnum)
}

/// Smallest power-of-two P achieving `cycle_est <= target`.
fn p_for_target(geom: &LayerGeom, uf: usize, target: u64) -> usize {
    let work = cycle_conv(geom);
    let needed = work.div_ceil(target * uf as u64).max(1);
    let p = needed.next_power_of_two() as usize;
    // P beyond the number of output values is waste
    p.min((geom.outputs() as usize).next_power_of_two())
}

fn plan_for_target(config: &NetConfig, target: u64, opts: &OptimizeOptions) -> Plan {
    let geoms = layer_geometry(config);
    let mut layers = Vec::with_capacity(geoms.len());
    for geom in geoms {
        let uf = paper_uf(&geom, opts.uf_scale);
        let p = p_for_target(&geom, uf, target);
        let params = LayerParams::new(uf, p);
        layers.push(PlanLayer {
            cycle_conv: cycle_conv(&geom),
            cycle_est: cycle_est(&geom, &params),
            cycle_real: cycle_real(&geom, &params, &opts.pipeline),
            geom,
            params,
        });
    }
    finish_plan(layers, opts)
}

fn finish_plan(layers: Vec<PlanLayer>, opts: &OptimizeOptions) -> Plan {
    let geoms: Vec<LayerGeom> = layers.iter().map(|l| l.geom.clone()).collect();
    let params: Vec<LayerParams> = layers.iter().map(|l| l.params).collect();
    let resources = resource::report(&geoms, &params, opts.device);
    let bottleneck_est = layers.iter().map(|l| l.cycle_est).max().unwrap_or(0);
    let bottleneck_real = layers.iter().map(|l| l.cycle_real).max().unwrap_or(0);
    Plan {
        fps: if bottleneck_real > 0 { opts.freq_hz / bottleneck_real as f64 } else { 0.0 },
        layers,
        resources,
        bottleneck_est,
        bottleneck_real,
    }
}

fn feasible(plan: &Plan, opts: &OptimizeOptions) -> bool {
    let r = &plan.resources.total;
    let d = &opts.device;
    (r.luts as f64) <= d.luts as f64 * opts.lut_headroom
        && r.brams <= d.brams
        && r.registers <= d.registers
        && r.dsps <= d.dsps
}

/// Minimize the bottleneck `Cycle_est` subject to the resource budget.
pub fn optimize(config: &NetConfig, opts: &OptimizeOptions) -> Result<Plan> {
    // search over candidate targets: the achievable est values are
    // work/(uf*p) for power-of-two p, so binary search on T converges.
    let mut lo: u64 = 64; // unreachable target
    let mut hi: u64 = layer_geometry(config)
        .iter()
        .map(cycle_conv)
        .max()
        .unwrap_or(0); // single PE would meet this
    if hi == 0 {
        bail!("empty network");
    }
    if !feasible(&plan_for_target(config, hi, opts), opts) {
        bail!("even the minimal design does not fit the device");
    }
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if feasible(&plan_for_target(config, mid, opts), opts) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Ok(plan_for_target(config, hi, opts))
}

/// The paper's exact Table-3 design point (UF/P as published), for
/// regenerating the table and benchmarking against [`optimize`].
pub fn paper_plan(opts: &OptimizeOptions) -> Plan {
    let config = NetConfig::table2();
    let geoms = layer_geometry(&config);
    let conv = crate::fpga::timing::paper_table3_conv_params();
    let mut layers = Vec::new();
    for (i, geom) in geoms.into_iter().enumerate() {
        let params = if i < conv.len() {
            conv[i]
        } else {
            crate::fpga::timing::paper_fc_params(&geom)
        };
        layers.push(PlanLayer {
            cycle_conv: cycle_conv(&geom),
            cycle_est: cycle_est(&geom, &params),
            cycle_real: cycle_real(&geom, &params, &opts.pipeline),
            geom,
            params,
        });
    }
    finish_plan(layers, opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimizer_reproduces_table3_conv_parallelism() {
        // paper Table 3: P = [32, 32, 16, 16, 8, 8] with bottleneck
        // Cycle_est = 12288.  Our optimizer must find the same P for the
        // balanced layers (conv 2-6); conv 1 may legitimately get less
        // (its est at P=16 is 8192 <= 12288) — EXPERIMENTS.md discusses.
        let plan = optimize(&NetConfig::table2(), &OptimizeOptions::default()).unwrap();
        let p: Vec<usize> = plan.layers[..6].iter().map(|l| l.params.p).collect();
        assert_eq!(&p[1..], &[32, 16, 16, 8, 8], "conv2-6 P");
        assert!(p[0] == 16 || p[0] == 32, "conv1 P {}", p[0]);
        assert_eq!(plan.bottleneck_est, 12_288);
        let uf: Vec<usize> = plan.layers[..6].iter().map(|l| l.params.uf).collect();
        assert_eq!(uf, vec![27, 384, 384, 768, 768, 1536]);
    }

    #[test]
    fn optimized_plan_fits_device() {
        let opts = OptimizeOptions::default();
        let plan = optimize(&NetConfig::table2(), &opts).unwrap();
        assert!(plan.resources.fits());
        // and is close to the paper's utilization (78.98% LUTs)
        let (lut_u, ..) = plan.resources.utilization();
        assert!(lut_u > 0.55 && lut_u < 0.85, "lut util {lut_u}");
    }

    #[test]
    fn paper_plan_matches_table3_est() {
        let plan = paper_plan(&OptimizeOptions::default());
        let est: Vec<u64> = plan.layers[..6].iter().map(|l| l.cycle_est).collect();
        assert_eq!(est, vec![4096, 12288, 12288, 12288, 12288, 12288]);
    }

    #[test]
    fn fc_layers_do_not_bottleneck() {
        let plan = paper_plan(&OptimizeOptions::default());
        let conv_max = plan.layers[..6].iter().map(|l| l.cycle_est).max().unwrap();
        for l in &plan.layers[6..] {
            assert!(l.cycle_est <= conv_max, "{}: {}", l.geom.name, l.cycle_est);
        }
    }

    #[test]
    fn smaller_uf_shifts_cost_to_spatial_parallelism() {
        // unfolding ablation: halving UF makes each PE take twice the
        // trips, so the optimizer doubles P to hold the bottleneck — same
        // XNOR lane count (temporal and spatial parallelism trade off,
        // §4.2) but more accumulator chains (DSP) and more PE instances.
        let base = optimize(&NetConfig::table2(), &OptimizeOptions::default()).unwrap();
        let half = optimize(
            &NetConfig::table2(),
            &OptimizeOptions { uf_scale: 0.5, ..OptimizeOptions::default() },
        )
        .unwrap();
        assert!(half.bottleneck_est <= base.bottleneck_est * 2);
        assert!(
            half.resources.total.dsps > base.resources.total.dsps,
            "halving UF must cost accumulators: {} vs {}",
            half.resources.total.dsps,
            base.resources.total.dsps
        );
        let sum_p =
            |p: &Plan| p.layers[..6].iter().map(|l| l.params.p as u64).sum::<u64>();
        assert!(sum_p(&half) > sum_p(&base));
    }

    #[test]
    fn plan_json_round_trips_with_table3_fields() {
        let plan = optimize(&NetConfig::table2(), &OptimizeOptions::default()).unwrap();
        let parsed = Json::parse(&plan.to_json().to_string()).unwrap();
        let layers = parsed.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), plan.layers.len());
        assert_eq!(
            layers[1].get("p").unwrap().as_usize().unwrap(),
            plan.layers[1].params.p
        );
        assert_eq!(
            layers[1].get("cycle_real").unwrap().as_usize().unwrap(),
            plan.layers[1].cycle_real as usize
        );
        assert_eq!(
            parsed.get("bottleneck_est").unwrap().as_usize().unwrap() as u64,
            plan.bottleneck_est
        );
        assert!(parsed.get("fps").unwrap().as_f64().unwrap() > 0.0);
        assert!(parsed.get("resources").unwrap().get("luts").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn tiny_config_optimizes() {
        let plan = optimize(&NetConfig::tiny(), &OptimizeOptions::default()).unwrap();
        assert!(plan.fps > 0.0);
        assert!(plan.resources.fits());
    }
}
