//! Regeneration of the paper's tables and Fig. 7 with paper-vs-measured
//! columns.  Used by the `repro tables` / `repro compare-gpu` subcommands
//! and by the bench binaries; EXPERIMENTS.md embeds this output.

use crate::benchkit::Table;
use crate::fpga::power::{gops_per_w, power};
use crate::fpga::resource::VIRTEX7_690T;
use crate::fpga::timing::system_fps;
use crate::fpga::DEFAULT_FREQ_HZ;
use crate::gpu::{GpuKernel, GpuModel};
use crate::model::NetConfig;
use crate::optimizer::{optimize, paper_plan, OptimizeOptions, Plan};

/// Paper Table 3 reference values (layer, UF, P, Cycle_conv, Cycle_est,
/// Cycle_r).
pub const PAPER_TABLE3: [(&str, usize, usize, u64, u64, u64); 6] = [
    ("Conv 1", 27, 32, 3_538_944, 4_096, 5_233),
    ("Conv 2", 384, 32, 150_994_944, 12_288, 12_386),
    ("Conv 3", 384, 16, 75_497_472, 12_288, 12_296),
    ("Conv 4", 768, 16, 150_994_944, 12_288, 13_329),
    ("Conv 5", 768, 8, 75_497_472, 12_288, 12_386),
    ("Conv 6", 1536, 8, 150_994_944, 12_288, 14_473),
];

/// Paper Table 4 reference (used, available).
pub const PAPER_TABLE4: [(&str, u64, u64); 4] = [
    ("LUTs", 342_126, 433_200),
    ("BRAMs", 1_007, 2_060),
    ("Registers", 70_769, 607_200),
    ("DSP", 1_096, 2_800),
];

/// Paper Table 5 comparison rows (reference, device, clock MHz, precision,
/// GOPS, power W) — published literature numbers quoted by the paper.
pub const PAPER_TABLE5: [(&str, &str, u32, &str, f64, f64); 8] = [
    ("[3]", "Virtex 6", 200, "16b", 147.0, 10.0),
    ("[1]", "Virtex 7", 100, "fp32", 62.0, 18.7),
    ("[12]", "Zynq-7000", 150, "16b", 137.0, 9.6),
    ("[4]", "Stratix-V", 120, "8-16b", 117.8, 25.8),
    ("[22]", "Arria-10", 150, "8-16b", 645.25, 21.2),
    ("[23]", "QPI FPGA", 200, "fp32", 123.48, 13.18),
    ("[24]", "Arria-10", 385, "fixed", 1790.0, 37.46),
    ("[21]", "Zynq-7000", 143, "1-2b", 207.8, 4.7),
];

/// Paper headline numbers for "Ours".
pub const PAPER_OURS_GOPS: f64 = 7663.0;
pub const PAPER_OURS_POWER_W: f64 = 8.2;
pub const PAPER_OURS_FPS: f64 = 6218.0;
pub const PAPER_OURS_KLUT: f64 = 342.126;

/// Table 2: the BCNN configuration.
pub fn table2(config: &NetConfig) -> String {
    let mut t = Table::new(&["layer", "filter/weight", "# filters", "output"]);
    for (i, s) in config.conv_shapes().iter().enumerate() {
        t.row(&[
            format!("CONV-{}", i + 1),
            format!("{}x3x3", s.in_c),
            format!("{}", s.out_c),
            format!("{}x{}x{}", s.out_c, s.out_hw, s.out_hw),
        ]);
    }
    for (j, (in_f, out_f)) in config.fc_shapes().iter().enumerate() {
        t.row(&[
            format!("FC-{}", j + 1),
            format!("{in_f}x{out_f}"),
            "-".into(),
            format!("{out_f}"),
        ]);
    }
    t.to_string()
}

/// Table 3: optimized parameters + cycle model, ours vs paper.
pub fn table3(plan: &Plan) -> String {
    let mut t = Table::new(&[
        "layer", "UF", "P", "Cycle_conv", "Cycle_est", "Cycle_r(model)", "Cycle_r(paper)", "err%",
    ]);
    for (layer, paper) in plan.layers.iter().zip(PAPER_TABLE3.iter()) {
        let err = 100.0 * (layer.cycle_real as f64 - paper.5 as f64) / paper.5 as f64;
        t.row(&[
            layer.geom.name.clone(),
            layer.params.uf.to_string(),
            layer.params.p.to_string(),
            layer.cycle_conv.to_string(),
            layer.cycle_est.to_string(),
            layer.cycle_real.to_string(),
            paper.5.to_string(),
            format!("{err:+.1}"),
        ]);
    }
    for layer in &plan.layers[6..] {
        t.row(&[
            layer.geom.name.clone(),
            layer.params.uf.to_string(),
            layer.params.p.to_string(),
            layer.cycle_conv.to_string(),
            layer.cycle_est.to_string(),
            layer.cycle_real.to_string(),
            "-".into(),
            "-".into(),
        ]);
    }
    let fps_model = system_fps(
        &plan.layers.iter().map(|l| l.cycle_real).collect::<Vec<_>>(),
        DEFAULT_FREQ_HZ,
    );
    format!(
        "{t}\nbottleneck: est={} real(model)={}  FPS(model)={:.0}  FPS(paper)={:.0}\n",
        plan.bottleneck_est,
        plan.bottleneck_real,
        fps_model,
        PAPER_OURS_FPS,
        t = t.to_string(),
    )
}

/// Table 4: resource utilization, ours vs paper.
pub fn table4(plan: &Plan) -> String {
    let r = &plan.resources;
    let ours = [
        ("LUTs", r.total.luts, VIRTEX7_690T.luts),
        ("BRAMs", r.total.brams, VIRTEX7_690T.brams),
        ("Registers", r.total.registers, VIRTEX7_690T.registers),
        ("DSP", r.total.dsps, VIRTEX7_690T.dsps),
    ];
    let mut t = Table::new(&["resource", "model", "paper", "available", "model%", "paper%", "err%"]);
    for ((name, got, avail), (pname, paper, _)) in ours.iter().zip(PAPER_TABLE4.iter()) {
        assert_eq!(name, pname);
        t.row(&[
            name.to_string(),
            got.to_string(),
            paper.to_string(),
            avail.to_string(),
            format!("{:.2}", 100.0 * *got as f64 / *avail as f64),
            format!("{:.2}", 100.0 * *paper as f64 / *avail as f64),
            format!("{:+.1}", 100.0 * (*got as f64 - *paper as f64) / *paper as f64),
        ]);
    }
    t.to_string()
}

/// Table 5: cross-accelerator comparison with our model row appended.
pub fn table5(plan: &Plan) -> String {
    let mut t = Table::new(&[
        "work", "device", "MHz", "precision", "GOPS", "W", "GOPS/W", "GOPS/kLUT",
    ]);
    for (r, dev, mhz, prec, gops, w) in PAPER_TABLE5.iter() {
        t.row(&[
            r.to_string(),
            dev.to_string(),
            mhz.to_string(),
            prec.to_string(),
            format!("{gops:.1}"),
            format!("{w:.1}"),
            format!("{:.1}", gops / w),
            "-".into(),
        ]);
    }
    let config = NetConfig::table2();
    let fps = system_fps(
        &plan.layers.iter().map(|l| l.cycle_real).collect::<Vec<_>>(),
        DEFAULT_FREQ_HZ,
    );
    let gops = config.ops_per_image() as f64 * fps / 1e9;
    let p = power(&plan.resources, DEFAULT_FREQ_HZ).total_w();
    let klut = plan.resources.total.luts as f64 / 1000.0;
    t.row(&[
        "Ours(model)".into(),
        "Virtex 7".into(),
        "90".into(),
        "1b".into(),
        format!("{gops:.0}"),
        format!("{p:.1}"),
        format!("{:.0}", gops_per_w(gops, p)),
        format!("{:.1}", gops / klut),
    ]);
    t.row(&[
        "Ours(paper)".into(),
        "Virtex 7".into(),
        "90".into(),
        "1b".into(),
        format!("{PAPER_OURS_GOPS:.0}"),
        format!("{PAPER_OURS_POWER_W:.1}"),
        format!("{:.0}", PAPER_OURS_GOPS / PAPER_OURS_POWER_W),
        format!("{:.1}", PAPER_OURS_GOPS / PAPER_OURS_KLUT),
    ]);
    t.to_string()
}

/// Fig. 7: FPGA vs GPU (baseline + XNOR) FPS and FPS/W across batch sizes.
pub fn fig7(plan: &Plan, batches: &[usize]) -> String {
    let config = NetConfig::table2();
    let gpu = GpuModel::new(&config);
    let fpga_fps = system_fps(
        &plan.layers.iter().map(|l| l.cycle_real).collect::<Vec<_>>(),
        DEFAULT_FREQ_HZ,
    );
    let fpga_w = power(&plan.resources, DEFAULT_FREQ_HZ).total_w();
    let mut t = Table::new(&[
        "batch",
        "FPGA FPS",
        "GPU-base FPS",
        "GPU-XNOR FPS",
        "FPGA FPS/W",
        "GPU-base FPS/W",
        "GPU-XNOR FPS/W",
        "FPGA/GPU-XNOR speedup",
        "FPGA/GPU-XNOR energy x",
    ]);
    for &b in batches {
        let base = gpu.fps(GpuKernel::Baseline, b);
        let xnor = gpu.fps(GpuKernel::Xnor, b);
        let base_eff = gpu.fps_per_w(GpuKernel::Baseline, b);
        let xnor_eff = gpu.fps_per_w(GpuKernel::Xnor, b);
        t.row(&[
            b.to_string(),
            format!("{fpga_fps:.0}"),
            format!("{base:.0}"),
            format!("{xnor:.0}"),
            format!("{:.1}", fpga_fps / fpga_w),
            format!("{base_eff:.2}"),
            format!("{xnor_eff:.2}"),
            format!("{:.1}", fpga_fps / xnor),
            format!("{:.1}", (fpga_fps / fpga_w) / xnor_eff),
        ]);
    }
    format!(
        "{}\npaper anchors: 8.3x speedup & 75x energy at batch 16; parity & 9.5x at batch 512\n",
        t.to_string()
    )
}

/// Default plan used by the table commands: the paper's design point.
pub fn default_plan() -> Plan {
    paper_plan(&OptimizeOptions::default())
}

/// Optimizer-derived plan (Table 3 regeneration from the model alone).
pub fn optimized_plan() -> anyhow::Result<Plan> {
    optimize(&NetConfig::table2(), &OptimizeOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render() {
        let plan = default_plan();
        let t2 = table2(&NetConfig::table2());
        assert!(t2.contains("CONV-6") && t2.contains("512x4x4"));
        let t3 = table3(&plan);
        assert!(t3.contains("Conv 6") && t3.contains("12288"));
        let t4 = table4(&plan);
        assert!(t4.contains("LUTs"));
        let t5 = table5(&plan);
        assert!(t5.contains("Ours(model)") && t5.contains("935"));
        let f7 = fig7(&plan, &[16, 512]);
        assert!(f7.contains("16") && f7.contains("512"));
    }

    #[test]
    fn fig7_ratios_in_shape() {
        let plan = default_plan();
        let s = fig7(&plan, &[16, 512]);
        // the table must show a large speedup at 16 and rough parity at 512
        // (checked numerically in gpu::tests; here just rendering sanity)
        assert!(s.lines().count() >= 5, "{s}");
    }
}
