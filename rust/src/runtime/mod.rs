//! PJRT runtime: load the AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> compile -> execute.  HLO *text* is
//! the interchange format — jax >= 0.5 emits protos with 64-bit ids the
//! 0.5.1 parser rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and `python/compile/aot.py`).
//!
//! [`params`] reconstructs the lowered graph's parameter literals from a
//! `.bcnn` weight file per the artifact's JSON manifest, so weights stay
//! hot-swappable without re-lowering.

pub mod params;

// The real `xla` crate is absent from the offline registry; an
// API-compatible stub keeps this layer compiling and turns every PJRT
// entry point into a clean runtime error (callers skip or report).  To
// re-enable real execution, add `xla` to Cargo.toml and delete this alias.
#[path = "xla_stub.rs"]
pub(crate) mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parsed artifact manifest (`artifacts/model_<cfg>_b<N>.json`).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub params: Vec<ParamSpec>,
}

/// One graph parameter (order matters: argument position = index + 1,
/// argument 0 being the image batch).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub dtype: String, // "s32" | "u32" | "f32"
    pub shape: Vec<usize>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let v = Json::parse(&text)?;
        let shape_of = |node: &Json| -> Result<Vec<usize>> {
            node.get("shape")?.as_arr()?.iter().map(|d| d.as_usize()).collect()
        };
        let mut params = Vec::new();
        for p in v.get("params")?.as_arr()? {
            params.push(ParamSpec {
                name: p.get("name")?.as_str()?.to_string(),
                dtype: p.get("dtype")?.as_str()?.to_string(),
                shape: shape_of(p)?,
            });
        }
        Ok(Self {
            config: v.get("config")?.as_str()?.to_string(),
            batch: v.get("batch")?.as_usize()?,
            input_shape: shape_of(v.get("input")?)?,
            output_shape: shape_of(v.get("output")?)?,
            params,
        })
    }
}

/// A compiled model artifact bound to its parameter literals.
pub struct LoadedModel {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
    /// Parameter literals in manifest order (built once from the .bcnn).
    param_literals: Vec<xla::Literal>,
}

/// The PJRT runtime: one CPU client, a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    models: HashMap<String, LoadedModel>,
}

impl Runtime {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client, artifacts_dir: artifacts_dir.into(), models: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `model_<config>_b<batch>` and bind weights from the
    /// given `.bcnn` file.  Idempotent per (config, batch).
    pub fn load_model(
        &mut self,
        config: &str,
        batch: usize,
        bcnn_path: impl AsRef<Path>,
    ) -> Result<&LoadedModel> {
        let key = format!("{config}_b{batch}");
        if !self.models.contains_key(&key) {
            let stem = self.artifacts_dir.join(format!("model_{config}_b{batch}"));
            let manifest = Manifest::load(stem.with_extension("json"))?;
            let hlo_path = stem.with_extension("hlo.txt");
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile: {e}"))?;
            let model = crate::model::BcnnModel::load(bcnn_path.as_ref())?;
            let param_literals = params::build_literals(&manifest, &model)?;
            self.models.insert(key.clone(), LoadedModel { manifest, exe, param_literals });
        }
        Ok(&self.models[&key])
    }

    pub fn get(&self, config: &str, batch: usize) -> Option<&LoadedModel> {
        self.models.get(&format!("{config}_b{batch}"))
    }
}

impl LoadedModel {
    /// Execute on a full image batch (`batch * hw * hw * c` i32 values,
    /// NHWC).  Returns `batch * classes` f32 scores, row-major.
    pub fn infer_batch(&self, images_flat: &[i32]) -> Result<Vec<f32>> {
        let expect: usize = self.manifest.input_shape.iter().product();
        if images_flat.len() != expect {
            bail!("input length {} != {expect}", images_flat.len());
        }
        let dims: Vec<i64> = self.manifest.input_shape.iter().map(|&d| d as i64).collect();
        let x = xla::Literal::vec1(images_flat)
            .reshape(&dims)
            .map_err(|e| anyhow!("input reshape: {e}"))?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + self.param_literals.len());
        args.push(&x);
        args.extend(self.param_literals.iter());
        let result = self
            .exe
            .execute(&args)
            .map_err(|e| anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        // lowered with return_tuple=True -> 1-tuple
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
    }

    pub fn batch(&self) -> usize {
        self.manifest.batch
    }

    pub fn classes(&self) -> usize {
        *self.manifest.output_shape.last().unwrap_or(&0)
    }
}
