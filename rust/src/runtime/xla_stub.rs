//! Offline stand-in for the `xla` crate (xla_extension 0.5.1).
//!
//! The seed environment compiled the AOT HLO artifacts through PJRT; this
//! build environment has no `xla` crate in its offline registry, so the
//! runtime layer links against this API-compatible stub instead (see
//! `runtime/mod.rs`).  Every entry point type-checks identically to the
//! real crate, and the *first* call a `Runtime` makes —
//! [`PjRtClient::cpu`] — returns a clear error, so callers degrade
//! gracefully (tests skip, `repro selftest` reports the missing runtime)
//! instead of failing to build.
//!
//! To restore real PJRT execution: add `xla` back to `Cargo.toml` and
//! delete the `#[path]` module alias in `runtime/mod.rs`.

use std::fmt;

/// Error type mirroring the real crate's (only `Display` is consumed).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("PJRT runtime unavailable: built with the in-tree xla stub (re-add the `xla` crate to enable AOT execution)".into())
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of the per-device buffer handle returned by `execute`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails: there is no PJRT in this build.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}
