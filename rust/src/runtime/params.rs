//! Rebuild the lowered graph's parameter literals from a `.bcnn` file.
//!
//! The AOT graph takes the folded model parameters as arguments (manifest
//! order, image first).  Layout contracts with `python/compile/`:
//!
//! * binary weights are `u32`-packed LSB-first — the `.bcnn` file's `u64`
//!   words split into (lo, hi) `u32` pairs (see
//!   `python/tests/test_packing.py::test_u32_and_u64_packings_agree`);
//! * first-layer weights are `s32` ±1; thresholds `s32`; classifier
//!   scale/bias `f32`.

use anyhow::{anyhow, bail, Result};

use crate::model::{BcnnModel, LayerWeights};
use crate::runtime::{xla, Manifest, ParamSpec};

/// Build literals for every manifest parameter from the loaded model.
pub(crate) fn build_literals(manifest: &Manifest, model: &BcnnModel) -> Result<Vec<xla::Literal>> {
    manifest.params.iter().map(|spec| build_one(spec, model)).collect()
}

fn build_one(spec: &ParamSpec, model: &BcnnModel) -> Result<xla::Literal> {
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let reshape = |lit: xla::Literal| -> Result<xla::Literal> {
        lit.reshape(&dims).map_err(|e| anyhow!("reshape {}: {e}", spec.name))
    };
    let expect: usize = spec.shape.iter().product();

    match classify(&spec.name)? {
        Param::Weights(layer_idx) => {
            let layer = layer_of(model, layer_idx)?;
            match layer {
                LayerWeights::FpConv { weights, .. } => {
                    if spec.dtype != "s32" {
                        bail!("{}: expected s32", spec.name);
                    }
                    let vals: Vec<i32> = weights.iter().map(|&w| w as i32).collect();
                    check_len(&spec.name, vals.len(), expect)?;
                    reshape(xla::Literal::vec1(&vals))
                }
                LayerWeights::BinConv { weights, words_per_row, out_c, in_c, .. } => {
                    let words32 = repack_u32(weights, *words_per_row, *out_c, 9 * in_c)?;
                    check_len(&spec.name, words32.len(), expect)?;
                    reshape(xla::Literal::vec1(&words32))
                }
                LayerWeights::BinFc { weights, words_per_row, out_f, in_f, .. }
                | LayerWeights::BinFcOut { weights, words_per_row, out_f, in_f, .. } => {
                    let words32 = repack_u32(weights, *words_per_row, *out_f, *in_f)?;
                    check_len(&spec.name, words32.len(), expect)?;
                    reshape(xla::Literal::vec1(&words32))
                }
            }
        }
        Param::Thresholds(layer_idx) => {
            let layer = layer_of(model, layer_idx)?;
            let thr = match layer {
                LayerWeights::FpConv { thresholds, .. }
                | LayerWeights::BinConv { thresholds, .. }
                | LayerWeights::BinFc { thresholds, .. } => thresholds,
                LayerWeights::BinFcOut { .. } => bail!("classifier has no thresholds"),
            };
            check_len(&spec.name, thr.len(), expect)?;
            reshape(xla::Literal::vec1(thr))
        }
        Param::Scale => {
            let LayerWeights::BinFcOut { scale, .. } = last_layer(model)? else {
                bail!("last layer is not a classifier");
            };
            check_len(&spec.name, scale.len(), expect)?;
            reshape(xla::Literal::vec1(scale))
        }
        Param::Bias => {
            let LayerWeights::BinFcOut { bias, .. } = last_layer(model)? else {
                bail!("last layer is not a classifier");
            };
            check_len(&spec.name, bias.len(), expect)?;
            reshape(xla::Literal::vec1(bias))
        }
    }
}

enum Param {
    Weights(usize),
    Thresholds(usize),
    Scale,
    Bias,
}

fn classify(name: &str) -> Result<Param> {
    if name == "scale" {
        return Ok(Param::Scale);
    }
    if name == "bias" {
        return Ok(Param::Bias);
    }
    if let Some(idx) = name.strip_prefix('w') {
        return Ok(Param::Weights(idx.parse()?));
    }
    if let Some(idx) = name.strip_prefix('c') {
        return Ok(Param::Thresholds(idx.parse()?));
    }
    bail!("unknown parameter name {name:?}")
}

fn layer_of(model: &BcnnModel, one_based: usize) -> Result<&LayerWeights> {
    model
        .layers
        .get(one_based.checked_sub(1).ok_or_else(|| anyhow!("layer 0"))?)
        .ok_or_else(|| anyhow!("layer {one_based} out of range"))
}

fn last_layer(model: &BcnnModel) -> Result<&LayerWeights> {
    model.layers.last().ok_or_else(|| anyhow!("empty model"))
}

fn check_len(name: &str, got: usize, want: usize) -> Result<()> {
    if got != want {
        bail!("{name}: {got} values, manifest wants {want}");
    }
    Ok(())
}

/// Split `.bcnn` u64 rows into the graph's u32 rows.
///
/// Python packs `ceil(k/32)` u32 words per row; the file has
/// `ceil(k/64)` u64 words.  u64 word w = u32[2w] | u32[2w+1] << 32, and
/// when `ceil(k/32)` is odd the final u64's high half is padding the graph
/// row does not include.
pub fn repack_u32(words64: &[u64], words_per_row: usize, rows: usize, k_bits: usize) -> Result<Vec<u32>> {
    if words64.len() != rows * words_per_row {
        bail!("weight rows mismatch: {} != {}", words64.len(), rows * words_per_row);
    }
    let row32 = k_bits.div_ceil(32);
    let mut out = Vec::with_capacity(rows * row32);
    for r in 0..rows {
        let row = &words64[r * words_per_row..(r + 1) * words_per_row];
        for i in 0..row32 {
            let w64 = row[i / 2];
            let half = if i % 2 == 0 { w64 as u32 } else { (w64 >> 32) as u32 };
            out.push(half);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repack_splits_lo_hi() {
        // one row, k=96 bits -> 2 u64 words -> 3 u32 words (last hi half
        // is padding, dropped)
        let words = vec![0x1111_2222_3333_4444u64, 0xdead_beef_0000_5555u64];
        let got = repack_u32(&words, 2, 1, 96).unwrap();
        assert_eq!(got, vec![0x3333_4444, 0x1111_2222, 0x0000_5555]);
    }

    #[test]
    fn repack_even_words() {
        let words = vec![0xAAAA_BBBB_CCCC_DDDDu64];
        let got = repack_u32(&words, 1, 1, 64).unwrap();
        assert_eq!(got, vec![0xCCCC_DDDD, 0xAAAA_BBBB]);
    }

    #[test]
    fn repack_rejects_bad_len() {
        assert!(repack_u32(&[0u64; 3], 2, 2, 64).is_err());
    }

    #[test]
    fn classify_names() {
        assert!(matches!(classify("w3").unwrap(), Param::Weights(3)));
        assert!(matches!(classify("c10").unwrap(), Param::Thresholds(10)));
        assert!(matches!(classify("scale").unwrap(), Param::Scale));
        assert!(classify("zzz").is_err());
    }
}
