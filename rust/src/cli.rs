//! Hand-rolled CLI (no clap in the offline crate cache).
//!
//! Subcommands:
//!   tables       — regenerate paper Tables 2/3/4/5
//!   simulate     — run the FPGA streaming simulator on a batch
//!   optimize     — run the §4.3 throughput optimizer for a config
//!   compare-gpu  — Fig. 7 batch sweep (FPGA model vs GPU model)
//!   infer        — classify images through a chosen backend
//!   serve        — start the serving control plane (registry of model
//!                  pools; optional protocol-v2 TCP front-end)
//!   deploy / undeploy / rollback / models — admin plane against a
//!                  running server (zero-downtime hot-swap by name)
//!   trace        — fetch the server's span rings as a Chrome trace-event
//!                  JSON file (load in Perfetto / chrome://tracing)
//!   top          — live terminal dashboard (windowed rate/p99
//!                  sparklines, pool health, per-stage busy bars)
//!   selftest     — engine vs PJRT vs FPGA-sim cross-check on artifacts
//!   features     — detected CPU features + chosen bitwise kernel
//!
//! `--kernel scalar|avx2|avx512|auto` (any command) forces the bitwise
//! SIMD dispatch: it is validated up front (typed error when the ISA is
//! missing) and exported as `BCNN_KERNEL`, so every engine built later —
//! including registry pools and pipeline stage threads — inherits it.

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::bcnn::Engine;
use crate::benchkit::{self, Table};
use crate::coordinator::workload::{
    random_images, run_closed_loop, run_frontend_load, run_open_loop, FrontendLoadConfig,
    LoadProto,
};
use crate::coordinator::{
    parse_qos_weights, serve_tcp_frontend, serve_tcp_threaded, Backend, BackendFactory,
    BatchPolicy, Coordinator, CoordinatorConfig, FpgaSimBackend, FrontendConfig, NativeBackend,
    PipelineBackend,
};
use crate::fpga::stream::simulate;
use crate::model::{BcnnModel, NetConfig};
use crate::optimizer::{optimize, OptimizeOptions};
use crate::runtime::Runtime;
use crate::serving::{
    serve_registry_frontend, serve_registry_threaded, BackendSpec, ControlClient, DeploySpec,
    ModelRegistry, ModelSource,
};
use crate::tables;
use crate::util::faults::{self, FaultPlan, FAULTS_ENV};
use crate::util::json::Json;
use crate::util::kernels::{Kernel, KernelKind, KERNEL_ENV};

/// Parsed arguments: positional subcommand + `--key value` / `--flag`.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = it.next().cloned().unwrap_or_else(|| "help".into());
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // "--key value" unless next token is another option/missing
                // (a trailing "--key" lands in `flags`; value-taking
                // accessors below turn that into a usage error instead of
                // a panic or a silently-applied default)
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let value = it
                            .next()
                            .ok_or_else(|| anyhow!("option --{key} requires a value"))?;
                        args.options.insert(key.to_string(), value.clone());
                    }
                    _ => args.flags.push(key.to_string()),
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// `Some(value)` for `--key value`, `None` when absent, and a usage
    /// error when `--key` was passed bare (it takes a value).
    pub fn value_of(&self, key: &str) -> Result<Option<&str>> {
        if self.flags.iter().any(|f| f == key) {
            bail!("option --{key} requires a value (see `repro help`)");
        }
        Ok(self.opt(key))
    }

    pub fn opt_or(&self, key: &str, default: &str) -> Result<String> {
        Ok(self.value_of(key)?.unwrap_or(default).to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.value_of(key)? {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.value_of(key)? {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

pub const USAGE: &str = "\
repro — BCNN FPGA-accelerator reproduction (Li et al. 2017)

USAGE: repro <command> [options]

COMMANDS
  tables [--table 2|3|4|5|all] [--optimized]
      Regenerate the paper's tables (default: all, paper design point).
  simulate [--config table2|small|tiny] [--images N] [--no-double-buffer]
           [--artifacts DIR]
      Run the FPGA streaming simulator (bit-exact numerics + cycle model).
  optimize [--config table2|small|tiny] [--uf-scale X] [--lut-headroom F]
           [--json]
      Run the throughput optimizer (paper §4.3) and print the plan.
      --json emits the full plan (per-layer UF/P/cycles, resources, fps)
      as machine-readable JSON, diffable against the executed host
      StagePlan recorded in BENCH_pipeline.json.
  compare-gpu [--batches 1,2,...]
      Fig. 7: FPGA vs Titan-X-model throughput & energy across batch sizes.
  infer [--config small] [--backend engine|pipeline|pjrt|fpga-sim]
        [--count N] [--inflight N] [--stage-threads N | --stage-plan auto]
        [--artifacts DIR]
      Classify random workload images; print scores summary + timing.
  serve [--config small | --models name=src,name=src,... [--default NAME]]
        [--backend engine|pipeline|fpga-sim|gpu-sim] [--port P]
        [--max-batch N] [--max-wait-ms M] [--requests N] [--rate RPS]
        [--workers W] [--queue-depth D] [--lanes L] [--inflight N]
        [--stage-threads N | --stage-plan auto]
        [--reactor-threads N] [--qos ON:OFF] [--deadline-ms MS]
        [--threaded]
      Start the serving control plane: every model gets its own sharded
      coordinator pool (W worker shards, bounded D-deep queues, L
      intra-batch lanes for the engine backend).  A model source is a
      built-in config name (artifact if trained, else synthetic), a
      `.bcnn` path, or `synthetic:<config>[:<seed>]`.  With --port,
      expose the TCP front-end (protocol v2 with model routing + admin
      frames; protocol-v1 clients are served by the default model);
      otherwise drive the built-in open-loop workload and print
      per-model serving metrics.  `--backend pipeline` serves from the
      row-streaming layer-pipeline runtime (N-image admission window);
      `--stage-threads N` balances N total stage lanes across the layers
      (paper §4.3 executed: the bottleneck stage gets more channel-
      partitioned lanes), `--stage-plan auto` sizes the budget to the
      machine's parallelism.  The TCP front-end is an epoll reactor:
      `--reactor-threads N` sizes the event-loop pool (0 = auto),
      `--qos ON:OFF` sets the online:offline admission weights
      (default 8:1), `--deadline-ms MS` gives online-lane requests a
      default dispatch deadline (expired requests get a typed shed
      reply), and `--threaded` falls back to the legacy
      thread-per-connection front-end.
  deploy --addr HOST:PORT --name NAME --source SRC [--backend B]
         [--workers W] [--queue-depth D]
      Hot-swap NAME on a running server: the new pool is built while the
      old version serves, then the route swaps — zero downtime.  SRC is
      a server-side `.bcnn` path or `synthetic:<config>[:<seed>]`.
      Omitted backend/workers/queue-depth inherit the pool parameters of
      the version currently serving under NAME.
  undeploy --addr HOST:PORT --name NAME
      Remove NAME from the routing table (in-flight requests drain).
  rollback --addr HOST:PORT --name NAME
      Redeploy NAME's previous version (zero downtime, new version id).
  models --addr HOST:PORT
      List deployed models and per-model serving stats (p50/p99) from
      the protocol-v2 LIST/STATS admin frames.
  health --addr HOST:PORT
      Per-model pool health from the protocol-v2 HEALTH admin frame:
      model state (ready/degraded/down) plus per-shard supervisor
      counters (state, crashes, restarts).
  trace --addr HOST:PORT [--out FILE]
      Fetch the server's span rings (protocol-v2 TRACE frame) as a
      Chrome trace-event JSON file (default trace.json): one track per
      worker shard (admission/queue/batch/reply spans) and one per
      pipeline stage, every span tagged with the request trace_id that
      v2 inference replies return.  Open the file in Perfetto
      (https://ui.perfetto.dev) or chrome://tracing.
  top --addr HOST:PORT [--interval-ms M] [--iterations N] [--no-clear]
      Live terminal dashboard: polls STATS + HEALTH every M ms (default
      1000) and redraws windowed throughput/p99 sparklines, per-model
      serving rows with client-side rates, pool health states, and
      per-stage busy/stall bars for pipeline backends.  N>0 exits after
      N refreshes (default: run until ^C); --no-clear appends frames
      instead of redrawing in place.
  profile --addr HOST:PORT [--duration S] [--out FILE]
      Performance accounting over the protocol-v2 PROFILE frame: per
      staged model, each stage's work ledger (rows, XNOR'd words,
      popcounts, bytes) and busy/stall clocks reconciled against the
      paper's eqs. 9-12 — utilization in (0,1], compute-/memory-bound
      roofline class, and the measured bottleneck stage checked against
      the eq.-12 prediction.  --duration S polls twice S seconds apart
      and reports the window between the polls (default: cumulative
      since deploy).  Writes the report to FILE (default
      BENCH_profile.json) in the shared benchkit envelope.
  bench --list | --merge FILE | --check [--baseline FILE] [--requests N]
        | --record [--baseline FILE] [--requests N]
      Perf-trajectory plumbing for the BENCH_*.json artifacts.  --list
      inventories artifacts (envelope: bench name, schema, commit);
      --merge aggregates them into one trajectory FILE; --check measures
      the hot-path ratios (serving overhead over bare engine, dispatched
      kernel over scalar) and gates them against the committed
      BENCH_baseline.json tolerance bands (exit non-zero on regression);
      --record refreshes the baseline file from fresh measurements.
  selftest [--artifacts DIR]
      Cross-check native engine vs PJRT executable vs FPGA simulator on
      the shipped artifacts (exit non-zero on mismatch).
  features
      Print detected CPU features, per-tier kernel availability, and the
      bitwise kernel the engine would dispatch to.
  help

GLOBAL OPTIONS
  --kernel scalar|avx2|avx512|auto
      Force the bitwise SIMD kernel (default: auto-detect, widest ISA
      wins).  Errors out if the requested ISA is unavailable.  Equivalent
      to setting BCNN_KERNEL.
  --faults <spec>
      Arm the deterministic fault-injection plan for this process, e.g.
      `seed=7;backend_infer:panic@once=3;submit:deny@p=0.01`.  The spec
      is validated up front and exported as BCNN_FAULTS so worker shards
      and stage threads inherit it.  Sites: backend_infer, stage_emit,
      submit, server_read, server_write.  Actions: panic, delay=<dur>,
      deny.  Triggers: @once=N, @every=N, @first=N, @p=<prob>.
";

/// Entry point used by `main.rs`.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    apply_kernel_option(&args)?;
    apply_faults_option(&args)?;
    match args.command.as_str() {
        "tables" => cmd_tables(&args),
        "simulate" => cmd_simulate(&args),
        "optimize" => cmd_optimize(&args),
        "compare-gpu" => cmd_compare_gpu(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "deploy" => cmd_deploy(&args),
        "undeploy" => cmd_admin_name_op(&args, "undeploy"),
        "rollback" => cmd_admin_name_op(&args, "rollback"),
        "models" => cmd_models(&args),
        "health" => cmd_health(&args),
        "trace" => cmd_trace(&args),
        "profile" => cmd_profile(&args),
        "bench" => cmd_bench(&args),
        "top" => cmd_top(&args),
        "selftest" => cmd_selftest(&args),
        "features" => cmd_features(),
        "help" | "" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn artifacts_dir(args: &Args) -> Result<PathBuf> {
    Ok(PathBuf::from(args.opt_or("artifacts", "artifacts")?))
}

/// Resolve `--kernel` (typed error for unknown/unavailable tiers) and
/// export it as `BCNN_KERNEL`, making the env var the single source of
/// truth: every `Engine::new` — worker shards, pipeline stage threads,
/// hot-swapped registry pools — picks the forced tier up from there.
fn apply_kernel_option(args: &Args) -> Result<()> {
    let Some(spec) = args.value_of("kernel")? else {
        return Ok(());
    };
    let kernel = Kernel::from_spec(Some(spec)).map_err(|e| anyhow!("--kernel {spec}: {e}"))?;
    std::env::set_var(KERNEL_ENV, kernel.name());
    Ok(())
}

/// Resolve `--faults` (typed error for a malformed spec), arm the plan in
/// this process, and export it as `BCNN_FAULTS` so spawned worker shards
/// and pipeline stage threads make identical, seeded injection decisions.
fn apply_faults_option(args: &Args) -> Result<()> {
    let Some(spec) = args.value_of("faults")? else {
        return Ok(());
    };
    let plan = FaultPlan::parse(spec).map_err(|e| anyhow!("--faults {spec:?}: {e}"))?;
    std::env::set_var(FAULTS_ENV, spec);
    faults::install(plan);
    Ok(())
}

/// `repro features`: the dispatch observability surface — what the CPU
/// reports, which kernel tiers can run, and which one auto-detect picks.
fn cmd_features() -> Result<()> {
    println!("cpu features (x86_64 SIMD dispatch inputs):");
    #[cfg(target_arch = "x86_64")]
    {
        for (name, have) in [
            ("popcnt", is_x86_feature_detected!("popcnt")),
            ("avx2", is_x86_feature_detected!("avx2")),
            ("avx512f", is_x86_feature_detected!("avx512f")),
            ("avx512bw", is_x86_feature_detected!("avx512bw")),
            ("avx512vpopcntdq", is_x86_feature_detected!("avx512vpopcntdq")),
        ] {
            println!("  {name:<16} {}", if have { "yes" } else { "no" });
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    println!("  (non-x86_64 host: scalar kernel only)");
    println!("kernel tiers:");
    for kind in KernelKind::ALL {
        match kind.unavailable_reason() {
            None => println!("  {:<8} available", kind.name()),
            Some(reason) => println!("  {:<8} unavailable ({reason})", kind.name()),
        }
    }
    match std::env::var(KERNEL_ENV).ok().filter(|v| !v.is_empty()) {
        Some(v) => println!("{KERNEL_ENV}={v}"),
        None => println!("{KERNEL_ENV} unset (auto-detect)"),
    }
    let chosen = Kernel::from_env().map_err(|e| anyhow!("{e}"))?;
    println!("selected kernel: {}", chosen.name());
    Ok(())
}

fn load_bcnn(args: &Args, config: &str) -> Result<BcnnModel> {
    let path = artifacts_dir(args)?.join(format!("model_{config}.bcnn"));
    match BcnnModel::load(&path) {
        Ok(m) => Ok(m),
        Err(e) => {
            // no trained artifact: fall back to deterministic synthetic
            // weights so serving/simulation demos run without python
            let Some(cfg) = NetConfig::by_name(config) else {
                return Err(e.context(format!(
                    "{} (run `make artifacts` first)",
                    path.display()
                )));
            };
            eprintln!(
                "note: {} not found; using synthetic weights for {config:?}",
                path.display()
            );
            Ok(BcnnModel::synthetic(&cfg, 0xB_C0DE))
        }
    }
}

fn net_config(args: &Args) -> Result<(String, NetConfig)> {
    let name = args.opt_or("config", "table2")?;
    let cfg = NetConfig::by_name(&name).ok_or_else(|| anyhow!("unknown config {name:?}"))?;
    Ok((name, cfg))
}

fn cmd_tables(args: &Args) -> Result<()> {
    let plan = if args.flag("optimized") { tables::optimized_plan()? } else { tables::default_plan() };
    let which = args.opt_or("table", "all")?;
    if which == "2" || which == "all" {
        println!("== Table 2: BCNN configuration ==\n{}", tables::table2(&NetConfig::table2()));
    }
    if which == "3" || which == "all" {
        println!("== Table 3: optimized parameters & cycles ==\n{}", tables::table3(&plan));
    }
    if which == "4" || which == "all" {
        println!("== Table 4: resource utilization ==\n{}", tables::table4(&plan));
    }
    if which == "5" || which == "all" {
        println!("== Table 5: accelerator comparison ==\n{}", tables::table5(&plan));
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (name, _cfg) = net_config(args)?;
    let model = load_bcnn(args, &name)?;
    let n = args.usize_or("images", 8)?;
    let backend = FpgaSimBackend::new(model.clone())?;
    let mut config = backend.stream_config().clone();
    config.double_buffered = !args.flag("no-double-buffer");
    let engine = crate::bcnn::Engine::new(model)?;
    let images = random_images(&engine.model().config(), n, 42);
    let report = simulate(&engine, &config, &images)?;
    println!("streaming simulation: {} images, config {}", n, name);
    println!("  double-buffered : {}", config.double_buffered);
    println!("  phase cycles    : {}", report.phase_cycles);
    println!("  total cycles    : {}", report.total_cycles);
    println!("  steady FPS      : {:.0} @ {:.0} MHz", report.fps, config.freq_hz / 1e6);
    println!("  first latency   : {:.3} ms", report.first_latency_s * 1e3);
    for (i, (c, u)) in report.layer_cycles.iter().zip(&report.utilization).enumerate() {
        println!("  layer {:>2} cycles : {:>8}  util {:>5.1}%", i + 1, c, u * 100.0);
    }
    let agree = images
        .iter()
        .zip(&report.scores)
        .all(|(img, s)| engine.infer(img).map(|e| &e == s).unwrap_or(false));
    println!("  numerics vs engine: {}", if agree { "MATCH" } else { "MISMATCH" });
    if !agree {
        bail!("simulator scores diverged from engine");
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let (_name, cfg) = net_config(args)?;
    let opts = OptimizeOptions {
        uf_scale: args.f64_or("uf-scale", 1.0)?,
        lut_headroom: args.f64_or("lut-headroom", 0.82)?,
        ..OptimizeOptions::default()
    };
    let plan = optimize(&cfg, &opts)?;
    if args.flag("json") {
        println!("{}", plan.to_json().to_string());
        return Ok(());
    }
    println!("{}", tables::table3(&plan));
    println!("{}", tables::table4(&plan));
    Ok(())
}

fn cmd_compare_gpu(args: &Args) -> Result<()> {
    let batches: Vec<usize> = match args.value_of("batches")? {
        None => vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
        Some(s) => s
            .split(',')
            .map(|x| x.trim().parse::<usize>().context("--batches"))
            .collect::<Result<_>>()?,
    };
    println!("{}", tables::fig7(&tables::default_plan(), &batches));
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let name = args.opt_or("config", "small")?;
    let model = load_bcnn(args, &name)?;
    let cfg = model.config();
    let count = args.usize_or("count", 16)?;
    let images = random_images(&cfg, count, 7);
    let backend = args.opt_or("backend", "native")?;
    let t0 = std::time::Instant::now();
    let scores: Vec<Vec<f32>> = match backend.as_str() {
        "engine" | "native" => {
            let engine = crate::bcnn::Engine::new(model)?;
            engine.infer_batch(&images)?
        }
        "pipeline" => {
            let inflight = args.usize_or("inflight", DEFAULT_INFLIGHT)?;
            let budget = stage_budget(args)?;
            let mut b = PipelineBackend::with_stage_budget(model, inflight, budget)?;
            b.infer_owned(&images)?.scores
        }
        "fpga-sim" => {
            let mut b = FpgaSimBackend::new(model)?;
            b.infer_owned(&images)?.scores
        }
        "pjrt" => {
            let mut rt = Runtime::new(artifacts_dir(args)?)?;
            let path = artifacts_dir(args)?.join(format!("model_{name}.bcnn"));
            let loaded = rt.load_model(&name, 1, path)?;
            let mut out = Vec::new();
            for img in &images {
                let s = loaded.infer_batch(img)?;
                out.push(s);
            }
            out
        }
        other => bail!("unknown backend {other:?}"),
    };
    let dt = t0.elapsed();
    let mut class_counts = vec![0usize; cfg.classes];
    for s in &scores {
        let arg = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        class_counts[arg] += 1;
    }
    println!(
        "{count} images via {backend}: {:.2} ms/image ({:.0} img/s)",
        dt.as_secs_f64() * 1e3 / count as f64,
        count as f64 / dt.as_secs_f64()
    );
    println!("predicted class histogram: {class_counts:?}");
    Ok(())
}

/// Default pipeline admission-window depth (images queued for feeding
/// beyond those already streaming through the stages).
pub const DEFAULT_INFLIGHT: usize = 8;

/// Resolve `--stage-threads N` / `--stage-plan auto` into a total
/// stage-lane budget for the pipeline backend (0 = one lane per stage,
/// i.e. the unbalanced pipeline).  `auto` sizes the budget to the
/// machine's available parallelism, letting the calibrated water-fill
/// decide which stages deserve the lanes.
fn stage_budget(args: &Args) -> Result<usize> {
    if let Some(v) = args.value_of("stage-threads")? {
        return v.parse::<usize>().with_context(|| format!("--stage-threads {v}"));
    }
    match args.value_of("stage-plan")? {
        None => Ok(0),
        Some("auto") => Ok(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8)),
        Some(other) => bail!("--stage-plan must be `auto`, got {other:?}"),
    }
}

/// Resolve `--backend`/`--lanes`/`--inflight`/`--stage-threads` into a
/// [`BackendSpec`]; an explicit `kind:N[:T]` parameter wins over the
/// separate flags.
fn backend_spec(
    kind: &str,
    lanes: usize,
    inflight: usize,
    stage_threads: usize,
) -> Result<BackendSpec> {
    let parsed = BackendSpec::parse(kind)?;
    if kind.contains(':') {
        return Ok(parsed);
    }
    Ok(match parsed {
        BackendSpec::Engine { .. } => BackendSpec::Engine { lanes },
        BackendSpec::Pipeline { .. } => BackendSpec::Pipeline { inflight, stage_threads },
        other => other,
    })
}

/// Load a model from a `--models` source: a built-in config name (trained
/// artifact if present, else synthetic), a `.bcnn` path, or
/// `synthetic:<config>[:<seed>]`.
fn resolve_model(args: &Args, source: &str) -> Result<BcnnModel> {
    if NetConfig::by_name(source).is_some() {
        return load_bcnn(args, source);
    }
    ModelSource::parse(source)?.load()
}

/// Build the reactor front-end config from `--reactor-threads`, `--qos`
/// (online:offline admission weights), and `--deadline-ms` (default
/// online-lane dispatch deadline).
fn frontend_config(args: &Args) -> Result<FrontendConfig> {
    let reactor_threads = args.usize_or("reactor-threads", 0)?;
    let mut qos = crate::coordinator::QosConfig::default();
    if let Some(spec) = args.value_of("qos")? {
        let (online, offline) = parse_qos_weights(spec)?;
        qos.online_weight = online;
        qos.offline_weight = offline;
    }
    let deadline_ms = args.usize_or("deadline-ms", 0)?;
    if deadline_ms > 0 {
        qos.default_deadline = Some(Duration::from_millis(deadline_ms as u64));
    }
    Ok(FrontendConfig { reactor_threads, qos })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let backend_name = args.opt_or("backend", "engine")?;
    let workers = args.usize_or("workers", 1)?.max(1);
    let queue_depth = args.usize_or("queue-depth", 256)?.max(1);
    let lanes = args.usize_or("lanes", 1)?.max(1);
    let inflight = args.usize_or("inflight", DEFAULT_INFLIGHT)?.max(1);
    let stage_threads = stage_budget(args)?;
    let policy = BatchPolicy {
        max_batch: args.usize_or("max-batch", 16)?,
        max_wait: Duration::from_millis(args.usize_or("max-wait-ms", 2)? as u64),
    };
    let backend = backend_spec(&backend_name, lanes, inflight, stage_threads)?;

    // model set: every entry gets its own pool behind the shared registry
    let registry = Arc::new(ModelRegistry::new());
    let mut default_cfg: Option<NetConfig> = None;
    let spec_for = |model: BcnnModel| {
        DeploySpec::new(model)
            .with_backend(backend)
            .with_workers(workers)
            .with_queue_depth(queue_depth)
            .with_policy(policy)
    };
    if let Some(models) = args.value_of("models")? {
        for part in models.split(',') {
            let part = part.trim();
            let (name, source) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("--models expects name=source, got {part:?}"))?;
            let model = resolve_model(args, source)?;
            if default_cfg.is_none() {
                default_cfg = Some(model.config());
            }
            let version = registry.deploy(name, spec_for(model))?;
            println!("deployed {name} v{version} <- {source} [{}]", backend.label());
        }
        // protocol-v1 clients are served by the default route (first
        // deployed unless overridden)
        if let Some(default) = args.value_of("default")? {
            registry.set_default(default)?;
            println!("default model: {default}");
        }
    } else {
        let name = args.opt_or("config", "small")?;
        let model = load_bcnn(args, &name)?;
        default_cfg = Some(model.config());
        let version = registry.deploy(&name, spec_for(model))?;
        println!("deployed {name} v{version} [{}]", backend.label());
    }

    if let Some(port) = args.value_of("port")? {
        let addr = format!("127.0.0.1:{port}");
        let listener = TcpListener::bind(&addr).with_context(|| format!("bind {addr}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        if args.flag("threaded") {
            println!(
                "serving {} model(s) on {addr} (thread-per-connection front-end; \
                 {workers} shard(s) per model, queue depth {queue_depth}; ctrl-c to stop)",
                registry.list().len()
            );
            serve_registry_threaded(listener, Arc::clone(&registry), stop)?;
            return Ok(());
        }
        let frontend = frontend_config(args)?;
        println!(
            "serving {} model(s) on {addr} (epoll reactor front-end, {} loop thread(s), \
             qos {}:{}; {workers} shard(s) per model, queue depth {queue_depth}; ctrl-c to stop)",
            registry.list().len(),
            frontend.resolved_threads(),
            frontend.qos.online_weight,
            frontend.qos.offline_weight,
        );
        serve_registry_frontend(listener, Arc::clone(&registry), stop, frontend)?;
        return Ok(());
    }

    // built-in workload mode against the default model
    let cfg = default_cfg.expect("at least one model deployed");
    let requests = args.usize_or("requests", 64)?;
    let rate = args.f64_or("rate", 200.0)?;
    println!(
        "driving open-loop workload: {requests} requests at {rate}/s \
         across {workers} shard(s)"
    );
    let entry = registry.router().resolve(None).map_err(|e| anyhow!("{e}"))?;
    let report = run_open_loop(&entry.client(), &cfg, requests, rate, 11)?;
    println!(
        "  achieved {:.1} req/s, mean latency {:.2} ms, mean batch {:.1}, errors {}",
        report.throughput(),
        report.mean_latency().as_secs_f64() * 1e3,
        report.mean_batch(),
        report.errors()
    );
    drop(entry);
    for s in registry.stats() {
        println!("  model {} v{} [{}]: {}", s.name, s.version, s.backend, s.metrics.summary());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// admin-plane commands (protocol v2 against a running `serve --port`)
// ---------------------------------------------------------------------------

fn admin_client(args: &Args) -> Result<ControlClient> {
    let addr = args
        .value_of("addr")?
        .ok_or_else(|| anyhow!("--addr HOST:PORT is required"))?;
    ControlClient::connect(addr)
}

fn required<'a>(args: &'a Args, key: &str) -> Result<&'a str> {
    args.value_of(key)?.ok_or_else(|| anyhow!("--{key} is required"))
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let name = required(args, "name")?;
    let source = required(args, "source")?;
    // unset fields inherit the currently-deployed pool's parameters
    let backend = args.opt_or("backend", "")?;
    let workers = args.usize_or("workers", 0)?;
    let queue_depth = args.usize_or("queue-depth", 0)?;
    let mut client = admin_client(args)?;
    let version = client.deploy(name, source, &backend, workers, queue_depth)?;
    let shown = if backend.is_empty() { "inherited" } else { backend.as_str() };
    println!("deployed {name} v{version} <- {source} [{shown}]");
    client.close()
}

fn cmd_admin_name_op(args: &Args, op: &str) -> Result<()> {
    let name = required(args, "name")?;
    let mut client = admin_client(args)?;
    let version = match op {
        "undeploy" => client.undeploy(name)?,
        _ => client.rollback(name)?,
    };
    match op {
        "undeploy" => println!("undeployed {name} (was v{version})"),
        _ => println!("rolled back {name} -> v{version}"),
    }
    client.close()
}

fn cmd_models(args: &Args) -> Result<()> {
    let mut client = admin_client(args)?;
    let list = client.list()?;
    let stats = client.stats()?;
    client.close()?;

    println!("routing epoch {}", list.get("epoch")?.as_f64()? as u64);
    let mut table = Table::new(&["model", "version", "backend", "config", "workers", "default"]);
    for m in list.get("models")?.as_arr()? {
        table.row(&[
            m.get("name")?.as_str()?.to_string(),
            format!("v{}", m.get("version")?.as_f64()? as u64),
            m.get("backend")?.as_str()?.to_string(),
            m.get("config")?.as_str()?.to_string(),
            format!("{}", m.get("workers")?.as_f64()? as u64),
            match m.get("default")? {
                Json::Bool(true) => "*".to_string(),
                _ => String::new(),
            },
        ]);
    }
    table.print();

    println!();
    let mut table =
        Table::new(&["model", "version", "live", "requests", "errors", "p50 ms", "p99 ms"]);
    for m in stats.get("models")?.as_arr()? {
        let metrics = m.get("metrics")?;
        table.row(&[
            m.get("name")?.as_str()?.to_string(),
            format!("v{}", m.get("version")?.as_f64()? as u64),
            match m.get("live")? {
                Json::Bool(true) => "yes".to_string(),
                _ => "no".to_string(),
            },
            format!("{}", metrics.get("requests")?.as_f64()? as u64),
            format!("{}", metrics.get("errors")?.as_f64()? as u64),
            format!("{:.2}", metrics.get("latency_p50_us")?.as_f64()? / 1e3),
            format!("{:.2}", metrics.get("latency_p99_us")?.as_f64()? / 1e3),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_health(args: &Args) -> Result<()> {
    let mut client = admin_client(args)?;
    let health = client.health()?;
    client.close()?;

    println!("routing epoch {}", health.get("epoch")?.as_f64()? as u64);
    let mut table = Table::new(&["model", "version", "state", "shards", "crashes", "restarts"]);
    for m in health.get("models")?.as_arr()? {
        let shards = m.get("shards")?.as_arr()?;
        let mut crashes = 0u64;
        let mut restarts = 0u64;
        let mut ready = 0usize;
        for s in shards {
            crashes += s.get("crashes")?.as_f64()? as u64;
            restarts += s.get("restarts")?.as_f64()? as u64;
            if s.get("state")?.as_str()? == "ready" {
                ready += 1;
            }
        }
        table.row(&[
            m.get("name")?.as_str()?.to_string(),
            format!("v{}", m.get("version")?.as_f64()? as u64),
            m.get("state")?.as_str()?.to_string(),
            format!("{ready}/{} ready", shards.len()),
            format!("{crashes}"),
            format!("{restarts}"),
        ]);
    }
    table.print();
    Ok(())
}

/// `repro trace`: fetch the server's span rings and write a Perfetto-
/// loadable Chrome trace-event JSON file.
fn cmd_trace(args: &Args) -> Result<()> {
    let out_path = args.opt_or("out", "trace.json")?;
    let mut client = admin_client(args)?;
    let trace = client.trace()?;
    client.close()?;
    let events = trace.get("traceEvents")?.as_arr()?;
    let spans = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()).map(|s| s == "X").unwrap_or(false))
        .count();
    let tracks = events.len() - spans;
    std::fs::write(&out_path, trace.to_string())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}: {spans} spans across {tracks} tracks");
    println!("open it in Perfetto (https://ui.perfetto.dev) or chrome://tracing");
    Ok(())
}

/// Eight-level block ramp for the `top` sparklines.
const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Render `values` as a `width`-column sparkline scaled to the series'
/// own maximum.  The output is always exactly `width` glyphs: a short
/// series is left-padded with spaces (so the newest sample stays pinned
/// to the right edge and the columns after the sparkline never drift),
/// and a long series shows its last `width` samples.
fn sparkline(values: &[f64], width: usize) -> String {
    let tail = &values[values.len().saturating_sub(width)..];
    let max = tail.iter().cloned().fold(0.0f64, f64::max);
    let glyphs: String = tail
        .iter()
        .map(|&v| {
            if max <= 0.0 {
                return SPARK[0];
            }
            SPARK[((v / max) * 7.0).round().clamp(0.0, 7.0) as usize]
        })
        .collect();
    format!("{}{}", " ".repeat(width - tail.len()), glyphs)
}

/// `frac` of `width` as a filled bar (`█` filled, `·` empty).
fn bar(frac: f64, width: usize) -> String {
    let filled = ((frac.clamp(0.0, 1.0) * width as f64).round() as usize).min(width);
    format!("{}{}", "█".repeat(filled), "·".repeat(width - filled))
}

/// `repro top`: live dashboard over the STATS + HEALTH admin frames.
fn cmd_top(args: &Args) -> Result<()> {
    let interval_ms = args.usize_or("interval-ms", 1000)? as u64;
    let interval = Duration::from_millis(interval_ms).max(Duration::from_millis(100));
    let iterations = args.usize_or("iterations", 0)?;
    let clear = !args.flag("no-clear");
    let addr = args.value_of("addr")?.unwrap_or("").to_string();
    let mut client = admin_client(args)?;
    // previous poll's per-model cumulative request counts, for the
    // client-side rate column (server windows cover the whole registry)
    let mut prev: Option<(Instant, BTreeMap<String, f64>)> = None;
    let mut rounds = 0usize;
    loop {
        let stats = client.stats()?;
        let health = client.health()?;
        let now = Instant::now();
        let prev_view = prev.as_ref().map(|(at, c)| (*at, c));
        let frame = render_top(&addr, &stats, &health, prev_view, now)?;
        if clear {
            print!("\x1b[2J\x1b[H");
        }
        print!("{frame}");
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        let mut cum = BTreeMap::new();
        for m in stats.get("models")?.as_arr()? {
            cum.insert(
                m.get("name")?.as_str()?.to_string(),
                m.get("metrics")?.get("requests")?.as_f64()?,
            );
        }
        // cumulative per-lane shed totals feed the lanes table's shed/s
        if let Some(lanes) =
            stats.get("frontend").ok().and_then(|fe| fe.get("lanes").ok()).and_then(|l| l.as_obj().ok())
        {
            for (name, lane) in lanes {
                cum.insert(format!("lane:{name}"), num(lane, "shed_expired") + num(lane, "shed_overload"));
            }
        }
        prev = Some((now, cum));
        rounds += 1;
        if iterations > 0 && rounds >= iterations {
            break;
        }
        std::thread::sleep(interval);
    }
    client.close()
}

/// Build one `top` frame: windowed sparklines, per-model rows, health
/// states, and per-stage busy/stall bars.
fn render_top(
    addr: &str,
    stats: &Json,
    health: &Json,
    prev: Option<(Instant, &BTreeMap<String, f64>)>,
    now: Instant,
) -> Result<String> {
    use std::fmt::Write as _;
    let mut out = String::new();
    let epoch = stats.get("epoch")?.as_f64()? as u64;
    let models = stats.get("models")?.as_arr()?;
    writeln!(out, "repro top — {addr}  epoch {epoch}  {} model(s)", models.len()).ok();

    // ---- registry-wide windowed telemetry ------------------------------
    let windows = stats.get("windows")?.as_arr()?;
    if windows.is_empty() {
        writeln!(out, "\nwindows: (no closed 1s windows yet)").ok();
    } else {
        let tail = &windows[windows.len().saturating_sub(60)..];
        let rates: Vec<f64> =
            tail.iter().map(|w| w.get("rate").and_then(|v| v.as_f64()).unwrap_or(0.0)).collect();
        let p99s: Vec<f64> = tail
            .iter()
            .map(|w| w.get("latency_p99_us").and_then(|v| v.as_f64()).unwrap_or(0.0) / 1e3)
            .collect();
        let last = tail.last().expect("tail is non-empty");
        writeln!(
            out,
            "\nwindows   rate {}  {:>8.1} req/s",
            sparkline(&rates, 60),
            rates.last().copied().unwrap_or(0.0)
        )
        .ok();
        let last_p99 = p99s.last().copied().unwrap_or(0.0);
        writeln!(out, "          p99  {}  {:>8.2} ms", sparkline(&p99s, 60), last_p99).ok();
        writeln!(
            out,
            "          last: requests {}  errors {}  crashes {}  failovers {}",
            last.get("requests")?.as_f64()? as u64,
            last.get("errors")?.as_f64()? as u64,
            last.get("crashes")?.as_f64()? as u64,
            last.get("requests_failed_over")?.as_f64()? as u64,
        )
        .ok();
    }

    // ---- front-end QoS lanes (reactor front-ends only) -----------------
    if let Ok(fe) = stats.get("frontend") {
        writeln!(
            out,
            "\nfrontend  conns {}  reactor threads {}  paused reads {}",
            num(fe, "connections") as u64,
            num(fe, "reactor_threads") as u64,
            num(fe, "paused_reads") as u64,
        )
        .ok();
        if let Some(lanes) = fe.get("lanes").ok().and_then(|l| l.as_obj().ok()) {
            let mut table = Table::new(&[
                "lane", "depth", "admitted", "dispatched", "shed exp", "shed ovl", "shed/s",
            ]);
            for (name, lane) in lanes {
                let sheds = num(lane, "shed_expired") + num(lane, "shed_overload");
                let shed_rate = match prev {
                    Some((at, cum)) => match cum.get(&format!("lane:{name}")) {
                        Some(&p) if now > at => {
                            format!("{:.1}", (sheds - p).max(0.0) / (now - at).as_secs_f64())
                        }
                        _ => "-".to_string(),
                    },
                    None => "-".to_string(),
                };
                table.row(&[
                    name.clone(),
                    format!("{}", num(lane, "depth") as u64),
                    format!("{}", num(lane, "admitted") as u64),
                    format!("{}", num(lane, "dispatched") as u64),
                    format!("{}", num(lane, "shed_expired") as u64),
                    format!("{}", num(lane, "shed_overload") as u64),
                    shed_rate,
                ]);
            }
            out.push_str(&table.to_string());
        }
    }

    // ---- per-model serving rows (health state joined in) ---------------
    let mut states: BTreeMap<String, String> = BTreeMap::new();
    for m in health.get("models")?.as_arr()? {
        let name = m.get("name")?.as_str()?.to_string();
        states.insert(name, m.get("state")?.as_str()?.to_string());
    }
    writeln!(out).ok();
    let mut table = Table::new(&[
        "model", "version", "state", "backend", "requests", "req/s", "p50 ms", "p99 ms", "util",
        "errors", "crashes",
    ]);
    for m in models {
        let name = m.get("name")?.as_str()?.to_string();
        let metrics = m.get("metrics")?;
        let requests = metrics.get("requests")?.as_f64()?;
        // aggregate pipeline utilization: Σbusy / Σ(busy+stalls) over
        // stages, "-" for backends without a staged pipeline
        let util = match metrics.get("stages").ok().map(|s| s.as_arr()) {
            Some(Ok(stages)) if !stages.is_empty() => {
                let mut busy = 0.0f64;
                let mut total = 0.0f64;
                for s in stages {
                    let b = s.get("busy_us").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    busy += b;
                    total += b
                        + s.get("stall_in_us").and_then(|v| v.as_f64()).unwrap_or(0.0)
                        + s.get("stall_out_us").and_then(|v| v.as_f64()).unwrap_or(0.0);
                }
                if total > 0.0 {
                    format!("{:.0}%", busy / total * 100.0)
                } else {
                    "-".to_string()
                }
            }
            _ => "-".to_string(),
        };
        let rate = match prev {
            Some((at, cum)) => match cum.get(&name) {
                Some(&p) if now > at => {
                    format!("{:.1}", (requests - p).max(0.0) / (now - at).as_secs_f64())
                }
                _ => "-".to_string(),
            },
            None => "-".to_string(),
        };
        let live = matches!(m.get("live")?, Json::Bool(true));
        let state = if live {
            states.get(&name).cloned().unwrap_or_else(|| "?".to_string())
        } else {
            "retired".to_string()
        };
        table.row(&[
            name,
            format!("v{}", m.get("version")?.as_f64()? as u64),
            state,
            m.get("backend")?.as_str()?.to_string(),
            format!("{}", requests as u64),
            rate,
            format!("{:.2}", metrics.get("latency_p50_us")?.as_f64()? / 1e3),
            format!("{:.2}", metrics.get("latency_p99_us")?.as_f64()? / 1e3),
            util,
            format!("{}", metrics.get("errors")?.as_f64()? as u64),
            format!("{}", metrics.get("crashes")?.as_f64()? as u64),
        ]);
    }
    out.push_str(&table.to_string());

    // ---- per-stage busy/stall bars (pipeline backends) -----------------
    for m in models {
        let metrics = m.get("metrics")?;
        let Ok(stages) = metrics.get("stages") else { continue };
        let stages = stages.as_arr()?;
        if stages.is_empty() {
            continue;
        }
        writeln!(out, "\nstages — {}", m.get("name")?.as_str()?).ok();
        for s in stages {
            let busy = s.get("busy_us")?.as_f64()?;
            let stall_in = s.get("stall_in_us")?.as_f64()?;
            let stall_out = s.get("stall_out_us")?.as_f64()?;
            let total = busy + stall_in + stall_out;
            let frac = if total > 0.0 { busy / total } else { 0.0 };
            // roofline class from the profiler's work ledger (absent or
            // zero while the BCNN_PROFILE gate is disarmed)
            let xor_words = s.get("xor_words").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let bytes = s.get("bytes_moved").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let bound = if bytes > 0.0 {
                crate::obs::classify(xor_words * 128.0 / bytes).label()
            } else {
                "-"
            };
            writeln!(
                out,
                "  stage {:>2} x{:<2} [{}] busy {:>5.1}%  stall in {:>5.1}% out {:>5.1}%  {}",
                s.get("layer")?.as_f64()? as u64,
                s.get("lanes")?.as_f64()? as u64,
                bar(frac, 20),
                frac * 100.0,
                if total > 0.0 { stall_in / total * 100.0 } else { 0.0 },
                if total > 0.0 { stall_out / total * 100.0 } else { 0.0 },
                bound,
            )
            .ok();
        }
    }
    Ok(out)
}

/// Tolerant numeric field read: 0.0 when absent or non-numeric.
fn num(j: &Json, k: &str) -> f64 {
    j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
}

/// `repro profile`: model-vs-measured performance accounting over the
/// OP_PROFILE admin frame.
fn cmd_profile(args: &Args) -> Result<()> {
    let duration = args.f64_or("duration", 0.0)?;
    let out_path = args.opt_or("out", "BENCH_profile.json")?;
    let addr = args.value_of("addr")?.unwrap_or("").to_string();
    let mut client = admin_client(args)?;
    let mut profile = client.profile()?;
    if duration > 0.0 {
        // two polls bracket the window; the report is the delta of the
        // raw counters with the derived columns recomputed client-side
        std::thread::sleep(Duration::from_secs_f64(duration));
        let second = client.profile()?;
        profile = windowed_profile(&profile, &second)?;
    }
    client.close()?;
    print!("{}", render_profile(&addr, duration, &profile)?);

    // artifact in the shared benchkit envelope (BTreeMap serialization
    // sorts keys; the envelope fields are still top-level for `bench
    // --list` and the perf-gate greps)
    let mut top = BTreeMap::new();
    top.insert(
        "schema_version".to_string(),
        Json::Num(benchkit::BENCH_SCHEMA_VERSION as f64),
    );
    top.insert("bench".to_string(), Json::Str("profile".to_string()));
    top.insert("git_commit".to_string(), Json::Str(benchkit::git_commit()));
    top.insert(
        "config_fingerprint".to_string(),
        Json::Str(format!("addr={addr};duration={duration}")),
    );
    top.insert("profile".to_string(), profile);
    std::fs::write(&out_path, Json::Obj(top).to_string())
        .with_context(|| format!("write {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// Delta two OP_PROFILE polls into a windowed report.  Models that were
/// redeployed between polls (version changed) or appear only in the
/// second poll fall back to their cumulative report.
fn windowed_profile(first: &Json, second: &Json) -> Result<Json> {
    let mut prev: BTreeMap<String, &Json> = BTreeMap::new();
    for m in first.get("models")?.as_arr()? {
        prev.insert(m.get("name")?.as_str()?.to_string(), m);
    }
    let mut models = Vec::new();
    for m in second.get("models")?.as_arr()? {
        let name = m.get("name")?.as_str()?;
        let windowed = prev
            .get(name)
            .filter(|p| {
                num(p, "version") == num(m, "version")
                    && p.get("report").and_then(|r| r.get("layers")).is_ok()
                    && m.get("report").and_then(|r| r.get("layers")).is_ok()
            })
            .map(|p| -> Result<Json> {
                let cur = m.get("report")?;
                let old = p.get("report")?;
                let mut entry = m.as_obj()?.clone();
                entry.insert("report".to_string(), window_report(cur, old)?);
                Ok(Json::Obj(entry))
            })
            .transpose()?;
        models.push(windowed.unwrap_or_else(|| m.clone()));
    }
    let mut top = second.as_obj()?.clone();
    top.insert("models".to_string(), Json::Arr(models));
    Ok(Json::Obj(top))
}

/// Window one model's account report: raw counters are deltas, derived
/// columns (utilization, ns/image, model ratio, measured bottleneck)
/// are recomputed from the deltas.  Model-side quantities (cycle
/// estimates, intensity, bound) carry over unchanged — they depend only
/// on the geometry.
fn window_report(cur: &Json, old: &Json) -> Result<Json> {
    let freq_hz = num(cur, "freq_hz").max(1.0);
    let old_layers = old.get("layers")?.as_arr()?;
    let mut layers = Vec::new();
    let mut best: Option<(usize, f64)> = None;
    for (i, layer) in cur.get("layers")?.as_arr()?.iter().enumerate() {
        let zero = Json::Obj(BTreeMap::new());
        let before = old_layers.get(i).unwrap_or(&zero);
        let mut m = layer.as_obj()?.clone();
        let delta = |k: &str| (num(layer, k) - num(before, k)).max(0.0);
        for k in [
            "images",
            "rows_in",
            "xor_words",
            "popcounts",
            "bytes_moved",
            "busy_us",
            "stall_in_us",
            "stall_out_us",
        ] {
            m.insert(k.to_string(), Json::Num(delta(k)));
        }
        let busy = delta("busy_us");
        let total = busy + delta("stall_in_us") + delta("stall_out_us");
        m.insert(
            "utilization".to_string(),
            if busy > 0.0 && total > 0.0 { Json::Num(busy / total) } else { Json::Null },
        );
        let images = delta("images");
        let ns_per_image = if images > 0.0 { Some(busy * 1e3 / images) } else { None };
        m.insert(
            "ns_per_image".to_string(),
            ns_per_image.map(Json::Num).unwrap_or(Json::Null),
        );
        let model_ns = num(layer, "cycles_est") / freq_hz * 1e9;
        m.insert(
            "model_ratio".to_string(),
            match ns_per_image {
                Some(ns) if model_ns > 0.0 => Json::Num(ns / model_ns),
                _ => Json::Null,
            },
        );
        if let Some(ns) = ns_per_image {
            let better = match best {
                Some((_, b)) => ns > b,
                None => true,
            };
            if better {
                best = Some((i, ns));
            }
        }
        layers.push(Json::Obj(m));
    }
    let mut top = cur.as_obj()?.clone();
    top.insert("layers".to_string(), Json::Arr(layers));
    let measured = best.map(|(i, _)| i);
    top.insert(
        "measured_bottleneck".to_string(),
        measured.map(|i| Json::Num(i as f64)).unwrap_or(Json::Null),
    );
    let predicted = num(cur, "predicted_bottleneck") as usize;
    top.insert(
        "bottleneck_match".to_string(),
        Json::Bool(measured == Some(predicted)),
    );
    Ok(Json::Obj(top))
}

/// Human-readable model-vs-measured table for one OP_PROFILE report.
fn render_profile(addr: &str, duration: f64, profile: &Json) -> Result<String> {
    use std::fmt::Write as _;
    let mut out = String::new();
    let scope =
        if duration > 0.0 { format!("{duration}s window") } else { "cumulative".to_string() };
    writeln!(out, "repro profile — {addr}  epoch {}  ({scope})", num(profile, "epoch") as u64)
        .ok();
    for m in profile.get("models")?.as_arr()? {
        let name = m.get("name")?.as_str()?;
        let report = m.get("report")?;
        if let Ok(err) = report.get("error") {
            writeln!(out, "\n{name}: accounting unavailable: {}", err.as_str().unwrap_or("?"))
                .ok();
            continue;
        }
        writeln!(
            out,
            "\n{name} v{} ({}, kernel {})",
            num(m, "version") as u64,
            m.get("backend")?.as_str()?,
            m.get("kernel").and_then(|k| k.as_str()).unwrap_or("-"),
        )
        .ok();
        let mut table = Table::new(&[
            "layer", "name", "lanes", "images", "util", "cyc est", "cyc real", "ns/img",
            "x model", "bitops/B", "bound",
        ]);
        let fmt_opt = |layer: &Json, k: &str, scale: f64, digits: usize| match layer.get(k) {
            Ok(Json::Num(n)) => format!("{:.*}", digits, n * scale),
            _ => "-".to_string(),
        };
        for layer in report.get("layers")?.as_arr()? {
            let util = match layer.get("utilization") {
                Ok(Json::Num(n)) => format!("{:.0}%", n * 100.0),
                _ => "-".to_string(),
            };
            table.row(&[
                format!("{}", num(layer, "layer") as u64),
                layer.get("name")?.as_str()?.to_string(),
                format!("{}", num(layer, "lanes") as u64),
                format!("{}", num(layer, "images") as u64),
                util,
                format!("{}", num(layer, "cycles_est") as u64),
                format!("{}", num(layer, "cycles_real") as u64),
                fmt_opt(layer, "ns_per_image", 1.0, 0),
                fmt_opt(layer, "model_ratio", 1.0, 2),
                format!("{:.1}", num(layer, "intensity")),
                layer.get("bound")?.as_str()?.to_string(),
            ]);
        }
        out.push_str(&table.to_string());
        let layers = report.get("layers")?.as_arr()?;
        let stage_name = |i: usize| -> String {
            layers
                .get(i)
                .and_then(|l| l.get("name").ok())
                .and_then(|n| n.as_str().ok())
                .unwrap_or("?")
                .to_string()
        };
        let predicted = num(report, "predicted_bottleneck") as usize;
        match report.get("measured_bottleneck")? {
            Json::Num(i) => {
                let i = *i as usize;
                let verdict = if report.get("bottleneck_match")?.as_bool()? {
                    "MATCH"
                } else {
                    "MISS"
                };
                writeln!(
                    out,
                    "bottleneck: measured stage {i} ({}) vs eq.12-predicted stage \
                     {predicted} ({}) — {verdict}",
                    stage_name(i),
                    stage_name(predicted),
                )
                .ok();
            }
            _ => {
                writeln!(
                    out,
                    "bottleneck: no traffic in window; eq.12 predicts stage {predicted} ({})",
                    stage_name(predicted),
                )
                .ok();
            }
        }
    }
    Ok(out)
}

/// `repro bench`: BENCH_*.json inventory / aggregation and the committed
/// perf-regression baseline check.
fn cmd_bench(args: &Args) -> Result<()> {
    if args.flag("list") {
        return bench_list();
    }
    if let Some(path) = args.value_of("merge")? {
        let path = path.to_string();
        return bench_merge(&path);
    }
    if args.flag("check") || args.flag("record") {
        return bench_check(args);
    }
    bail!("bench: pass --list, --merge FILE, --check, or --record (see help)")
}

/// Every BENCH_*.json reachable from the usual emit locations: the
/// working directory (examples run from the repo root) and `rust/`
/// (cargo benches run from the package root).
fn bench_artifacts() -> Vec<PathBuf> {
    let mut found = Vec::new();
    for dir in [".", "rust"] {
        let Ok(entries) = std::fs::read_dir(dir) else { continue };
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().to_string();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                found.push(entry.path());
            }
        }
    }
    found.sort();
    found
}

fn bench_list() -> Result<()> {
    let files = bench_artifacts();
    if files.is_empty() {
        println!("no BENCH_*.json artifacts found (run a cargo bench or `repro profile` first)");
        return Ok(());
    }
    let mut table = Table::new(&["file", "bench", "schema", "commit", "fingerprint"]);
    for path in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        // pre-envelope artifacts still list, with the gaps visible
        let parsed = Json::parse(&text).ok();
        let field = |k: &str| -> String {
            parsed
                .as_ref()
                .and_then(|j| j.get(k).ok().cloned())
                .map(|v| match v {
                    Json::Str(s) => s,
                    Json::Num(n) => format!("{n}"),
                    other => other.to_string(),
                })
                .unwrap_or_else(|| "-".to_string())
        };
        let commit = field("git_commit");
        table.row(&[
            path.display().to_string(),
            field("bench"),
            field("schema_version"),
            commit.chars().take(8).collect(),
            field("config_fingerprint"),
        ]);
    }
    table.print();
    println!("{} artifact(s)", files.len());
    Ok(())
}

fn bench_merge(out_path: &str) -> Result<()> {
    let files = bench_artifacts();
    let mut benches = BTreeMap::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let parsed =
            Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
        let stem = path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_else(|| path.display().to_string());
        benches.insert(stem, parsed);
    }
    let n = benches.len();
    let mut top = BTreeMap::new();
    top.insert(
        "schema_version".to_string(),
        Json::Num(benchkit::BENCH_SCHEMA_VERSION as f64),
    );
    top.insert("bench".to_string(), Json::Str("merged".to_string()));
    top.insert("git_commit".to_string(), Json::Str(benchkit::git_commit()));
    top.insert("benches".to_string(), Json::Obj(benches));
    std::fs::write(out_path, Json::Obj(top).to_string())
        .with_context(|| format!("write {out_path}"))?;
    println!("merged {n} artifact(s) into {out_path}");
    Ok(())
}

/// Measure the machine-portable hot-path ratios and gate them against
/// (or, with `--record`, refresh) the committed baseline.
fn bench_check(args: &Args) -> Result<()> {
    let requests = args.usize_or("requests", 256)?;
    let baseline_path = match args.value_of("baseline")? {
        Some(p) => p.to_string(),
        // cargo runs from the repo root; the committed copy lives in rust/
        None if std::path::Path::new("rust/BENCH_baseline.json").exists() => {
            "rust/BENCH_baseline.json".to_string()
        }
        None => "BENCH_baseline.json".to_string(),
    };

    let model = BcnnModel::load_or_synthetic("tiny", "artifacts", 0xB_C0DE)?;
    let cfg = model.config();
    let images = random_images(&cfg, 4, 0xBE);

    // bare engine, dispatched kernel (the serving denominator)
    let engine = Engine::new(model.clone())?;
    let mut i = 0usize;
    let engine_ns = benchkit::bench(|| {
        let img = &images[i % images.len()];
        i += 1;
        std::hint::black_box(engine.infer(img).expect("engine infer"));
    })
    .median_ns;

    // same engine pinned to the scalar kernel (the dispatch numerator's
    // portable reference point)
    let scalar = Engine::with_kernel(
        model.clone(),
        Kernel::force(KernelKind::Scalar).map_err(|e| anyhow!("{e}"))?,
    )?;
    let mut j = 0usize;
    let scalar_ns = benchkit::bench(|| {
        let img = &images[j % images.len()];
        j += 1;
        std::hint::black_box(scalar.infer(img).expect("scalar infer"));
    })
    .median_ns;

    // closed-loop serving through a 1-worker native pool: queueing +
    // batching + channel overhead over the bare engine
    let m = model.clone();
    let factory: BackendFactory = Arc::new(move || -> anyhow::Result<Box<dyn Backend>> {
        Ok(Box::new(NativeBackend::new(m.clone())?))
    });
    let coord = Coordinator::start_sharded(
        factory,
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::ZERO },
            workers: 1,
            queue_depth: 64,
            ..Default::default()
        },
    )?;
    run_closed_loop(&coord.client(), &cfg, (requests / 4).max(8), 0xA1)?; // warm-up
    let report = run_closed_loop(&coord.client(), &cfg, requests, 0xA2)?;
    let serve_ns = 1e9 / report.throughput().max(1e-9);

    // front-end A/B on the same pool: legacy thread-per-connection vs
    // the epoll reactor under an identical multiplexed open-loop load.
    // Lower-is-better ratio: reactor ns/request over threaded ns/request
    // — a climbing ratio means the reactor front-end is losing ground.
    let fe_ns = |reactor: bool| -> Result<f64> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let (client, stop2) = (coord.client(), Arc::clone(&stop));
        let serve = std::thread::spawn(move || -> Result<()> {
            if reactor {
                serve_tcp_frontend(listener, client, stop2, FrontendConfig::default())
            } else {
                serve_tcp_threaded(listener, client, stop2)
            }
        });
        let load = FrontendLoadConfig {
            addr,
            connections: 64,
            threads: 2,
            window: 4,
            duration: Duration::from_millis(300),
            rate_rps: None,
            proto: LoadProto::V1,
            seed: 0xF00D,
        };
        let fe_report = run_frontend_load(&load, &images[0])?;
        stop.store(true, Ordering::SeqCst);
        serve.join().map_err(|_| anyhow!("front-end serve thread panicked"))??;
        if !fe_report.conservation_ok() {
            bail!(
                "front-end load lost {} of {} request(s) without a reply",
                fe_report.lost,
                fe_report.sent
            );
        }
        Ok(1e9 / fe_report.throughput().max(1e-9))
    };
    let threaded_ns = fe_ns(false)?;
    let reactor_ns = fe_ns(true)?;
    coord.shutdown();

    let mut measured: BTreeMap<String, f64> = BTreeMap::new();
    measured.insert("serve_over_engine_ratio".to_string(), serve_ns / engine_ns.max(1e-9));
    measured.insert("dispatched_over_scalar_ratio".to_string(), engine_ns / scalar_ns.max(1e-9));
    measured.insert(
        "reactor_over_threaded_ns_ratio".to_string(),
        reactor_ns / threaded_ns.max(1e-9),
    );
    measured.insert("engine_ns_per_image".to_string(), engine_ns);
    measured.insert("scalar_ns_per_image".to_string(), scalar_ns);
    measured.insert("serve_ns_per_request".to_string(), serve_ns);
    measured.insert("frontend_threaded_ns_per_request".to_string(), threaded_ns);
    measured.insert("frontend_reactor_ns_per_request".to_string(), reactor_ns);

    if args.flag("record") {
        return bench_record(&baseline_path, &measured);
    }

    let text = std::fs::read_to_string(&baseline_path)
        .with_context(|| format!("read baseline {baseline_path} (run `bench --record`?)"))?;
    let baseline = Json::parse(&text).with_context(|| format!("parse {baseline_path}"))?;
    let results = benchkit::check_baseline(&baseline, &measured)?;

    let mut table = Table::new(&["metric", "baseline", "measured", "limit", "gate", "verdict"]);
    let mut failed = Vec::new();
    for r in &results {
        table.row(&[
            r.metric.clone(),
            format!("{:.3}", r.baseline),
            r.measured.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".to_string()),
            if r.gated { format!("{:.3}", r.limit) } else { "-".to_string() },
            if r.gated { "yes" } else { "info" }.to_string(),
            if r.pass { "ok" } else { "FAIL" }.to_string(),
        ]);
        if !r.pass {
            failed.push(r.metric.clone());
        }
    }
    println!("=== bench --check vs {baseline_path} ({requests} closed-loop requests) ===");
    table.print();
    if !failed.is_empty() {
        bail!("perf regression past the tolerance band: {}", failed.join(", "));
    }
    println!("all gated metrics within their tolerance bands");
    Ok(())
}

/// `bench --record`: refresh the baseline from fresh measurements.  The
/// ratio metrics keep generous bands (they gate CI), the absolute
/// nanosecond metrics stay informational — they are machine-specific.
fn bench_record(path: &str, measured: &BTreeMap<String, f64>) -> Result<()> {
    let band = |metric: &str| match metric {
        "serve_over_engine_ratio" => Some(150.0),
        "dispatched_over_scalar_ratio" => Some(25.0),
        // reactor ns/request over threaded ns/request at ~64 multiplexed
        // connections; generous band — CI boxes schedule noisily, the
        // gate only has to catch the reactor collapsing outright
        "reactor_over_threaded_ns_ratio" => Some(100.0),
        _ => None,
    };
    let mut metrics = BTreeMap::new();
    for (name, &value) in measured {
        let mut m = BTreeMap::new();
        m.insert("value".to_string(), Json::Num(value));
        m.insert(
            "max_regression_pct".to_string(),
            Json::Num(band(name).unwrap_or(0.0)),
        );
        m.insert("gate".to_string(), Json::Bool(band(name).is_some()));
        metrics.insert(name.clone(), Json::Obj(m));
    }
    let mut top = BTreeMap::new();
    top.insert(
        "schema_version".to_string(),
        Json::Num(benchkit::BENCH_SCHEMA_VERSION as f64),
    );
    top.insert("bench".to_string(), Json::Str("baseline".to_string()));
    top.insert("git_commit".to_string(), Json::Str(benchkit::git_commit()));
    top.insert(
        "config_fingerprint".to_string(),
        Json::Str("tiny;native-pool-w1".to_string()),
    );
    top.insert("metrics".to_string(), Json::Obj(metrics));
    std::fs::write(path, Json::Obj(top).to_string()).with_context(|| format!("write {path}"))?;
    println!("recorded baseline to {path}");
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args)?;
    let name = "tiny";
    let model = BcnnModel::load(dir.join(format!("model_{name}.bcnn")))?;
    let cfg = model.config();
    let images = random_images(&cfg, 4, 99);
    let engine = crate::bcnn::Engine::new(model.clone())?;
    let native: Vec<Vec<f32>> = engine.infer_batch(&images)?;

    // PJRT path
    let mut rt = Runtime::new(&dir)?;
    let loaded = rt.load_model(name, 1, dir.join(format!("model_{name}.bcnn")))?;
    for (i, img) in images.iter().enumerate() {
        let scores = loaded.infer_batch(img)?;
        for (a, b) in scores.iter().zip(&native[i]) {
            if (a - b).abs() > 1e-3 {
                bail!("PJRT vs native mismatch image {i}: {a} vs {b}");
            }
        }
    }
    println!("PJRT == native: OK ({} images)", images.len());

    // FPGA simulator path
    let mut fpga = FpgaSimBackend::new(model)?;
    let sim = fpga.infer_owned(&images)?;
    for (i, s) in sim.scores.iter().enumerate() {
        if s != &native[i] {
            bail!("FPGA-sim vs native mismatch image {i}");
        }
    }
    println!("FPGA-sim == native: OK (bit-exact)");
    println!("selftest PASS");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        let argv: Vec<String> = tokens.iter().map(|s| s.to_string()).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn trailing_valued_flag_is_a_usage_error_not_a_panic() {
        // `repro serve --workers` used to fall through as a silent boolean
        // flag (and the parser's unwrap path could panic); now every
        // value-taking accessor reports a usage error
        let args = parse(&["serve", "--workers"]);
        assert!(args.usize_or("workers", 1).is_err());
        assert!(args.opt_or("workers", "x").is_err());
        assert!(args.value_of("workers").is_err());
        assert!(args.f64_or("workers", 1.0).is_err());
    }

    #[test]
    fn valued_flag_followed_by_flag_is_also_bare() {
        let args = parse(&["serve", "--workers", "--port", "9000"]);
        assert!(args.usize_or("workers", 1).is_err());
        assert_eq!(args.value_of("port").unwrap(), Some("9000"));
    }

    #[test]
    fn kernel_option_rejects_unknown_and_bare() {
        // unknown tier and a bare `--kernel` are usage errors surfaced
        // before any subcommand runs (and before the env var is touched)
        assert!(apply_kernel_option(&parse(&["infer", "--kernel", "sse9"])).is_err());
        assert!(apply_kernel_option(&parse(&["infer", "--kernel"])).is_err());
        assert!(apply_kernel_option(&parse(&["infer"])).is_ok());
    }

    #[test]
    fn faults_option_rejects_malformed_and_bare() {
        // malformed site/action specs and a bare `--faults` are usage
        // errors surfaced before any subcommand runs (nothing is armed)
        assert!(apply_faults_option(&parse(&["infer", "--faults", "bogus_site:panic"])).is_err());
        assert!(apply_faults_option(&parse(&["infer", "--faults", "submit:explode"])).is_err());
        assert!(apply_faults_option(&parse(&["infer", "--faults"])).is_err());
        assert!(apply_faults_option(&parse(&["infer"])).is_ok());
    }

    #[test]
    fn normal_parsing_still_works() {
        let args = parse(&["serve", "--workers", "4", "--optimized", "pos"]);
        assert_eq!(args.usize_or("workers", 1).unwrap(), 4);
        assert!(args.flag("optimized"));
        assert_eq!(args.usize_or("queue-depth", 7).unwrap(), 7);
        assert_eq!(args.positional, vec!["pos".to_string()]);
        assert_eq!(args.opt_or("backend", "engine").unwrap(), "engine");
    }
}
