//! Hand-rolled CLI (no clap in the offline crate cache).
//!
//! Subcommands:
//!   tables       — regenerate paper Tables 2/3/4/5
//!   simulate     — run the FPGA streaming simulator on a batch
//!   optimize     — run the §4.3 throughput optimizer for a config
//!   compare-gpu  — Fig. 7 batch sweep (FPGA model vs GPU model)
//!   infer        — classify images through a chosen backend
//!   serve        — start the coordinator (optionally with TCP front-end)
//!   selftest     — engine vs PJRT vs FPGA-sim cross-check on artifacts

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::workload::{random_images, run_open_loop};
use crate::coordinator::{
    Backend, BackendFactory, BatchPolicy, Coordinator, CoordinatorConfig, FpgaSimBackend,
    GpuSimBackend, NativeBackend, PipelineBackend,
};
use crate::fpga::stream::simulate;
use crate::gpu::GpuKernel;
use crate::model::{BcnnModel, NetConfig};
use crate::optimizer::{optimize, OptimizeOptions};
use crate::runtime::Runtime;
use crate::tables;

/// Parsed arguments: positional subcommand + `--key value` / `--flag`.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = it.next().cloned().unwrap_or_else(|| "help".into());
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // "--key value" unless next token is another option/missing
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        args.options.insert(key.to_string(), it.next().unwrap().clone());
                    }
                    _ => args.flags.push(key.to_string()),
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

pub const USAGE: &str = "\
repro — BCNN FPGA-accelerator reproduction (Li et al. 2017)

USAGE: repro <command> [options]

COMMANDS
  tables [--table 2|3|4|5|all] [--optimized]
      Regenerate the paper's tables (default: all, paper design point).
  simulate [--config table2|small|tiny] [--images N] [--no-double-buffer]
           [--artifacts DIR]
      Run the FPGA streaming simulator (bit-exact numerics + cycle model).
  optimize [--config table2|small|tiny] [--uf-scale X] [--lut-headroom F]
      Run the throughput optimizer (paper §4.3) and print the plan.
  compare-gpu [--batches 1,2,...]
      Fig. 7: FPGA vs Titan-X-model throughput & energy across batch sizes.
  infer [--config small] [--backend engine|pipeline|pjrt|fpga-sim]
        [--count N] [--inflight N] [--artifacts DIR]
      Classify random workload images; print scores summary + timing.
  serve [--config small] [--backend engine|pipeline|fpga-sim|gpu-sim]
        [--port P] [--max-batch N] [--max-wait-ms M] [--requests N]
        [--rate RPS] [--workers W] [--queue-depth D] [--lanes L]
        [--inflight N]
      Start the sharded coordinator (W worker shards, one backend replica
      each, bounded D-deep queues, L intra-batch lanes for the engine
      backend); with --port, expose TCP; otherwise drive the built-in
      open-loop workload and print serving metrics.  `--backend pipeline`
      serves from the row-streaming layer-pipeline runtime (all layers
      concurrently active; N-image admission window per replica).
  selftest [--artifacts DIR]
      Cross-check native engine vs PJRT executable vs FPGA simulator on
      the shipped artifacts (exit non-zero on mismatch).
  help
";

/// Entry point used by `main.rs`.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "tables" => cmd_tables(&args),
        "simulate" => cmd_simulate(&args),
        "optimize" => cmd_optimize(&args),
        "compare-gpu" => cmd_compare_gpu(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "selftest" => cmd_selftest(&args),
        "help" | "" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_or("artifacts", "artifacts"))
}

fn load_bcnn(args: &Args, config: &str) -> Result<BcnnModel> {
    let path = artifacts_dir(args).join(format!("model_{config}.bcnn"));
    match BcnnModel::load(&path) {
        Ok(m) => Ok(m),
        Err(e) => {
            // no trained artifact: fall back to deterministic synthetic
            // weights so serving/simulation demos run without python
            let Some(cfg) = NetConfig::by_name(config) else {
                return Err(e.context(format!(
                    "{} (run `make artifacts` first)",
                    path.display()
                )));
            };
            eprintln!(
                "note: {} not found; using synthetic weights for {config:?}",
                path.display()
            );
            Ok(BcnnModel::synthetic(&cfg, 0xB_C0DE))
        }
    }
}

fn net_config(args: &Args) -> Result<(String, NetConfig)> {
    let name = args.opt_or("config", "table2");
    let cfg = NetConfig::by_name(&name).ok_or_else(|| anyhow!("unknown config {name:?}"))?;
    Ok((name, cfg))
}

fn cmd_tables(args: &Args) -> Result<()> {
    let plan = if args.flag("optimized") { tables::optimized_plan()? } else { tables::default_plan() };
    let which = args.opt_or("table", "all");
    if which == "2" || which == "all" {
        println!("== Table 2: BCNN configuration ==\n{}", tables::table2(&NetConfig::table2()));
    }
    if which == "3" || which == "all" {
        println!("== Table 3: optimized parameters & cycles ==\n{}", tables::table3(&plan));
    }
    if which == "4" || which == "all" {
        println!("== Table 4: resource utilization ==\n{}", tables::table4(&plan));
    }
    if which == "5" || which == "all" {
        println!("== Table 5: accelerator comparison ==\n{}", tables::table5(&plan));
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (name, _cfg) = net_config(args)?;
    let model = load_bcnn(args, &name)?;
    let n = args.usize_or("images", 8)?;
    let backend = FpgaSimBackend::new(model.clone())?;
    let mut config = backend.stream_config().clone();
    config.double_buffered = !args.flag("no-double-buffer");
    let engine = crate::bcnn::Engine::new(model)?;
    let images = random_images(&engine.model().config(), n, 42);
    let report = simulate(&engine, &config, &images)?;
    println!("streaming simulation: {} images, config {}", n, name);
    println!("  double-buffered : {}", config.double_buffered);
    println!("  phase cycles    : {}", report.phase_cycles);
    println!("  total cycles    : {}", report.total_cycles);
    println!("  steady FPS      : {:.0} @ {:.0} MHz", report.fps, config.freq_hz / 1e6);
    println!("  first latency   : {:.3} ms", report.first_latency_s * 1e3);
    for (i, (c, u)) in report.layer_cycles.iter().zip(&report.utilization).enumerate() {
        println!("  layer {:>2} cycles : {:>8}  util {:>5.1}%", i + 1, c, u * 100.0);
    }
    let agree = images
        .iter()
        .zip(&report.scores)
        .all(|(img, s)| engine.infer(img).map(|e| &e == s).unwrap_or(false));
    println!("  numerics vs engine: {}", if agree { "MATCH" } else { "MISMATCH" });
    if !agree {
        bail!("simulator scores diverged from engine");
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let (_name, cfg) = net_config(args)?;
    let opts = OptimizeOptions {
        uf_scale: args.f64_or("uf-scale", 1.0)?,
        lut_headroom: args.f64_or("lut-headroom", 0.82)?,
        ..OptimizeOptions::default()
    };
    let plan = optimize(&cfg, &opts)?;
    println!("{}", tables::table3(&plan));
    println!("{}", tables::table4(&plan));
    Ok(())
}

fn cmd_compare_gpu(args: &Args) -> Result<()> {
    let batches: Vec<usize> = match args.opt("batches") {
        None => vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
        Some(s) => s
            .split(',')
            .map(|x| x.trim().parse::<usize>().context("--batches"))
            .collect::<Result<_>>()?,
    };
    println!("{}", tables::fig7(&tables::default_plan(), &batches));
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let name = args.opt_or("config", "small");
    let model = load_bcnn(args, &name)?;
    let cfg = model.config();
    let count = args.usize_or("count", 16)?;
    let images = random_images(&cfg, count, 7);
    let backend = args.opt_or("backend", "native");
    let t0 = std::time::Instant::now();
    let scores: Vec<Vec<f32>> = match backend.as_str() {
        "engine" | "native" => {
            let engine = crate::bcnn::Engine::new(model)?;
            engine.infer_batch(&images)?
        }
        "pipeline" => {
            let inflight = args.usize_or("inflight", DEFAULT_INFLIGHT)?;
            let mut b = PipelineBackend::new(model, inflight)?;
            b.infer_owned(&images)?.scores
        }
        "fpga-sim" => {
            let mut b = FpgaSimBackend::new(model)?;
            b.infer_owned(&images)?.scores
        }
        "pjrt" => {
            let mut rt = Runtime::new(artifacts_dir(args))?;
            let loaded = rt.load_model(&name, 1, artifacts_dir(args).join(format!("model_{name}.bcnn")))?;
            let mut out = Vec::new();
            for img in &images {
                let s = loaded.infer_batch(img)?;
                out.push(s);
            }
            out
        }
        other => bail!("unknown backend {other:?}"),
    };
    let dt = t0.elapsed();
    let mut class_counts = vec![0usize; cfg.classes];
    for s in &scores {
        let arg = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        class_counts[arg] += 1;
    }
    println!(
        "{count} images via {backend}: {:.2} ms/image ({:.0} img/s)",
        dt.as_secs_f64() * 1e3 / count as f64,
        count as f64 / dt.as_secs_f64()
    );
    println!("predicted class histogram: {class_counts:?}");
    Ok(())
}

/// Default pipeline admission-window depth (images queued for feeding
/// beyond those already streaming through the stages).
pub const DEFAULT_INFLIGHT: usize = 8;

/// Build a per-worker backend factory for the named backend kind
/// (`engine` is the canonical name for the sequential native engine;
/// `native` stays accepted for compatibility).
fn backend_factory(
    kind: &str,
    model: BcnnModel,
    lanes: usize,
    inflight: usize,
) -> Result<BackendFactory> {
    match kind {
        "engine" | "native" | "pipeline" | "fpga-sim" | "gpu-sim" => {}
        other => bail!("unknown backend {other:?}"),
    }
    let kind = kind.to_string();
    Ok(Arc::new(move || -> Result<Box<dyn Backend>> {
        Ok(match kind.as_str() {
            "engine" | "native" => Box::new(NativeBackend::with_lanes(model.clone(), lanes)?),
            "pipeline" => Box::new(PipelineBackend::new(model.clone(), inflight)?),
            "fpga-sim" => Box::new(FpgaSimBackend::new(model.clone())?),
            _ => Box::new(GpuSimBackend::new(model.clone(), GpuKernel::Xnor)?),
        })
    }))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let name = args.opt_or("config", "small");
    let model = load_bcnn(args, &name)?;
    let cfg = model.config();
    let backend_name = args.opt_or("backend", "engine");
    let workers = args.usize_or("workers", 1)?.max(1);
    let queue_depth = args.usize_or("queue-depth", 256)?.max(1);
    let lanes = args.usize_or("lanes", 1)?.max(1);
    let inflight = args.usize_or("inflight", DEFAULT_INFLIGHT)?.max(1);
    let policy = BatchPolicy {
        max_batch: args.usize_or("max-batch", 16)?,
        max_wait: Duration::from_millis(args.usize_or("max-wait-ms", 2)? as u64),
    };
    let factory = backend_factory(&backend_name, model, lanes, inflight)?;
    let coord =
        Coordinator::start_sharded(factory, CoordinatorConfig { policy, workers, queue_depth })?;

    if let Some(port) = args.opt("port") {
        let addr = format!("127.0.0.1:{port}");
        let listener = TcpListener::bind(&addr).with_context(|| format!("bind {addr}"))?;
        println!(
            "serving {name} via {backend_name} on {addr} \
             ({workers} shard(s), queue depth {queue_depth}; ctrl-c to stop)"
        );
        let stop = Arc::new(AtomicBool::new(false));
        crate::coordinator::server::serve_tcp(listener, coord.client(), stop)?;
        return Ok(());
    }

    // built-in workload mode
    let requests = args.usize_or("requests", 64)?;
    let rate = args.f64_or("rate", 200.0)?;
    println!(
        "driving open-loop workload: {requests} requests at {rate}/s \
         across {workers} shard(s)"
    );
    let report = run_open_loop(&coord.client(), &cfg, requests, rate, 11)?;
    println!(
        "  achieved {:.1} req/s, mean latency {:.2} ms, mean batch {:.1}, errors {}",
        report.throughput(),
        report.mean_latency().as_secs_f64() * 1e3,
        report.mean_batch(),
        report.errors()
    );
    let per_shard: Vec<u64> = coord.shard_metrics().iter().map(|m| m.requests).collect();
    let metrics = coord.shutdown();
    println!("  per-shard requests: {per_shard:?}");
    println!("  {}", metrics.summary());
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let name = "tiny";
    let model = BcnnModel::load(dir.join(format!("model_{name}.bcnn")))?;
    let cfg = model.config();
    let images = random_images(&cfg, 4, 99);
    let engine = crate::bcnn::Engine::new(model.clone())?;
    let native: Vec<Vec<f32>> = engine.infer_batch(&images)?;

    // PJRT path
    let mut rt = Runtime::new(&dir)?;
    let loaded = rt.load_model(name, 1, dir.join(format!("model_{name}.bcnn")))?;
    for (i, img) in images.iter().enumerate() {
        let scores = loaded.infer_batch(img)?;
        for (a, b) in scores.iter().zip(&native[i]) {
            if (a - b).abs() > 1e-3 {
                bail!("PJRT vs native mismatch image {i}: {a} vs {b}");
            }
        }
    }
    println!("PJRT == native: OK ({} images)", images.len());

    // FPGA simulator path
    let mut fpga = FpgaSimBackend::new(model)?;
    let sim = fpga.infer_owned(&images)?;
    for (i, s) in sim.scores.iter().enumerate() {
        if s != &native[i] {
            bail!("FPGA-sim vs native mismatch image {i}");
        }
    }
    println!("FPGA-sim == native: OK (bit-exact)");
    println!("selftest PASS");
    Ok(())
}
