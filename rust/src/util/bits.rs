//! Bit-string helpers shared by the packed engine and the model loader.
//!
//! Convention (identical to `python/compile/packing.py`): bit `b` of word
//! `w` holds flattened element `w*64 + b` — LSB-first within each `u64`.

/// Number of `u64` words needed to hold `bits` bits.
#[inline]
pub const fn words_for(bits: usize) -> usize {
    bits.div_ceil(64)
}

/// Read bit `idx` from a packed word slice.
#[inline]
pub fn get_bit(words: &[u64], idx: usize) -> bool {
    (words[idx / 64] >> (idx % 64)) & 1 == 1
}

/// Set bit `idx` in a packed word slice.
#[inline]
pub fn set_bit(words: &mut [u64], idx: usize, value: bool) {
    let (w, b) = (idx / 64, idx % 64);
    if value {
        words[w] |= 1u64 << b;
    } else {
        words[w] &= !(1u64 << b);
    }
}

/// Copy `len` bits from `src` starting at bit `src_off` into `dst` starting
/// at bit `dst_off`.  Destination bits outside the range are preserved.
///
/// This is the patch-assembly primitive of the native engine (gathering
/// 3x3 neighbourhood channel blocks into an im2row patch) so it has a fast
/// word-aligned path; the general path shifts across word boundaries.
pub fn copy_bits(dst: &mut [u64], dst_off: usize, src: &[u64], src_off: usize, len: usize) {
    if len == 0 {
        return;
    }
    debug_assert!(src_off + len <= src.len() * 64, "src range");
    debug_assert!(dst_off + len <= dst.len() * 64, "dst range");

    // Fast path: both offsets word-aligned.
    if dst_off % 64 == 0 && src_off % 64 == 0 {
        let dw = dst_off / 64;
        let sw = src_off / 64;
        let full = len / 64;
        dst[dw..dw + full].copy_from_slice(&src[sw..sw + full]);
        let tail = len % 64;
        if tail != 0 {
            let mask = (1u64 << tail) - 1;
            dst[dw + full] = (dst[dw + full] & !mask) | (src[sw + full] & mask);
        }
        return;
    }

    // General path: 64-bit chunks with unaligned word reads.
    let mut done = 0;
    while done < len {
        let n = (len - done).min(64);
        let chunk = read_bits_u64(src, src_off + done, n);
        write_bits_u64(dst, dst_off + done, chunk, n);
        done += n;
    }
}

/// Read `n <= 64` bits starting at `off` as the low bits of a u64.
#[inline]
pub fn read_bits_u64(words: &[u64], off: usize, n: usize) -> u64 {
    debug_assert!(n >= 1 && n <= 64);
    let w = off / 64;
    let b = off % 64;
    let lo = words[w] >> b;
    let val = if b != 0 && b + n > 64 {
        lo | (words[w + 1] << (64 - b))
    } else {
        lo
    };
    if n == 64 {
        val
    } else {
        val & ((1u64 << n) - 1)
    }
}

/// Write the low `n <= 64` bits of `value` at bit offset `off`.
#[inline]
pub fn write_bits_u64(words: &mut [u64], off: usize, value: u64, n: usize) {
    debug_assert!(n >= 1 && n <= 64);
    let masked = if n == 64 { value } else { value & ((1u64 << n) - 1) };
    let w = off / 64;
    let b = off % 64;
    if b == 0 {
        let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
        words[w] = (words[w] & !mask) | masked;
    } else if b + n <= 64 {
        let mask = (if n == 64 { u64::MAX } else { (1u64 << n) - 1 }) << b;
        words[w] = (words[w] & !mask) | (masked << b);
    } else {
        let lo_n = 64 - b;
        let hi_n = n - lo_n;
        let lo_mask = ((1u64 << lo_n) - 1) << b;
        words[w] = (words[w] & !lo_mask) | (masked << b);
        let hi_mask = (1u64 << hi_n) - 1;
        words[w + 1] = (words[w + 1] & !hi_mask) | (masked >> lo_n);
    }
}

/// Popcount of `a XOR b` over whole word slices (the XnorDotProduct core:
/// mismatch count; match count = k_bits - mismatches when pad bits agree).
#[inline]
pub fn xor_popcount(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for (x, y) in a.iter().zip(b.iter()) {
        acc += (x ^ y).count_ones();
    }
    acc
}

/// For one activation word `p`, accumulate `popcount(p ^ bank[n])` into
/// `mism[n]` for every filter lane `n` — the vertical (filter-bank-major)
/// XnorDotProduct step of the tap-major engine.  The weight bank is
/// unit-stride, so the loop lowers to popcount lanes with no horizontal
/// reductions; `p` is broadcast.
///
/// The `out_c` lanes are walked in chunks of 4 with the trailing partial
/// chunk handled once at the end, so the hot loop carries no per-word
/// bounds check; the bank/mismatch length invariant is asserted at the
/// call boundary instead (`debug_assert!` — callers size both from
/// `out_c`).
#[inline]
pub fn xor_popcount_lanes(p: u64, bank: &[u64], mism: &mut [u64]) {
    debug_assert_eq!(bank.len(), mism.len(), "bank/mismatch lanes");
    let n = bank.len().min(mism.len());
    let (bank, mism) = (&bank[..n], &mut mism[..n]);
    let mut banks = bank.chunks_exact(4);
    let mut misms = mism.chunks_exact_mut(4);
    for (b4, m4) in (&mut banks).zip(&mut misms) {
        m4[0] += (p ^ b4[0]).count_ones() as u64;
        m4[1] += (p ^ b4[1]).count_ones() as u64;
        m4[2] += (p ^ b4[2]).count_ones() as u64;
        m4[3] += (p ^ b4[3]).count_ones() as u64;
    }
    for (m, &w) in misms.into_remainder().iter_mut().zip(banks.remainder()) {
        *m += (p ^ w).count_ones() as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn random_bits(rng: &mut SplitMix64, n: usize) -> Vec<bool> {
        (0..n).map(|_| rng.bit()).collect()
    }

    fn pack(bits: &[bool]) -> Vec<u64> {
        let mut words = vec![0u64; words_for(bits.len())];
        for (i, &b) in bits.iter().enumerate() {
            set_bit(&mut words, i, b);
        }
        words
    }

    #[test]
    fn get_set_roundtrip() {
        let mut rng = SplitMix64::new(1);
        let bits = random_bits(&mut rng, 193);
        let words = pack(&bits);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(get_bit(&words, i), b, "bit {i}");
        }
    }

    #[test]
    fn copy_bits_property() {
        // property sweep: random (src_off, dst_off, len) against a scalar model
        let mut rng = SplitMix64::new(2);
        for case in 0..500 {
            let src_bits = random_bits(&mut rng, 256);
            let dst_bits = random_bits(&mut rng, 256);
            let src = pack(&src_bits);
            let mut dst = pack(&dst_bits);
            let len = rng.below(200) as usize;
            let src_off = rng.below((256 - len + 1) as u64) as usize;
            let dst_off = rng.below((256 - len + 1) as u64) as usize;
            copy_bits(&mut dst, dst_off, &src, src_off, len);
            for i in 0..256 {
                let want = if i >= dst_off && i < dst_off + len {
                    src_bits[src_off + (i - dst_off)]
                } else {
                    dst_bits[i]
                };
                assert_eq!(get_bit(&dst, i), want, "case {case} bit {i}");
            }
        }
    }

    #[test]
    fn copy_bits_aligned_fast_path() {
        let mut rng = SplitMix64::new(3);
        let src_bits = random_bits(&mut rng, 320);
        let src = pack(&src_bits);
        let mut dst = vec![0u64; 5];
        copy_bits(&mut dst, 64, &src, 128, 96);
        for i in 0..96 {
            assert_eq!(get_bit(&dst, 64 + i), src_bits[128 + i]);
        }
        assert_eq!(dst[0], 0);
    }

    #[test]
    fn read_write_bits_u64_roundtrip() {
        let mut rng = SplitMix64::new(4);
        for _ in 0..500 {
            let mut words = vec![rng.next_u64(), rng.next_u64(), rng.next_u64()];
            let n = 1 + rng.below(64) as usize;
            let off = rng.below((192 - n + 1) as u64) as usize;
            let val = rng.next_u64();
            let before: Vec<bool> = (0..192).map(|i| get_bit(&words, i)).collect();
            write_bits_u64(&mut words, off, val, n);
            let got = read_bits_u64(&words, off, n);
            let want = if n == 64 { val } else { val & ((1 << n) - 1) };
            assert_eq!(got, want);
            for i in 0..192 {
                if i < off || i >= off + n {
                    assert_eq!(get_bit(&words, i), before[i], "untouched bit {i}");
                }
            }
        }
    }

    #[test]
    fn xor_popcount_lanes_matches_scalar() {
        let mut rng = SplitMix64::new(6);
        // lane counts exercising the 4-lane chunks and every remainder
        for lanes in [0usize, 1, 2, 3, 4, 5, 8, 9, 11] {
            let p = rng.next_u64();
            let bank: Vec<u64> = (0..lanes).map(|_| rng.next_u64()).collect();
            let mut mism = vec![3u64; lanes]; // non-zero start: must accumulate
            xor_popcount_lanes(p, &bank, &mut mism);
            for (n, &w) in bank.iter().enumerate() {
                assert_eq!(mism[n], 3 + (p ^ w).count_ones() as u64, "{lanes} lanes, lane {n}");
            }
        }
    }

    #[test]
    fn xor_popcount_matches_scalar() {
        let mut rng = SplitMix64::new(5);
        let a: Vec<u64> = (0..7).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..7).map(|_| rng.next_u64()).collect();
        let scalar: u32 = (0..7 * 64)
            .filter(|&i| get_bit(&a, i) != get_bit(&b, i))
            .count() as u32;
        assert_eq!(xor_popcount(&a, &b), scalar);
    }
}
