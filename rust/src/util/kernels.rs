//! Runtime-dispatched SIMD implementations of the two bitwise primitives
//! that dominate the engine hot path — the host-side analogue of widening
//! the paper's UF-wide XNOR array + popcount tree (§4, Fig. 5).
//!
//! Two primitives, two access patterns:
//!
//! * [`Kernel::xor_popcount`] — whole-row XNOR dot product (FC flatten
//!   dot, `scalar_ref`).  The AVX2 path runs a Harley–Seal carry-save
//!   adder tree over blocks of 16x256-bit XOR'd vectors so the expensive
//!   `vpshufb`-LUT popcount fires once per 16 vectors instead of once per
//!   vector; AVX-512 uses `vpopcntq` directly.
//! * [`Kernel::xor_popcount_lanes`] — the per-tap bank accumulation of
//!   the tap-major conv loop: one activation word broadcast against a
//!   unit-stride bank of filter words, mismatch counts accumulated per
//!   filter lane.  This is vertical (no horizontal reduction), so both
//!   wide paths are a straight broadcast-XOR-popcount-add over 4 (AVX2)
//!   or 8 (AVX-512) lanes per iteration.
//!
//! The kernel is chosen once per [`Kernel`] construction via
//! `is_x86_feature_detected!` (avx512 > avx2 > scalar) and stored as a
//! `Copy` value, so an `Engine` carries its dispatch with it — tests can
//! hold a scalar engine and a SIMD engine side by side in one process.
//! `BCNN_KERNEL=scalar|avx2|avx512` (or `--kernel`) forces a tier, with a
//! typed [`KernelError`] when the requested ISA is unavailable.  The
//! scalar path in [`crate::util::bits`] remains the portable fallback and
//! the bit-exactness oracle.
//!
//! AVX-512 intrinsics are additionally gated on the `bcnn_avx512` cfg
//! emitted by `build.rs` (rustc >= 1.89, where `_mm512_*` stabilised);
//! on older toolchains the avx512 tier reports itself unavailable instead
//! of breaking the build.

use std::fmt;

use crate::util::bits;

/// Environment variable that forces the kernel tier (same values as the
/// CLI `--kernel` flag); empty or `auto` means auto-detect.
pub const KERNEL_ENV: &str = "BCNN_KERNEL";

/// The ISA tier a [`Kernel`] dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Portable `u64` XOR + `count_ones` loop (`util::bits`), always
    /// available; the bit-exactness oracle for the wide paths.
    Scalar,
    /// 256-bit lanes: `vpshufb` nibble-LUT popcount, Harley–Seal CSA
    /// tree for whole rows.
    Avx2,
    /// 512-bit lanes with the `vpopcntq` instruction
    /// (`avx512vpopcntdq`); needs rustc >= 1.89.
    Avx512,
}

impl KernelKind {
    /// All tiers, widest last — iteration order for `repro features`
    /// listings and bench sweeps.
    pub const ALL: [KernelKind; 3] = [KernelKind::Scalar, KernelKind::Avx2, KernelKind::Avx512];

    /// Stable lowercase name, also the `--kernel` / `BCNN_KERNEL` spec.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Avx512 => "avx512",
        }
    }

    /// Parse a `--kernel` / `BCNN_KERNEL` spec (not `auto` — resolve
    /// that via [`Kernel::from_spec`]).
    pub fn parse(spec: &str) -> Result<Self, KernelError> {
        match spec {
            "scalar" => Ok(KernelKind::Scalar),
            "avx2" => Ok(KernelKind::Avx2),
            "avx512" => Ok(KernelKind::Avx512),
            other => Err(KernelError::Unknown(other.to_string())),
        }
    }

    /// Can this tier run here (CPU features and compiler support)?
    pub fn available(self) -> bool {
        self.unavailable_reason().is_none()
    }

    /// `None` when the tier is runnable, otherwise why it is not.
    pub fn unavailable_reason(self) -> Option<&'static str> {
        match self {
            KernelKind::Scalar => None,
            KernelKind::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    if is_x86_feature_detected!("avx2") {
                        None
                    } else {
                        Some("CPU does not report avx2")
                    }
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    Some("avx2 requires an x86_64 host")
                }
            }
            KernelKind::Avx512 => {
                #[cfg(all(target_arch = "x86_64", bcnn_avx512))]
                {
                    if !is_x86_feature_detected!("avx512f") {
                        Some("CPU does not report avx512f")
                    } else if !is_x86_feature_detected!("avx512vpopcntdq") {
                        Some("CPU does not report avx512vpopcntdq")
                    } else {
                        None
                    }
                }
                #[cfg(all(target_arch = "x86_64", not(bcnn_avx512)))]
                {
                    Some("toolchain predates stable AVX-512 intrinsics (rustc < 1.89)")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    Some("avx512 requires an x86_64 host")
                }
            }
        }
    }
}

impl fmt::Display for KernelKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A kernel spec could not be honoured — distinguished from a model
/// error so callers can report "your host can't do that" precisely.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KernelError {
    /// The spec names no known tier.
    Unknown(String),
    /// The tier exists but cannot run on this host/toolchain.
    Unavailable {
        requested: KernelKind,
        reason: &'static str,
    },
}

impl fmt::Display for KernelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KernelError::Unknown(spec) => write!(
                f,
                "unknown kernel {spec:?} (expected scalar, avx2, avx512 or auto)"
            ),
            KernelError::Unavailable { requested, reason } => {
                write!(f, "kernel {requested} unavailable: {reason}")
            }
        }
    }
}

impl std::error::Error for KernelError {}

/// A resolved dispatch decision.  `Copy` by design: every `Engine` owns
/// one, so scalar and SIMD engines coexist in-process for A/B tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Kernel {
    kind: KernelKind,
}

impl Kernel {
    /// The portable fallback / oracle.
    pub fn scalar() -> Self {
        Kernel {
            kind: KernelKind::Scalar,
        }
    }

    /// Widest tier the host can run: avx512 > avx2 > scalar.
    pub fn detect() -> Self {
        for kind in [KernelKind::Avx512, KernelKind::Avx2] {
            if kind.available() {
                return Kernel { kind };
            }
        }
        Kernel::scalar()
    }

    /// Force a specific tier; typed error when the ISA is unavailable.
    pub fn force(kind: KernelKind) -> Result<Self, KernelError> {
        match kind.unavailable_reason() {
            None => Ok(Kernel { kind }),
            Some(reason) => Err(KernelError::Unavailable {
                requested: kind,
                reason,
            }),
        }
    }

    /// Resolve a `--kernel` / `BCNN_KERNEL` spec: absent, empty or
    /// `auto` auto-detects; anything else forces that tier.
    pub fn from_spec(spec: Option<&str>) -> Result<Self, KernelError> {
        match spec {
            None | Some("") | Some("auto") => Ok(Kernel::detect()),
            Some(s) => Kernel::force(KernelKind::parse(s)?),
        }
    }

    /// [`Kernel::from_spec`] on the [`KERNEL_ENV`] environment variable —
    /// the resolution `Engine::new` performs.
    pub fn from_env() -> Result<Self, KernelError> {
        let spec = std::env::var(KERNEL_ENV).ok();
        Kernel::from_spec(spec.as_deref())
    }

    pub fn kind(self) -> KernelKind {
        self.kind
    }

    pub fn name(self) -> &'static str {
        self.kind.name()
    }

    /// Popcount of `a ^ b` over whole rows (mismatch count of the XNOR
    /// dot product).  Lengths must match; the shorter prefix is used in
    /// release builds.
    #[inline]
    pub fn xor_popcount(self, a: &[u64], b: &[u64]) -> u32 {
        debug_assert_eq!(a.len(), b.len(), "xor_popcount row lengths");
        match self.kind {
            KernelKind::Scalar => bits::xor_popcount(a, b),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `force`/`detect` admit Avx2 only when the CPU
            // reports avx2 support.
            KernelKind::Avx2 => unsafe { avx2::xor_popcount(a, b) },
            #[cfg(all(target_arch = "x86_64", bcnn_avx512))]
            // SAFETY: Avx512 is only admitted when avx512f and
            // avx512vpopcntdq are both detected.
            KernelKind::Avx512 => unsafe { avx512::xor_popcount(a, b) },
            #[cfg(not(all(target_arch = "x86_64", bcnn_avx512)))]
            _ => bits::xor_popcount(a, b),
        }
    }

    /// For one activation word `p`, accumulate `popcount(p ^ bank[n])`
    /// into `mism[n]` for every filter lane `n` — the per-tap bank step
    /// of the tap-major conv loop.  Lengths must match; the shorter
    /// prefix is used in release builds.
    #[inline]
    pub fn xor_popcount_lanes(self, p: u64, bank: &[u64], mism: &mut [u64]) {
        debug_assert_eq!(bank.len(), mism.len(), "bank/mismatch lanes");
        match self.kind {
            KernelKind::Scalar => bits::xor_popcount_lanes(p, bank, mism),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: as in `xor_popcount`.
            KernelKind::Avx2 => unsafe { avx2::xor_popcount_lanes(p, bank, mism) },
            #[cfg(all(target_arch = "x86_64", bcnn_avx512))]
            // SAFETY: as in `xor_popcount`.
            KernelKind::Avx512 => unsafe { avx512::xor_popcount_lanes(p, bank, mism) },
            #[cfg(not(all(target_arch = "x86_64", bcnn_avx512)))]
            _ => bits::xor_popcount_lanes(p, bank, mism),
        }
    }
}

impl fmt::Display for Kernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.kind.fmt(f)
    }
}

/// 256-bit paths.  Every function is `#[target_feature(enable = "avx2")]`
/// and must only be reached through [`Kernel`], which guards on runtime
/// detection.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Per-64-bit-lane popcount via Mula's `vpshufb` nibble LUT: table
    /// lookup per nibble gives per-byte counts, then `vpsadbw` against
    /// zero folds the 8 bytes of each 64-bit lane into its low word.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_epi64(v: __m256i) -> __m256i {
        #[rustfmt::skip]
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let nib = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, nib);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), nib);
        let bytes = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(bytes, _mm256_setzero_si256())
    }

    /// Carry-save adder: returns `(carry, sum)` of three bit-vectors —
    /// one level of the Harley–Seal tree, the same full-adder cell the
    /// paper's popcount tree is built from.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn csa(a: __m256i, b: __m256i, c: __m256i) -> (__m256i, __m256i) {
        let u = _mm256_xor_si256(a, b);
        let h = _mm256_or_si256(_mm256_and_si256(a, b), _mm256_and_si256(u, c));
        let l = _mm256_xor_si256(u, c);
        (h, l)
    }

    /// Sum the four 64-bit lanes of `v`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi64(v: __m256i) -> u64 {
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
        lanes[0]
            .wrapping_add(lanes[1])
            .wrapping_add(lanes[2])
            .wrapping_add(lanes[3])
    }

    /// XOR of the 4-word vectors at word offset `j` of two rows.
    /// Unaligned loads: rows are plain `Vec<u64>` slices.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn xor_at(a: *const u64, b: *const u64, j: usize) -> __m256i {
        _mm256_xor_si256(
            _mm256_loadu_si256(a.add(j) as *const __m256i),
            _mm256_loadu_si256(b.add(j) as *const __m256i),
        )
    }

    /// Whole-row XOR popcount: Harley–Seal carry-save tree over blocks
    /// of 16 vectors (64 words), so the LUT popcount runs once per block
    /// on the `sixteens` counter instead of once per vector; then a
    /// plain 4-word vector loop and a scalar word tail.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports avx2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcount(a: &[u64], b: &[u64]) -> u32 {
        let n = a.len().min(b.len());
        let ap = a.as_ptr();
        let bp = b.as_ptr();

        let mut total = _mm256_setzero_si256();
        let mut ones = _mm256_setzero_si256();
        let mut twos = _mm256_setzero_si256();
        let mut fours = _mm256_setzero_si256();
        let mut eights = _mm256_setzero_si256();

        let mut i = 0usize;
        while i + 64 <= n {
            let (twos_a, o1) = csa(ones, xor_at(ap, bp, i), xor_at(ap, bp, i + 4));
            let (twos_b, o2) = csa(o1, xor_at(ap, bp, i + 8), xor_at(ap, bp, i + 12));
            let (fours_a, t1) = csa(twos, twos_a, twos_b);
            let (twos_c, o3) = csa(o2, xor_at(ap, bp, i + 16), xor_at(ap, bp, i + 20));
            let (twos_d, o4) = csa(o3, xor_at(ap, bp, i + 24), xor_at(ap, bp, i + 28));
            let (fours_b, t2) = csa(t1, twos_c, twos_d);
            let (eights_a, f1) = csa(fours, fours_a, fours_b);
            let (twos_e, o5) = csa(o4, xor_at(ap, bp, i + 32), xor_at(ap, bp, i + 36));
            let (twos_f, o6) = csa(o5, xor_at(ap, bp, i + 40), xor_at(ap, bp, i + 44));
            let (fours_c, t3) = csa(t2, twos_e, twos_f);
            let (twos_g, o7) = csa(o6, xor_at(ap, bp, i + 48), xor_at(ap, bp, i + 52));
            let (twos_h, o8) = csa(o7, xor_at(ap, bp, i + 56), xor_at(ap, bp, i + 60));
            let (fours_d, t4) = csa(t3, twos_g, twos_h);
            let (eights_b, f2) = csa(f1, fours_c, fours_d);
            let (sixteens, e) = csa(eights, eights_a, eights_b);
            ones = o8;
            twos = t4;
            fours = f2;
            eights = e;
            total = _mm256_add_epi64(total, popcnt_epi64(sixteens));
            i += 64;
        }

        let mut count = hsum_epi64(total) * 16
            + hsum_epi64(popcnt_epi64(eights)) * 8
            + hsum_epi64(popcnt_epi64(fours)) * 4
            + hsum_epi64(popcnt_epi64(twos)) * 2
            + hsum_epi64(popcnt_epi64(ones));
        while i + 4 <= n {
            count += hsum_epi64(popcnt_epi64(xor_at(ap, bp, i)));
            i += 4;
        }
        while i < n {
            count += (a[i] ^ b[i]).count_ones() as u64;
            i += 1;
        }
        count as u32
    }

    /// Broadcast `p` against the bank, 4 filter lanes per iteration,
    /// accumulating 64-bit mismatch counters in place.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports avx2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcount_lanes(p: u64, bank: &[u64], mism: &mut [u64]) {
        let n = bank.len().min(mism.len());
        let pv = _mm256_set1_epi64x(p as i64);
        let bp = bank.as_ptr();
        let mp = mism.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let w = _mm256_loadu_si256(bp.add(i) as *const __m256i);
            let m = _mm256_loadu_si256(mp.add(i) as *const __m256i);
            let c = popcnt_epi64(_mm256_xor_si256(w, pv));
            _mm256_storeu_si256(mp.add(i) as *mut __m256i, _mm256_add_epi64(m, c));
            i += 4;
        }
        while i < n {
            *mism.get_unchecked_mut(i) += (p ^ *bank.get_unchecked(i)).count_ones() as u64;
            i += 1;
        }
    }
}

/// 512-bit paths using the native `vpopcntq` instruction; gated on the
/// `bcnn_avx512` cfg from `build.rs` (rustc >= 1.89) on top of runtime
/// detection of avx512f + avx512vpopcntdq.
#[cfg(all(target_arch = "x86_64", bcnn_avx512))]
mod avx512 {
    use std::arch::x86_64::*;

    /// Whole-row XOR popcount, 8 words per iteration.  `vpopcntq` does
    /// the counting directly, so no CSA tree is needed: the pipeline is
    /// load-load-xor-popcnt-add.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports avx512f and avx512vpopcntdq.
    #[target_feature(enable = "avx512f,avx512vpopcntdq")]
    pub unsafe fn xor_popcount(a: &[u64], b: &[u64]) -> u32 {
        let n = a.len().min(b.len());
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc = _mm512_setzero_si512();
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm512_xor_si512(
                _mm512_loadu_si512(ap.add(i) as *const _),
                _mm512_loadu_si512(bp.add(i) as *const _),
            );
            acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(x));
            i += 8;
        }
        let mut count = _mm512_reduce_add_epi64(acc) as u64;
        while i < n {
            count += (a[i] ^ b[i]).count_ones() as u64;
            i += 1;
        }
        count as u32
    }

    /// Broadcast `p` against the bank, 8 filter lanes per iteration.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports avx512f and avx512vpopcntdq.
    #[target_feature(enable = "avx512f,avx512vpopcntdq")]
    pub unsafe fn xor_popcount_lanes(p: u64, bank: &[u64], mism: &mut [u64]) {
        let n = bank.len().min(mism.len());
        let pv = _mm512_set1_epi64(p as i64);
        let bp = bank.as_ptr();
        let mp = mism.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let w = _mm512_loadu_si512(bp.add(i) as *const _);
            let m = _mm512_loadu_si512(mp.add(i) as *const _);
            let c = _mm512_popcnt_epi64(_mm512_xor_si512(w, pv));
            _mm512_storeu_si512(mp.add(i) as *mut _, _mm512_add_epi64(m, c));
            i += 8;
        }
        while i < n {
            *mism.get_unchecked_mut(i) += (p ^ *bank.get_unchecked(i)).count_ones() as u64;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn available_kernels() -> Vec<Kernel> {
        KernelKind::ALL
            .iter()
            .filter(|k| k.available())
            .map(|&k| Kernel::force(k).expect("available tier must force"))
            .collect()
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for kind in KernelKind::ALL {
            assert_eq!(KernelKind::parse(kind.name()), Ok(kind));
            assert_eq!(kind.to_string(), kind.name());
        }
        match KernelKind::parse("sse9") {
            Err(KernelError::Unknown(s)) => assert_eq!(s, "sse9"),
            other => panic!("expected Unknown, got {other:?}"),
        }
    }

    #[test]
    fn from_spec_auto_and_force() {
        assert_eq!(Kernel::from_spec(None).unwrap(), Kernel::detect());
        assert_eq!(Kernel::from_spec(Some("")).unwrap(), Kernel::detect());
        assert_eq!(Kernel::from_spec(Some("auto")).unwrap(), Kernel::detect());
        assert_eq!(
            Kernel::from_spec(Some("scalar")).unwrap().kind(),
            KernelKind::Scalar
        );
        assert!(matches!(
            Kernel::from_spec(Some("mmx")),
            Err(KernelError::Unknown(_))
        ));
    }

    #[test]
    fn force_unavailable_is_typed() {
        for kind in KernelKind::ALL {
            match (kind.unavailable_reason(), Kernel::force(kind)) {
                (None, Ok(k)) => assert_eq!(k.kind(), kind),
                (Some(reason), Err(KernelError::Unavailable { requested, reason: r })) => {
                    assert_eq!(requested, kind);
                    assert_eq!(r, reason);
                }
                (avail, got) => panic!("inconsistent force for {kind}: {avail:?} vs {got:?}"),
            }
        }
    }

    #[test]
    fn detect_picks_an_available_kernel() {
        let k = Kernel::detect();
        assert!(k.kind().available());
        // scalar is always a valid floor
        assert!(KernelKind::Scalar.available());
    }

    #[test]
    fn xor_popcount_bit_exact_vs_scalar_across_widths() {
        let mut rng = SplitMix64::new(0xC0FFEE);
        let kernels = available_kernels();
        // widths straddling every path boundary: scalar tail only,
        // 4-word vector loop, and multiple 64-word Harley–Seal blocks
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 63, 64, 65, 100, 127, 128, 129, 200] {
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let want = bits::xor_popcount(&a, &b);
            for k in &kernels {
                assert_eq!(k.xor_popcount(&a, &b), want, "kernel {k} width {n}");
            }
        }
    }

    #[test]
    fn xor_popcount_extremes() {
        let kernels = available_kernels();
        for n in [64usize, 65, 130] {
            let zeros = vec![0u64; n];
            let ones = vec![u64::MAX; n];
            for k in &kernels {
                assert_eq!(k.xor_popcount(&zeros, &ones), (n * 64) as u32, "kernel {k}");
                assert_eq!(k.xor_popcount(&ones, &ones), 0, "kernel {k}");
            }
        }
    }

    #[test]
    fn xor_popcount_lanes_bit_exact_vs_scalar_across_widths() {
        let mut rng = SplitMix64::new(0xBEEF);
        let kernels = available_kernels();
        // lane counts off the 4- and 8-lane lattice, incl. below one chunk
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 11, 16, 33, 40, 100, 130] {
            let p = rng.next_u64();
            let bank: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let start: Vec<u64> = (0..n).map(|_| rng.below(1000)).collect();
            let mut want = start.clone();
            bits::xor_popcount_lanes(p, &bank, &mut want);
            for k in &kernels {
                let mut got = start.clone();
                k.xor_popcount_lanes(p, &bank, &mut got);
                assert_eq!(got, want, "kernel {k} lanes {n}");
            }
        }
    }

    #[test]
    fn xor_popcount_lanes_accumulates_repeatedly() {
        // the conv loop calls this 9x per pixel per word — accumulation
        // across calls must compose for every tier
        let mut rng = SplitMix64::new(0xACC);
        let kernels = available_kernels();
        let bank: Vec<u64> = (0..13).map(|_| rng.next_u64()).collect();
        let taps: Vec<u64> = (0..9).map(|_| rng.next_u64()).collect();
        let mut want = vec![0u64; 13];
        for &p in &taps {
            bits::xor_popcount_lanes(p, &bank, &mut want);
        }
        for k in &kernels {
            let mut got = vec![0u64; 13];
            for &p in &taps {
                k.xor_popcount_lanes(p, &bank, &mut got);
            }
            assert_eq!(got, want, "kernel {k}");
        }
    }
}
