//! SplitMix64 — the deterministic PRNG used by workload generators and the
//! property-test harness (the crate cache has no `rand`).
//!
//! Reference: Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
//! Generators", OOPSLA 2014.  Passes BigCrush when used as a stream.

/// Seeded, deterministic 64-bit generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`; `n` must be non-zero.  Uses the unbiased
    /// multiply-shift reduction (Lemire 2019) with rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times for the
    /// open-loop workload generator).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Random bit.
    #[inline]
    pub fn bit(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(1);
        for n in [1u64, 2, 3, 7, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = SplitMix64::new(2);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..1000 {
            let v = r.range_i64(-1, 1);
            assert!((-1..=1).contains(&v));
            saw_lo |= v == -1;
            saw_hi |= v == 1;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(3);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(4);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = SplitMix64::new(5);
        let lambda = 4.0;
        let mean = (0..20_000).map(|_| r.exp(lambda)).sum::<f64>() / 20_000.0;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }
}
