//! Small self-contained substrates the offline environment forces us to
//! own: a seeded PRNG (no `rand`), a minimal JSON reader (no `serde_json`),
//! bit-string copy helpers shared by the engine and the model loader, the
//! runtime-dispatched SIMD kernels behind the bitwise hot path, and the
//! deterministic fault-injection + poison-tolerant-locking substrate the
//! supervision layer is built on.

pub mod bits;
pub mod faults;
pub mod json;
pub mod kernels;
pub mod prng;
pub mod sync;

pub use faults::{FaultAction, FaultPlan, FaultRule, Trigger, FAULTS_ENV};
pub use kernels::{Kernel, KernelError, KernelKind};
pub use prng::SplitMix64;
pub use sync::{lock_recover, panic_message, read_recover, write_recover};
