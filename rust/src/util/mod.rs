//! Small self-contained substrates the offline environment forces us to
//! own: a seeded PRNG (no `rand`), a minimal JSON reader (no `serde_json`),
//! bit-string copy helpers shared by the engine and the model loader, and
//! the runtime-dispatched SIMD kernels behind the bitwise hot path.

pub mod bits;
pub mod json;
pub mod kernels;
pub mod prng;

pub use kernels::{Kernel, KernelError, KernelKind};
pub use prng::SplitMix64;
