//! Small self-contained substrates the offline environment forces us to
//! own: a seeded PRNG (no `rand`), a minimal JSON reader (no `serde_json`),
//! and bit-string copy helpers shared by the engine and the model loader.

pub mod bits;
pub mod json;
pub mod prng;

pub use prng::SplitMix64;
