//! Deterministic fault injection — the testing backbone behind the
//! supervision layer (DESIGN.md §6).
//!
//! A [`FaultPlan`] names *sites* in the serving stack (backend infer,
//! stage emission, submit, server read/write) and attaches *actions*
//! (panic, delay, deny) fired by deterministic *triggers*.  Each site
//! keeps a global hit counter; whether hit `k` at site `s` fires is a
//! pure function of `(seed, s, k)` via [`SplitMix64`], so the fault
//! schedule is reproducible regardless of thread interleaving (which
//! request absorbs hit `k` varies; how many faults fire over N hits does
//! not).
//!
//! Configured via the `BCNN_FAULTS` env var or `--faults` (spec grammar
//! below); compiled to a single relaxed atomic load when unset, so the
//! hot paths pay nothing in production.
//!
//! Spec grammar (`;`-separated clauses):
//!
//! ```text
//! seed=1337;backend_infer:panic@every=150;stage_emit:delay=1ms@p=0.02;submit:deny@once=7
//! ```
//!
//! * site    — one of [`SITES`]
//! * action  — `panic` | `delay=<N>{us|ms|s}` | `deny`
//! * trigger — `p=<f64>` | `once=<k>` | `every=<k>` | `first=<k>`
//!   (default `p=1`, i.e. every hit)

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::util::prng::SplitMix64;

/// Environment variable holding the fault-plan spec.
pub const FAULTS_ENV: &str = "BCNN_FAULTS";

/// The named injection sites wired into the serving stack.
pub const SITES: &[&str] = [
    SITE_BACKEND_INFER,
    SITE_STAGE_EMIT,
    SITE_SUBMIT,
    SITE_SERVER_READ,
    SITE_SERVER_WRITE,
]
.as_slice();

/// Around `Backend::infer_batch` on the shard worker (panic = worker crash).
pub const SITE_BACKEND_INFER: &str = "backend_infer";
/// Per row emission inside a pipeline stage lane (panic = stage death).
pub const SITE_STAGE_EMIT: &str = "stage_emit";
/// At `Client::submit` (deny = synthetic queue-full storm).
pub const SITE_SUBMIT: &str = "submit";
/// After a TCP request frame is parsed (deny = shed the request).
pub const SITE_SERVER_READ: &str = "server_read";
/// Before a TCP reply frame is written (deny = error frame instead).
pub const SITE_SERVER_WRITE: &str = "server_write";

/// What a firing rule does to the caller.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// Unwind the calling thread (contained by the supervision layer).
    Panic,
    /// Sleep for the given duration (latency storm).
    Delay(Duration),
    /// Report "deny" to the call site (queue-full / shed semantics).
    Deny,
}

/// When a rule fires, as a pure function of the site hit counter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Independently with probability `p` per hit (seeded, deterministic).
    Prob(f64),
    /// Exactly on hit `k` (1-based).
    Once(u64),
    /// On hits `k, 2k, 3k, ...`.
    Every(u64),
    /// On every hit `<= k`.
    First(u64),
}

/// One `site:action@trigger` clause.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRule {
    pub site: &'static str,
    pub action: FaultAction,
    pub trigger: Trigger,
}

/// A parsed, validated fault plan.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse a spec string (grammar in the module docs).  Empty specs give
    /// an empty plan (no rules, never fires).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan { seed: 0, rules: Vec::new() };
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(seed) = clause.strip_prefix("seed=") {
                plan.seed = seed.parse().map_err(|_| anyhow!("bad seed {seed:?}"))?;
                continue;
            }
            let (site_name, rest) = clause
                .split_once(':')
                .ok_or_else(|| anyhow!("clause {clause:?} is not site:action[@trigger]"))?;
            let site = SITES
                .iter()
                .copied()
                .find(|s| *s == site_name)
                .ok_or_else(|| anyhow!("unknown fault site {site_name:?} (valid: {SITES:?})"))?;
            let (action_str, trigger_str) = match rest.split_once('@') {
                Some((a, t)) => (a, Some(t)),
                None => (rest, None),
            };
            let action = parse_action(action_str)?;
            let trigger = match trigger_str {
                None => Trigger::Prob(1.0),
                Some(t) => parse_trigger(t)?,
            };
            plan.rules.push(FaultRule { site, action, trigger });
        }
        Ok(plan)
    }

    /// True when the plan can never fire.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

fn parse_action(s: &str) -> Result<FaultAction> {
    if s == "panic" {
        return Ok(FaultAction::Panic);
    }
    if s == "deny" {
        return Ok(FaultAction::Deny);
    }
    if let Some(d) = s.strip_prefix("delay=") {
        return Ok(FaultAction::Delay(parse_duration(d)?));
    }
    bail!("unknown fault action {s:?} (panic | delay=<dur> | deny)")
}

fn parse_duration(s: &str) -> Result<Duration> {
    let (num, scale_us) = if let Some(n) = s.strip_suffix("us") {
        (n, 1u64)
    } else if let Some(n) = s.strip_suffix("ms") {
        (n, 1_000)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1_000_000)
    } else {
        bail!("duration {s:?} needs a us/ms/s suffix")
    };
    let v: u64 = num.parse().map_err(|_| anyhow!("bad duration {s:?}"))?;
    Ok(Duration::from_micros(v * scale_us))
}

fn parse_trigger(s: &str) -> Result<Trigger> {
    if let Some(p) = s.strip_prefix("p=") {
        let p: f64 = p.parse().map_err(|_| anyhow!("bad probability {p:?}"))?;
        if !(0.0..=1.0).contains(&p) {
            bail!("probability {p} out of [0,1]");
        }
        return Ok(Trigger::Prob(p));
    }
    for (prefix, make) in [
        ("once=", Trigger::Once as fn(u64) -> Trigger),
        ("every=", Trigger::Every as fn(u64) -> Trigger),
        ("first=", Trigger::First as fn(u64) -> Trigger),
    ] {
        if let Some(k) = s.strip_prefix(prefix) {
            let k: u64 = k.parse().map_err(|_| anyhow!("bad trigger count {k:?}"))?;
            if k == 0 {
                bail!("trigger count must be >= 1 in {s:?}");
            }
            return Ok(make(k));
        }
    }
    bail!("unknown trigger {s:?} (p=<f> | once=<k> | every=<k> | first=<k>)")
}

// ---------------------------------------------------------------------------
// global armed state
// ---------------------------------------------------------------------------

const MODE_UNINIT: u8 = 0;
const MODE_OFF: u8 = 1;
const MODE_ON: u8 = 2;

/// Fast-path gate: a single relaxed load decides "faults off" without
/// touching the `RwLock`.
static MODE: AtomicU8 = AtomicU8::new(MODE_UNINIT);

struct Armed {
    plan: FaultPlan,
    /// One monotone hit counter per entry of [`SITES`].
    hits: Vec<AtomicU64>,
    /// Fired count per rule (observability for soak asserts).
    fired: Vec<AtomicU64>,
}

fn armed_slot() -> &'static RwLock<Option<Arc<Armed>>> {
    static SLOT: OnceLock<RwLock<Option<Arc<Armed>>>> = OnceLock::new();
    SLOT.get_or_init(|| RwLock::new(None))
}

/// Arm `plan` process-wide (tests, `--faults`).  An empty plan disarms.
pub fn install(plan: FaultPlan) {
    let armed = if plan.is_empty() {
        None
    } else {
        Some(Arc::new(Armed {
            hits: SITES.iter().map(|_| AtomicU64::new(0)).collect(),
            fired: plan.rules.iter().map(|_| AtomicU64::new(0)).collect(),
            plan,
        }))
    };
    let mode = if armed.is_some() { MODE_ON } else { MODE_OFF };
    let mut slot = armed_slot().write().unwrap_or_else(std::sync::PoisonError::into_inner);
    *slot = armed;
    MODE.store(mode, Ordering::Release);
}

/// Disarm all faults (tests call this between cases).
pub fn clear() {
    install(FaultPlan::default());
}

/// True when a non-empty plan is armed.
pub fn active() -> bool {
    maybe_init();
    MODE.load(Ordering::Acquire) == MODE_ON
}

/// First-use initialisation from `BCNN_FAULTS` (a parse error disarms and
/// warns rather than panicking inside an arbitrary serving thread).
fn maybe_init() {
    if MODE.load(Ordering::Acquire) != MODE_UNINIT {
        return;
    }
    let plan = match std::env::var(FAULTS_ENV) {
        Ok(spec) if !spec.trim().is_empty() => match FaultPlan::parse(&spec) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("warning: ignoring unparsable {FAULTS_ENV}={spec:?}: {e}");
                FaultPlan::default()
            }
        },
        _ => FaultPlan::default(),
    };
    install(plan);
}

/// Per-rule fired counts as `(site:action, count)` (empty when disarmed).
pub fn fired_counts() -> Vec<(String, u64)> {
    maybe_init();
    let slot = armed_slot().read().unwrap_or_else(std::sync::PoisonError::into_inner);
    let Some(armed) = slot.as_ref() else {
        return Vec::new();
    };
    armed
        .plan
        .rules
        .iter()
        .zip(&armed.fired)
        .map(|(r, f)| {
            let label = match r.action {
                FaultAction::Panic => format!("{}:panic", r.site),
                FaultAction::Delay(d) => format!("{}:delay={}us", r.site, d.as_micros()),
                FaultAction::Deny => format!("{}:deny", r.site),
            };
            (label, f.load(Ordering::Relaxed))
        })
        .collect()
}

/// Evaluate the armed plan at `site`.  Delays are slept here, panics
/// unwind from here (the supervision layer contains them), and `true`
/// means a `deny` rule fired.  A single relaxed atomic load when no plan
/// is armed.
pub fn fire(site: &'static str) -> bool {
    match MODE.load(Ordering::Acquire) {
        MODE_OFF => return false,
        MODE_UNINIT => {
            maybe_init();
            if MODE.load(Ordering::Acquire) != MODE_ON {
                return false;
            }
        }
        _ => {}
    }
    let armed = {
        let slot = armed_slot().read().unwrap_or_else(std::sync::PoisonError::into_inner);
        match slot.as_ref() {
            Some(a) => Arc::clone(a),
            None => return false,
        }
    };
    let Some(site_idx) = SITES.iter().position(|s| *s == site) else {
        return false;
    };
    // 1-based hit index: `once=1` means the very first hit
    let hit = armed.hits[site_idx].fetch_add(1, Ordering::Relaxed) + 1;
    let mut deny = false;
    for (rule_idx, rule) in armed.plan.rules.iter().enumerate() {
        if rule.site != site || !decide(armed.plan.seed, site_idx, hit, rule.trigger) {
            continue;
        }
        armed.fired[rule_idx].fetch_add(1, Ordering::Relaxed);
        match rule.action {
            FaultAction::Delay(d) => std::thread::sleep(d),
            FaultAction::Deny => deny = true,
            FaultAction::Panic => {
                panic!("injected fault: panic at {site} (hit {hit})")
            }
        }
    }
    deny
}

/// Pure per-hit decision: `(seed, site, hit)` fully determine the outcome.
fn decide(seed: u64, site_idx: usize, hit: u64, trigger: Trigger) -> bool {
    match trigger {
        Trigger::Once(k) => hit == k,
        Trigger::Every(k) => hit % k == 0,
        Trigger::First(k) => hit <= k,
        Trigger::Prob(p) => {
            if p >= 1.0 {
                return true;
            }
            if p <= 0.0 {
                return false;
            }
            let mut r = SplitMix64::new(
                seed ^ (site_idx as u64).wrapping_mul(0xA076_1D64_78BD_642F) ^ hit,
            );
            r.f64() < p
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let plan = FaultPlan::parse(
            "seed=42;backend_infer:panic@every=10;stage_emit:delay=2ms@p=0.5;\
             submit:deny@once=3;server_read:delay=50us@first=2;server_write:deny",
        )
        .unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.rules.len(), 5);
        assert_eq!(plan.rules[0].action, FaultAction::Panic);
        assert_eq!(plan.rules[0].trigger, Trigger::Every(10));
        assert_eq!(plan.rules[1].action, FaultAction::Delay(Duration::from_millis(2)));
        assert_eq!(plan.rules[2].trigger, Trigger::Once(3));
        assert_eq!(plan.rules[3].trigger, Trigger::First(2));
        assert_eq!(plan.rules[4].trigger, Trigger::Prob(1.0));
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "nosuchsite:panic",
            "backend_infer:explode",
            "backend_infer:delay=5",
            "backend_infer:panic@p=2.0",
            "backend_infer:panic@every=0",
            "seed=abc",
            "backend_infer",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn empty_spec_is_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ; ").unwrap().is_empty());
    }

    #[test]
    fn decisions_are_deterministic() {
        let fires: Vec<bool> =
            (1..=1000).map(|hit| decide(7, 0, hit, Trigger::Prob(0.1))).collect();
        let again: Vec<bool> =
            (1..=1000).map(|hit| decide(7, 0, hit, Trigger::Prob(0.1))).collect();
        assert_eq!(fires, again);
        let count = fires.iter().filter(|f| **f).count();
        assert!((50..200).contains(&count), "p=0.1 fired {count}/1000");
        // different seed, different schedule
        let other: Vec<bool> =
            (1..=1000).map(|hit| decide(8, 0, hit, Trigger::Prob(0.1))).collect();
        assert_ne!(fires, other);
    }

    #[test]
    fn counter_triggers() {
        assert!(decide(0, 0, 5, Trigger::Once(5)));
        assert!(!decide(0, 0, 6, Trigger::Once(5)));
        assert!(decide(0, 0, 10, Trigger::Every(5)));
        assert!(!decide(0, 0, 11, Trigger::Every(5)));
        assert!(decide(0, 0, 2, Trigger::First(2)));
        assert!(!decide(0, 0, 3, Trigger::First(2)));
    }
}
