//! Minimal JSON reader/writer (the offline crate cache has no serde).
//!
//! Supports the full JSON value grammar minus exotic number forms; enough
//! for the AOT manifests (`artifacts/*.json`) and for emitting metrics.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {self:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Serialize (stable key order: BTreeMap).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: re-decode from the byte stream
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    let end = start + width;
                    if end > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected , or ] got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected , or }} got {:?} at {}", c as char, self.pos),
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"config": "tiny", "batch": 1,
            "params": [{"name": "w1", "dtype": "s32", "shape": [32, 27]}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("config").unwrap().as_str().unwrap(), "tiny");
        let params = v.get("params").unwrap().as_arr().unwrap();
        assert_eq!(params[0].get("name").unwrap().as_str().unwrap(), "w1");
        let shape: Vec<usize> = params[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![32, 27]);
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,-3],"b":"x\ny","c":null,"d":true,"e":{}}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ✓");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }
}
