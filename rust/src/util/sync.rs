//! Poison-tolerant locking helpers.
//!
//! A thread that panics while holding a `Mutex`/`RwLock` poisons it; the
//! default `.lock().unwrap()` then propagates that panic into every other
//! thread touching the lock — one crash takes a whole pool down.  The
//! supervision layer (DESIGN.md §6) contains panics instead, so lock
//! poisoning downgrades to "the protected data may be mid-update": for
//! our uses (metrics counters, routing tables, reply queues) the values
//! are always individually valid, so recovering the guard is safe.

use std::any::Any;
use std::sync::{Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// `lock()` that survives poisoning (recovers the inner guard).
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `read()` that survives poisoning.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// `write()` that survives poisoning.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// Best-effort human-readable payload from `catch_unwind`.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "expected the lock to be poisoned");
        assert_eq!(*lock_recover(&m), 7);
    }

    #[test]
    fn recovers_poisoned_rwlock() {
        let l = Arc::new(RwLock::new(3u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert_eq!(*read_recover(&l), 3);
        *write_recover(&l) = 4;
        assert_eq!(*read_recover(&l), 4);
    }

    #[test]
    fn panic_payload_extraction() {
        let p = std::panic::catch_unwind(|| panic!("literal")).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "literal");
        let p = std::panic::catch_unwind(|| panic!("formatted {}", 1)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "formatted 1");
    }
}
