//! Analytic Titan X GPU model — the Fig. 7 comparator.
//!
//! The paper benchmarks the BCNN on a Titan X with two CUDA kernels: the
//! floating-point *baseline* and the bit-packed *XNOR kernel* of Ref. 9
//! (32 1-bit lanes per 32-bit word; each fully-pipelined CUDA core retires
//! 32 bitwise ops/cycle, §2.4).  No physical GPU exists in this
//! environment, so Fig. 7's GPU series comes from a first-order
//! latency-hiding model:
//!
//! * `FPS(batch) = FPS_peak * U(batch)`, with the occupancy/utilization
//!   curve `U(b) = b / (b + b_half)` — the standard latency-hiding
//!   saturation form (utilization grows with thread-level parallelism
//!   until functional-unit latency is hidden);
//! * `FPS_peak` from device arithmetic: 3072 cores x 32 bit-ops/cycle
//!   x 1 GHz for the XNOR kernel, derated by a measured-efficiency factor
//!   (XNOR kernels are memory/layout bound well below arithmetic peak);
//! * board power during kernel execution (CAL) from the paper's two
//!   energy-efficiency ratios, which pin it at ~76 W for this workload —
//!   far under TDP, consistent with a memory-bound binary kernel.
//!
//! CAL constants reproduce the paper's anchor points: XNOR kernel at
//! batch 512 on par with the FPGA's 6218 FPS, 8.3x slower at batch 16,
//! and the 7x XNOR-over-baseline speedup reported in Ref. 9.

use crate::model::NetConfig;

/// Titan X (Maxwell) device arithmetic.
pub const CUDA_CORES: f64 = 3072.0;
pub const GPU_CLOCK_HZ: f64 = 1.0e9;
/// Bitwise lanes per core per cycle with the 32-bit packed XNOR kernel.
pub const BIT_LANES: f64 = 32.0;
/// fp32 FMA throughput (2 flops/core/cycle).
pub const FP32_FLOPS: f64 = CUDA_CORES * 2.0 * GPU_CLOCK_HZ;

// --- CAL constants (calibrated against the paper's reported ratios) -----
/// Achieved fraction of bit-op peak for the XNOR kernel (memory-bound;
/// yields ~8.1 kFPS asymptotic on the Table-2 net, putting batch-512
/// throughput on par with the FPGA as Fig. 7 reports).
pub const XNOR_EFFICIENCY: f64 = 0.051;
/// Achieved fraction of fp32 peak for the baseline kernel, set so the
/// XNOR kernel's asymptotic speedup over baseline is the 7x of Ref. 9.
pub const BASELINE_EFFICIENCY: f64 = 0.232;
/// Latency-hiding half-saturation batch size (batch at which utilization
/// reaches 50%); from the paper's 8.3x @16 vs parity @512 anchors.
pub const B_HALF: f64 = 158.0;
/// Board power during XNOR-kernel execution, W (CAL: pinned by the
/// paper's 75x @16 and 9.5x @512 energy-efficiency ratios).
pub const XNOR_POWER_W: f64 = 76.0;
/// Board power during fp32 baseline execution, W (higher ALU activity).
pub const BASELINE_POWER_W: f64 = 150.0;

/// Which CUDA kernel the model evaluates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuKernel {
    /// fp32 cuDNN-style baseline.
    Baseline,
    /// Bit-packed XNOR kernel of Ref. 9.
    Xnor,
}

/// Analytic Titan X model for a given network.
#[derive(Debug, Clone)]
pub struct GpuModel {
    /// Total MAC-equivalent ops per image (x2 convention, like the FPGA
    /// side's GOPS accounting).
    pub ops_per_image: f64,
    pub b_half: f64,
}

impl GpuModel {
    pub fn new(config: &NetConfig) -> Self {
        Self { ops_per_image: config.ops_per_image() as f64, b_half: B_HALF }
    }

    /// Asymptotic (fully latency-hidden) throughput of a kernel.
    pub fn peak_fps(&self, kernel: GpuKernel) -> f64 {
        match kernel {
            GpuKernel::Xnor => {
                let bitops_per_s = CUDA_CORES * BIT_LANES * GPU_CLOCK_HZ * 2.0;
                XNOR_EFFICIENCY * bitops_per_s / self.ops_per_image
            }
            GpuKernel::Baseline => BASELINE_EFFICIENCY * FP32_FLOPS / self.ops_per_image,
        }
    }

    /// Utilization at a batch size (latency-hiding saturation curve).
    pub fn utilization(&self, batch: usize) -> f64 {
        let b = batch as f64;
        b / (b + self.b_half)
    }

    /// Throughput at a batch size.
    pub fn fps(&self, kernel: GpuKernel, batch: usize) -> f64 {
        self.peak_fps(kernel) * self.utilization(batch)
    }

    /// Board power during execution.
    pub fn power_w(&self, kernel: GpuKernel) -> f64 {
        match kernel {
            GpuKernel::Xnor => XNOR_POWER_W,
            GpuKernel::Baseline => BASELINE_POWER_W,
        }
    }

    /// Energy efficiency in FPS/W at a batch size.
    pub fn fps_per_w(&self, kernel: GpuKernel, batch: usize) -> f64 {
        self.fps(kernel, batch) / self.power_w(kernel)
    }

    /// GOPS at a batch size (Table-5-style accounting).
    pub fn gops(&self, kernel: GpuKernel, batch: usize) -> f64 {
        self.fps(kernel, batch) * self.ops_per_image / 1e9
    }

    /// Mean per-request latency at a batch size (batch must fill first:
    /// the whole batch completes together — this is what makes small-batch
    /// online serving GPU-unfriendly).
    pub fn batch_latency_s(&self, kernel: GpuKernel, batch: usize) -> f64 {
        batch as f64 / self.fps(kernel, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> GpuModel {
        GpuModel::new(&NetConfig::table2())
    }

    const FPGA_FPS: f64 = 6218.0;
    const FPGA_POWER: f64 = 8.2;

    #[test]
    fn fig7_throughput_anchor_batch16() {
        // paper: FPGA 8.3x faster than GPU XNOR kernel at batch 16
        let ratio = FPGA_FPS / model().fps(GpuKernel::Xnor, 16);
        assert!((ratio - 8.3).abs() / 8.3 < 0.15, "ratio {ratio}");
    }

    #[test]
    fn fig7_throughput_anchor_batch512() {
        // paper: on a par at batch 512 (say within 10%)
        let ratio = FPGA_FPS / model().fps(GpuKernel::Xnor, 512);
        assert!((ratio - 1.0).abs() < 0.10, "ratio {ratio}");
    }

    #[test]
    fn fig7_energy_anchor_batch16() {
        // paper: 75x better energy efficiency at batch 16
        let fpga = FPGA_FPS / FPGA_POWER;
        let gpu = model().fps_per_w(GpuKernel::Xnor, 16);
        let ratio = fpga / gpu;
        assert!((ratio - 75.0).abs() / 75.0 < 0.15, "ratio {ratio}");
    }

    #[test]
    fn fig7_energy_anchor_batch512() {
        // paper: 9.5x better energy efficiency at batch 512
        let fpga = FPGA_FPS / FPGA_POWER;
        let gpu = model().fps_per_w(GpuKernel::Xnor, 512);
        let ratio = fpga / gpu;
        assert!((ratio - 9.5).abs() / 9.5 < 0.15, "ratio {ratio}");
    }

    #[test]
    fn xnor_speedup_over_baseline_is_ref9_7x() {
        let m = model();
        let speedup = m.peak_fps(GpuKernel::Xnor) / m.peak_fps(GpuKernel::Baseline);
        assert!((speedup - 7.0).abs() < 0.8, "speedup {speedup}");
    }

    #[test]
    fn utilization_monotone_saturating() {
        let m = model();
        let mut prev = 0.0;
        for b in [1usize, 4, 16, 64, 256, 1024, 8192] {
            let u = m.utilization(b);
            assert!(u > prev && u < 1.0);
            prev = u;
        }
        assert!(m.utilization(100_000) > 0.99);
    }

    #[test]
    fn batch_latency_grows_with_batch() {
        let m = model();
        assert!(
            m.batch_latency_s(GpuKernel::Xnor, 512) > m.batch_latency_s(GpuKernel::Xnor, 16)
        );
    }
}
