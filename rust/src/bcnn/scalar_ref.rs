//! Textbook ±1 reference implementation (paper eq. 1/3, pre-reformulation).
//!
//! Deliberately slow and obvious: unpacked `i32` ±1 arrays, nested-loop
//! convolution, explicit batch-norm-free threshold semantics.  The test
//! suite runs this against [`crate::bcnn::Engine`] to validate every bit
//! trick (packing, XNOR+popcount, -1 padding, FC flattening) end to end.

use anyhow::{bail, Result};

use crate::model::{BcnnModel, LayerWeights};
use crate::util::bits::get_bit;

/// ±1 value of a packed weight bit (1 -> +1, 0 -> -1; paper §3.1 encoding).
fn pm1(words: &[u64], idx: usize) -> i32 {
    if get_bit(words, idx) {
        1
    } else {
        -1
    }
}

/// Classify one image with the unpacked reference semantics.
pub fn infer_reference(model: &BcnnModel, image: &[i32]) -> Result<Vec<f32>> {
    let hw = model.input_hw;
    let c = model.input_channels;
    if image.len() != hw * hw * c {
        bail!("image size mismatch");
    }
    // activations carried as ±1 i32 (or raw ints before the first layer)
    enum Act {
        Int(Vec<i32>, usize, usize),  // data, hw, c
        Pm1(Vec<i32>, usize, usize),
    }
    let mut act = Act::Int(image.to_vec(), hw, c);

    for layer in &model.layers {
        act = match layer {
            LayerWeights::FpConv { in_c, out_c, pool, weights, thresholds } => {
                let Act::Int(data, hw, c) = &act else { bail!("FpConv wants ints") };
                assert_eq!(c, in_c);
                // true zero padding for the integer first layer
                let y = conv3x3(
                    *hw,
                    *in_c,
                    *out_c,
                    |sy, sx, ch| {
                        if sy < 0 || sx < 0 || sy >= *hw as isize || sx >= *hw as isize {
                            0
                        } else {
                            data[(sy as usize * hw + sx as usize) * in_c + ch]
                        }
                    },
                    |n, k| weights[n * 9 * in_c + k] as i32,
                );
                let (y, ohw) = pool2x2(y, *hw, *out_c, *pool);
                // first layer: y IS y_lo; threshold directly
                Act::Pm1(
                    binarize(&y, *out_c, |v, n| v >= thresholds[n]),
                    ohw,
                    *out_c,
                )
            }
            LayerWeights::BinConv { in_c, out_c, pool, weights, words_per_row, thresholds } => {
                let Act::Pm1(data, hw, c) = &act else { bail!("BinConv wants ±1") };
                assert_eq!(c, in_c);
                // ±1 conv with -1 padding (paper hardware semantics)
                let y_lo = conv3x3(
                    *hw,
                    *in_c,
                    *out_c,
                    |sy, sx, ch| {
                        if sy < 0 || sx < 0 || sy >= *hw as isize || sx >= *hw as isize {
                            -1
                        } else {
                            data[(sy as usize * hw + sx as usize) * in_c + ch]
                        }
                    },
                    |n, k| pm1(&weights[n * words_per_row..(n + 1) * words_per_row], k),
                );
                let (y_lo, ohw) = pool2x2(y_lo, *hw, *out_c, *pool);
                // eq. 6: y_lo = 2*y_l - cnum, so the match count is exactly
                // y_l = (y_lo + cnum)/2 (always even sum); compare to c_l.
                let cnum = (9 * in_c) as i32;
                Act::Pm1(
                    binarize(&y_lo, *out_c, |v, n| (v + cnum) / 2 >= thresholds[n]),
                    ohw,
                    *out_c,
                )
            }
            LayerWeights::BinFc { in_f, out_f, weights, words_per_row, thresholds } => {
                let Act::Pm1(data, hw, c) = &act else { bail!("BinFc wants ±1") };
                assert_eq!(hw * hw * c, *in_f);
                let mut out = Vec::with_capacity(*out_f);
                for n in 0..*out_f {
                    let w = &weights[n * words_per_row..(n + 1) * words_per_row];
                    let y_lo: i32 = (0..*in_f).map(|k| data[k] * pm1(w, k)).sum();
                    let y_l = (y_lo + *in_f as i32) / 2;
                    out.push(if y_l >= thresholds[n] { 1 } else { -1 });
                }
                Act::Pm1(out, 1, *out_f)
            }
            LayerWeights::BinFcOut { in_f, out_f, weights, words_per_row, scale, bias } => {
                let Act::Pm1(data, hw, c) = &act else { bail!("BinFcOut wants ±1") };
                assert_eq!(hw * hw * c, *in_f);
                let mut scores = Vec::with_capacity(*out_f);
                for n in 0..*out_f {
                    let w = &weights[n * words_per_row..(n + 1) * words_per_row];
                    let y_lo: i32 = (0..*in_f).map(|k| data[k] * pm1(w, k)).sum();
                    let y_l = (y_lo + *in_f as i32) / 2; // exact: y_lo+cnum even
                    scores.push(y_l as f32 * scale[n] + bias[n]);
                }
                return Ok(scores);
            }
        };
    }
    bail!("model has no classifier layer")
}

/// Generic 3x3/stride-1 convolution with caller-supplied tap and weight
/// accessors; output NHWC `hw*hw*out_c`.
fn conv3x3(
    hw: usize,
    in_c: usize,
    out_c: usize,
    tap: impl Fn(isize, isize, usize) -> i32,
    weight: impl Fn(usize, usize) -> i32,
) -> Vec<i32> {
    let mut out = vec![0i32; hw * hw * out_c];
    for y in 0..hw {
        for x in 0..hw {
            for n in 0..out_c {
                let mut acc = 0;
                for kh in 0..3usize {
                    for kw in 0..3usize {
                        for ch in 0..in_c {
                            let k = (kh * 3 + kw) * in_c + ch;
                            acc += tap(y as isize + kh as isize - 1, x as isize + kw as isize - 1, ch)
                                * weight(n, k);
                        }
                    }
                }
                out[(y * hw + x) * out_c + n] = acc;
            }
        }
    }
    out
}

fn pool2x2(y: Vec<i32>, hw: usize, c: usize, pool: bool) -> (Vec<i32>, usize) {
    // odd-`hw` pooling would silently drop the last row/column here; such
    // models are rejected up front by `Engine::new` (ModelError::
    // OddPoolInput), so the oracle only ever sees even resolutions.
    if !pool {
        return (y, hw);
    }
    let oh = hw / 2;
    let mut out = vec![i32::MIN; oh * oh * c];
    for py in 0..oh {
        for px in 0..oh {
            for ch in 0..c {
                let mut best = i32::MIN;
                for dy in 0..2 {
                    for dx in 0..2 {
                        best = best.max(y[((py * 2 + dy) * hw + px * 2 + dx) * c + ch]);
                    }
                }
                out[(py * oh + px) * c + ch] = best;
            }
        }
    }
    (out, oh)
}

fn binarize(y: &[i32], c: usize, pred: impl Fn(i32, usize) -> bool) -> Vec<i32> {
    y.iter()
        .enumerate()
        .map(|(i, &v)| if pred(v, i % c) { 1 } else { -1 })
        .collect()
}
