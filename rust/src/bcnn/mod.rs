//! Native packed-`u64` BCNN inference engine.
//!
//! This is (a) the serving hot path of the coordinator (no Python, no PJRT
//! — pure integer/bit arithmetic), and (b) the *functional* model of the
//! FPGA datapath: the fpga simulator calls [`engine::Engine::run_layer_at`]
//! per layer so its numerics are exactly the paper's architecture
//! (XnorDotProduct -> MP -> NormBinarize, fig. 3).
//!
//! [`scalar_ref`] is the slow ±1 textbook implementation (paper eq. 1/3)
//! used by tests to validate every bit trick in [`engine`].

pub mod engine;
pub mod scalar_ref;
pub mod tensor;

pub use engine::{
    Engine, LayerOutput, LayerShape, LayerStepper, ModelError, RowRef, Scratch, StepperOut,
};
pub use tensor::{Activation, BitFmap};
