//! Packed activation tensors.
//!
//! A [`BitFmap`] stores a binary feature map as one word-aligned packed row
//! per spatial pixel (c bits, LSB-first, channel-minor) — the layout the
//! engine's patch gather and the FC flatten both consume, and the moral
//! equivalent of the paper's distributed-RAM feature-map banks (§5.3).

use crate::util::bits::{copy_bits, get_bit, set_bit, words_for};

/// Binary feature map: `hw x hw` pixels, `c` channels, 1 bit each.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitFmap {
    pub hw: usize,
    pub c: usize,
    pub words_per_pixel: usize,
    /// `hw*hw` rows of `words_per_pixel` words.
    pub data: Vec<u64>,
}

impl BitFmap {
    pub fn zeros(hw: usize, c: usize) -> Self {
        let words_per_pixel = words_for(c);
        Self { hw, c, words_per_pixel, data: vec![0; hw * hw * words_per_pixel] }
    }

    #[inline]
    pub fn pixel(&self, y: usize, x: usize) -> &[u64] {
        let row = y * self.hw + x;
        &self.data[row * self.words_per_pixel..(row + 1) * self.words_per_pixel]
    }

    #[inline]
    pub fn pixel_mut(&mut self, y: usize, x: usize) -> &mut [u64] {
        let row = y * self.hw + x;
        &mut self.data[row * self.words_per_pixel..(row + 1) * self.words_per_pixel]
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> bool {
        get_bit(self.pixel(y, x), ch)
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: bool) {
        set_bit(self.pixel_mut(y, x), ch, v)
    }

    /// Flatten to a single packed bit row in (h, w, c) order — the FC input
    /// layout shared with `python/compile/model.py`.
    pub fn flatten(&self) -> Vec<u64> {
        let total = self.hw * self.hw * self.c;
        let mut out = vec![0u64; words_for(total)];
        if self.c % 64 == 0 {
            // pixel rows are already contiguous words
            out.copy_from_slice(&self.data[..words_for(total)]);
        } else {
            for row in 0..self.hw * self.hw {
                let src = &self.data[row * self.words_per_pixel..(row + 1) * self.words_per_pixel];
                copy_bits(&mut out, row * self.c, src, 0, self.c);
            }
        }
        out
    }
}

/// An activation between layers: integer plane (first layer / pre-threshold
/// accumulator values) or binary feature map.
#[derive(Debug, Clone, PartialEq)]
pub enum Activation {
    /// NHWC integer plane: `hw*hw*c` values.
    Int { hw: usize, c: usize, data: Vec<i32> },
    /// Packed binary feature map.
    Bits(BitFmap),
}

impl Activation {
    pub fn hw(&self) -> usize {
        match self {
            Activation::Int { hw, .. } => *hw,
            Activation::Bits(f) => f.hw,
        }
    }

    pub fn channels(&self) -> usize {
        match self {
            Activation::Int { c, .. } => *c,
            Activation::Bits(f) => f.c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn get_set_roundtrip() {
        let mut f = BitFmap::zeros(4, 33);
        let mut rng = SplitMix64::new(1);
        let mut want = vec![false; 4 * 4 * 33];
        for y in 0..4 {
            for x in 0..4 {
                for ch in 0..33 {
                    let v = rng.bit();
                    f.set(y, x, ch, v);
                    want[(y * 4 + x) * 33 + ch] = v;
                }
            }
        }
        for y in 0..4 {
            for x in 0..4 {
                for ch in 0..33 {
                    assert_eq!(f.get(y, x, ch), want[(y * 4 + x) * 33 + ch]);
                }
            }
        }
    }

    #[test]
    fn flatten_hwc_order() {
        for c in [32usize, 64, 96, 33] {
            let mut f = BitFmap::zeros(2, c);
            let mut rng = SplitMix64::new(c as u64);
            let mut want = vec![false; 2 * 2 * c];
            for (i, w) in want.iter_mut().enumerate() {
                *w = rng.bit();
                let (pix, ch) = (i / c, i % c);
                f.set(pix / 2, pix % 2, ch, *w);
            }
            let flat = f.flatten();
            for (i, &w) in want.iter().enumerate() {
                assert_eq!(get_bit(&flat, i), w, "c={c} bit {i}");
            }
        }
    }
}
