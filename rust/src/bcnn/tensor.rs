//! Packed activation tensors.
//!
//! A [`BitFmap`] stores a binary feature map as one word-aligned packed row
//! per spatial pixel (c bits, LSB-first, channel-minor) — the layout the
//! engine's patch gather and the FC flatten both consume, and the moral
//! equivalent of the paper's distributed-RAM feature-map banks (§5.3).

use crate::util::bits::{copy_bits, get_bit, set_bit, words_for};

/// Binary feature map: `hw x hw` pixels, `c` channels, 1 bit each.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BitFmap {
    pub hw: usize,
    pub c: usize,
    pub words_per_pixel: usize,
    /// `hw*hw` rows of `words_per_pixel` words.
    pub data: Vec<u64>,
}

impl BitFmap {
    pub fn zeros(hw: usize, c: usize) -> Self {
        let words_per_pixel = words_for(c);
        Self { hw, c, words_per_pixel, data: vec![0; hw * hw * words_per_pixel] }
    }

    /// Reshape to an all-zero `hw x hw x c` map, reusing the existing
    /// allocation — the scratch-arena primitive: the engine's ping-pong
    /// activation buffers are `reset` once per layer and never reallocate
    /// after the first image warms their capacity to the network maximum.
    pub fn reset(&mut self, hw: usize, c: usize) {
        self.hw = hw;
        self.c = c;
        self.words_per_pixel = words_for(c);
        self.data.clear();
        self.data.resize(hw * hw * self.words_per_pixel, 0);
    }

    /// Like [`BitFmap::reset`] but skips the zero-fill: word contents are
    /// unspecified afterwards.  Only for callers that overwrite every
    /// word (the engine's threshold compare writes each packed word in
    /// full, pad bits included, so pre-zeroing would double the writes on
    /// the hot path).
    pub fn reshape_for_overwrite(&mut self, hw: usize, c: usize) {
        self.hw = hw;
        self.c = c;
        self.words_per_pixel = words_for(c);
        self.data.resize(hw * hw * self.words_per_pixel, 0);
    }

    #[inline]
    pub fn pixel(&self, y: usize, x: usize) -> &[u64] {
        let row = y * self.hw + x;
        &self.data[row * self.words_per_pixel..(row + 1) * self.words_per_pixel]
    }

    #[inline]
    pub fn pixel_mut(&mut self, y: usize, x: usize) -> &mut [u64] {
        let row = y * self.hw + x;
        &mut self.data[row * self.words_per_pixel..(row + 1) * self.words_per_pixel]
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> bool {
        get_bit(self.pixel(y, x), ch)
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: bool) {
        set_bit(self.pixel_mut(y, x), ch, v)
    }

    /// Flatten into a caller-owned packed bit row in (h, w, c) order — the
    /// FC input layout shared with `python/compile/model.py`.  Reuses the
    /// buffer's capacity (allocation-free once warmed).
    pub fn flatten_into(&self, out: &mut Vec<u64>) {
        let total = self.hw * self.hw * self.c;
        out.clear();
        out.resize(words_for(total), 0);
        if self.c % 64 == 0 {
            // pixel rows are already contiguous words
            out.copy_from_slice(&self.data[..words_for(total)]);
        } else {
            for row in 0..self.hw * self.hw {
                let src = &self.data[row * self.words_per_pixel..(row + 1) * self.words_per_pixel];
                copy_bits(out, row * self.c, src, 0, self.c);
            }
        }
    }

    /// Owning variant of [`BitFmap::flatten_into`].
    pub fn flatten(&self) -> Vec<u64> {
        let mut out = Vec::new();
        self.flatten_into(&mut out);
        out
    }
}

/// An activation between layers: integer plane (first layer / pre-threshold
/// accumulator values) or binary feature map.
#[derive(Debug, Clone, PartialEq)]
pub enum Activation {
    /// NHWC integer plane: `hw*hw*c` values.
    Int { hw: usize, c: usize, data: Vec<i32> },
    /// Packed binary feature map.
    Bits(BitFmap),
}

impl Activation {
    pub fn hw(&self) -> usize {
        match self {
            Activation::Int { hw, .. } => *hw,
            Activation::Bits(f) => f.hw,
        }
    }

    pub fn channels(&self) -> usize {
        match self {
            Activation::Int { c, .. } => *c,
            Activation::Bits(f) => f.c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn get_set_roundtrip() {
        let mut f = BitFmap::zeros(4, 33);
        let mut rng = SplitMix64::new(1);
        let mut want = vec![false; 4 * 4 * 33];
        for y in 0..4 {
            for x in 0..4 {
                for ch in 0..33 {
                    let v = rng.bit();
                    f.set(y, x, ch, v);
                    want[(y * 4 + x) * 33 + ch] = v;
                }
            }
        }
        for y in 0..4 {
            for x in 0..4 {
                for ch in 0..33 {
                    assert_eq!(f.get(y, x, ch), want[(y * 4 + x) * 33 + ch]);
                }
            }
        }
    }

    #[test]
    fn reset_reuses_capacity_and_zeroes() {
        let mut f = BitFmap::zeros(4, 96);
        for w in f.data.iter_mut() {
            *w = u64::MAX;
        }
        let cap = f.data.capacity();
        f.reset(2, 33);
        assert_eq!((f.hw, f.c, f.words_per_pixel), (2, 33, 1));
        assert_eq!(f.data.len(), 2 * 2);
        assert!(f.data.iter().all(|&w| w == 0), "reset must zero");
        assert_eq!(f.data.capacity(), cap, "shrinking reset must not reallocate");
    }

    #[test]
    fn reshape_for_overwrite_shapes_without_zeroing_cost() {
        let mut f = BitFmap::zeros(2, 65);
        for w in f.data.iter_mut() {
            *w = u64::MAX;
        }
        f.reshape_for_overwrite(1, 130);
        assert_eq!((f.hw, f.c, f.words_per_pixel), (1, 130, 3));
        assert_eq!(f.data.len(), 3);
        // contents are unspecified (stale words allowed); a full overwrite
        // must leave it equal to the zeroed-and-set equivalent
        for w in f.data.iter_mut() {
            *w = 0;
        }
        let mut rng = SplitMix64::new(12);
        let mut want = BitFmap::zeros(1, 130);
        for ch in 0..130 {
            let v = rng.bit();
            f.set(0, 0, ch, v);
            want.set(0, 0, ch, v);
        }
        assert_eq!(f, want);
    }

    #[test]
    fn flatten_into_matches_flatten() {
        let mut f = BitFmap::zeros(3, 33);
        let mut rng = SplitMix64::new(9);
        for y in 0..3 {
            for x in 0..3 {
                for ch in 0..33 {
                    f.set(y, x, ch, rng.bit());
                }
            }
        }
        let mut out = vec![u64::MAX; 17]; // stale content must be cleared
        f.flatten_into(&mut out);
        assert_eq!(out, f.flatten());
    }

    #[test]
    fn flatten_hwc_order() {
        for c in [32usize, 64, 96, 33] {
            let mut f = BitFmap::zeros(2, c);
            let mut rng = SplitMix64::new(c as u64);
            let mut want = vec![false; 2 * 2 * c];
            for (i, w) in want.iter_mut().enumerate() {
                *w = rng.bit();
                let (pix, ch) = (i / c, i % c);
                f.set(pix / 2, pix % 2, ch, *w);
            }
            let flat = f.flatten();
            for (i, &w) in want.iter().enumerate() {
                assert_eq!(get_bit(&flat, i), w, "c={c} bit {i}");
            }
        }
    }
}
