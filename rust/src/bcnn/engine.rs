//! The packed XNOR+popcount inference engine (paper fig. 3, bit-exact).
//!
//! Per layer: `XnorDotProduct` = `cnum - popcount(patch ^ weights)` over
//! packed `u64` rows (paper eq. 5/6), optional 2x2/2 max-pool on the
//! *integer* accumulator plane, then the folded `NormBinarize` threshold
//! compare (eq. 8).  The first layer is the 6-bit x ±1 integer dot product
//! of eq. 7.  Padding contributes zero bits = -1 activations, keeping
//! `cnum = FW*FH*FD` constant across the border exactly like the paper's
//! fixed-size PE datapath.
//!
//! ## Tap-major dataflow (PERF iter 6, EXPERIMENTS.md §Perf)
//!
//! The conv hot path is **tap-major**: no im2row patch is ever gathered.
//! For each output pixel the 9 filter taps are visited directly — each tap
//! XORs the input pixel's own packed channel words (already contiguous in
//! [`BitFmap`]) against that tap's word-aligned slice of the transposed
//! weight bank, accumulating mismatches *vertically* across all filters
//! (one popcount lane per filter).  This is the software analogue of the
//! paper's line-buffer pipeline (fig. 3): every input pixel streams past
//! the filter bank once per tap position, and nothing is re-packed.
//! Out-of-bounds taps contribute a precomputed per-tap weight popcount
//! (all activation bits zero = all −1 padding).  Rows are split into
//! border/interior so the interior — the vast majority of pixels at
//! `hw >= 8` — runs a branch-free constant-trip tap loop.  For pooling
//! layers the 2x2/2 max is fused into the conv output write, so the
//! full-resolution accumulator plane is never materialized.
//!
//! The engine is allocation-free on the per-image path after warm-up: the
//! integer accumulator plane, the mismatch lanes, the ping-pong packed
//! activation buffers, and the FC flatten row all live in a per-worker
//! [`Scratch`] arena that the coordinator reuses across requests
//! ([`Engine::infer_into`] performs zero heap allocations once the arena
//! is warm; see the capacity regression test in
//! `rust/tests/engine_integration.rs`).
//!
//! ## SIMD kernel dispatch (PERF iter 7)
//!
//! The two bitwise primitives under the hot path — the whole-row XOR
//! popcount of the FC dots and the per-tap bank lane accumulation of the
//! conv loops — go through a [`Kernel`] resolved once at [`Engine::new`]
//! time (avx512 > avx2 > scalar, overridable via `BCNN_KERNEL`).  The
//! kernel is a `Copy` field of the engine, so every path that borrows the
//! engine — whole-image inference, the layer-at-a-time API, and every
//! [`LayerStepper`] lane of the row-streaming pipeline — dispatches to
//! the same wide implementation.  The `[tap][word][out_c]` bank layout
//! already makes each tap's lane slice contiguous and unit-stride, which
//! is exactly the shape the 256/512-bit loads want; no restructuring was
//! needed.  See `util::kernels` for the implementations and DESIGN.md for
//! the mapping onto the paper's UF-wide XNOR array.
//!
//! Malformed models (packed rows whose word stride disagrees with their
//! bit width, pooling at an odd resolution, mis-sized parameter vectors)
//! are rejected with a typed [`ModelError`] at [`Engine::new`] time
//! instead of producing silent misnumerics at request time.

use std::fmt;

use anyhow::{bail, Result};

use crate::bcnn::tensor::{Activation, BitFmap};
use crate::model::{BcnnModel, LayerWeights};
use crate::util::bits::{copy_bits, read_bits_u64, set_bit, words_for};
use crate::util::kernels::{Kernel, KernelError};

/// Output of one layer.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerOutput {
    Act(Activation),
    /// Classifier scores (only from the final layer).
    Scores(Vec<f32>),
}

/// Model-validation failure detected at [`Engine::new`] time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ModelError {
    /// A packed weight row's word stride disagrees with its bit width
    /// (`words_per_row != words_for(row_bits)`), which would make every
    /// row slice read the wrong filter.
    WeightRowWidth { layer: usize, got: usize, want: usize },
    /// A weight/threshold/scale/bias vector's length disagrees with the
    /// layer shape.
    VectorLen { layer: usize, what: &'static str, got: usize, want: usize },
    /// A 2x2/2 max-pool would run at an odd resolution and silently drop
    /// the last row/column of the feature map.
    OddPoolInput { layer: usize, hw: usize },
    /// A layer's declared input geometry disagrees with the previous
    /// layer's output — the model would bail (or, worse, misnumerate
    /// against phantom pad bits) at request time.
    ChainMismatch { layer: usize, what: &'static str, got: usize, want: usize },
    /// The `BCNN_KERNEL` kernel override could not be honoured (unknown
    /// name, or the requested ISA is unavailable on this host) —
    /// surfaced at construction, where the dispatch is resolved.
    Kernel(KernelError),
}

impl fmt::Display for ModelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelError::WeightRowWidth { layer, got, want } => write!(
                f,
                "layer {layer}: packed weight rows span {got} words but the row width needs {want}"
            ),
            ModelError::VectorLen { layer, what, got, want } => {
                write!(f, "layer {layer}: {what} has {got} elements, expected {want}")
            }
            ModelError::OddPoolInput { layer, hw } => write!(
                f,
                "layer {layer}: 2x2/2 max-pool at odd resolution {hw}x{hw} \
                 would drop the last row/column"
            ),
            ModelError::ChainMismatch { layer, what, got, want } => write!(
                f,
                "layer {layer}: declared {what} {got} disagrees with the \
                 previous layer's output ({want})"
            ),
            ModelError::Kernel(e) => write!(f, "kernel dispatch: {e}"),
        }
    }
}

impl std::error::Error for ModelError {}

/// Reusable per-worker scratch arena.  Everything the per-image path
/// touches lives here: after one warm-up image every buffer has reached
/// the network's maximum size and later images perform zero heap
/// allocations (asserted by [`Scratch::capacity_bytes`] in the
/// regression tests).
#[derive(Debug, Default, Clone)]
pub struct Scratch {
    /// Integer conv accumulator plane (already pooled for pooling layers).
    acc: Vec<i32>,
    /// Per-pixel mismatch accumulators, one lane per output channel.
    mismatch: Vec<u64>,
    /// Per-pixel integer accumulators for the first (eq. 7) layer.
    pix: Vec<i32>,
    /// Ping-pong packed activation planes reused across layers and images.
    bits_in: BitFmap,
    bits_out: BitFmap,
    /// Packed FC input row (flatten target).
    fc_row: Vec<u64>,
}

impl Scratch {
    /// Total heap capacity currently owned by the arena, in bytes.  The
    /// zero-allocation regression test asserts this stops growing after
    /// one warm-up image.
    pub fn capacity_bytes(&self) -> usize {
        self.acc.capacity() * std::mem::size_of::<i32>()
            + self.pix.capacity() * std::mem::size_of::<i32>()
            + self.mismatch.capacity() * std::mem::size_of::<u64>()
            + self.fc_row.capacity() * std::mem::size_of::<u64>()
            + self.bits_in.data.capacity() * std::mem::size_of::<u64>()
            + self.bits_out.data.capacity() * std::mem::size_of::<u64>()
    }
}

/// Tap-major prepared form of one BinConv layer's weights.
#[derive(Debug, Clone)]
struct PreparedBin {
    /// `[tap][word][out_c]` transposed weights: entry
    /// `(t * chan_words + w) * out_c + n` holds bits
    /// `[t*in_c + 64w, t*in_c + 64w + 64)` of filter `n`'s packed row —
    /// i.e. tap `t`'s channel block, re-aligned to word boundaries so it
    /// XORs directly against the input pixel's own packed words.
    tap_weights: Vec<u64>,
    /// `[tap][out_c]` popcount of each tap's weight bits: the mismatch
    /// contribution of an out-of-bounds tap (zero activation bits = all
    /// -1 padding, paper border semantics).
    tap_pop: Vec<u32>,
    /// `words_for(in_c)` — packed words per input pixel.
    chan_words: usize,
}

/// Packed-u64 inference engine over a loaded (and validated) model.
#[derive(Debug, Clone)]
pub struct Engine {
    model: BcnnModel,
    /// First-layer weights transposed to `[k][out_c]` and widened to i32
    /// at load time, so the per-tap filter loop is a unit-stride
    /// vectorizable MAC over out_c lanes (PERF iter 2).
    fp_weights_t: Vec<Vec<i32>>,
    /// Tap-major transposed banks for every BinConv layer (PERF iter 6;
    /// superseded the whole-row `[word][out_c]` transpose of iter 4).
    bin_prepared: Vec<Option<PreparedBin>>,
    /// Bitwise-primitive dispatch (PERF iter 7): resolved once at
    /// construction, carried by value so steppers and clones inherit it.
    kernel: Kernel,
}

impl Engine {
    /// Validate `model` (per-layer shapes AND layer-to-layer geometry
    /// chaining) and prepare the transposed weight banks.  The bitwise
    /// kernel is resolved here from `BCNN_KERNEL` (auto-detect when
    /// unset); use [`Engine::with_kernel`] to pin one explicitly.
    pub fn new(model: BcnnModel) -> std::result::Result<Self, ModelError> {
        let kernel = Kernel::from_env().map_err(ModelError::Kernel)?;
        Self::with_kernel(model, kernel)
    }

    /// [`Engine::new`] with an explicit kernel — lets tests and benches
    /// hold scalar and SIMD engines over the same model side by side.
    pub fn with_kernel(
        model: BcnnModel,
        kernel: Kernel,
    ) -> std::result::Result<Self, ModelError> {
        let mut hw = model.input_hw;
        let mut c = model.input_channels;
        for (i, layer) in model.layers.iter().enumerate() {
            validate_layer(i, layer)?;
            match layer {
                LayerWeights::FpConv { in_c, out_c, pool, .. }
                | LayerWeights::BinConv { in_c, out_c, pool, .. } => {
                    if *in_c != c {
                        return Err(ModelError::ChainMismatch {
                            layer: i,
                            what: "input channels",
                            got: *in_c,
                            want: c,
                        });
                    }
                    if *pool {
                        if hw % 2 != 0 {
                            return Err(ModelError::OddPoolInput { layer: i, hw });
                        }
                        hw /= 2;
                    }
                    c = *out_c;
                }
                LayerWeights::BinFc { in_f, out_f, .. }
                | LayerWeights::BinFcOut { in_f, out_f, .. } => {
                    if *in_f != hw * hw * c {
                        return Err(ModelError::ChainMismatch {
                            layer: i,
                            what: "input features",
                            got: *in_f,
                            want: hw * hw * c,
                        });
                    }
                    hw = 1;
                    c = *out_f;
                }
            }
        }
        let fp_weights_t = model.layers.iter().map(prepare_fp).collect();
        let bin_prepared = model.layers.iter().map(prepare_bin).collect();
        Ok(Self { model, fp_weights_t, bin_prepared, kernel })
    }

    pub fn model(&self) -> &BcnnModel {
        &self.model
    }

    /// The bitwise kernel this engine dispatches to.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Classify one image (`hw*hw*input_channels` NHWC int values in the
    /// 6-bit range).  Returns per-class scores.
    pub fn infer(&self, image: &[i32]) -> Result<Vec<f32>> {
        self.infer_with_scratch(image, &mut Scratch::default())
    }

    /// Allocation-reusing variant for the serving hot path (allocates only
    /// the returned score vector; see [`Engine::infer_into`]).
    pub fn infer_with_scratch(&self, image: &[i32], scratch: &mut Scratch) -> Result<Vec<f32>> {
        let mut scores = Vec::with_capacity(self.model.classes);
        self.infer_into(image, scratch, &mut scores)?;
        Ok(scores)
    }

    /// Fully allocation-free inference: the class scores land in `scores`
    /// (cleared first) and every intermediate lives in `scratch`.  After
    /// one warm-up image neither buffer grows again.
    pub fn infer_into(
        &self,
        image: &[i32],
        scratch: &mut Scratch,
        scores: &mut Vec<f32>,
    ) -> Result<()> {
        let hw = self.model.input_hw;
        let c = self.model.input_channels;
        if image.len() != hw * hw * c {
            bail!("image size {} != {}", image.len(), hw * hw * c);
        }
        scores.clear();
        let n_layers = self.model.layers.len();
        let Scratch { acc, mismatch, pix, bits_in, bits_out, fc_row } = scratch;
        // parity of the ping-pong swaps this image has performed; restored
        // on exit so every image resets the same physical buffer sequence
        // (otherwise an odd number of activation layers alternates the
        // buffer roles between images and capacities keep flip-flopping —
        // the arena would only freeze after the *second* image)
        let mut flipped = false;
        for i in 0..n_layers {
            let layer = &self.model.layers[i];
            // the first layer reads the caller's image in place; later
            // layers read the ping (bits_in) and write the pong (bits_out)
            let input = if i == 0 {
                ActRef::Int { hw, c, data: image }
            } else {
                ActRef::Bits(&*bits_in)
            };
            let out = step_layer(
                self.kernel,
                layer,
                self.fp_weights_t[i].as_slice(),
                self.bin_prepared[i].as_ref(),
                input,
                StepBufs {
                    acc: &mut *acc,
                    mism: &mut *mismatch,
                    pix: &mut *pix,
                    bits_out: &mut *bits_out,
                    fc_row: &mut *fc_row,
                },
                scores,
            )?;
            match out {
                StepOut::Act => {
                    std::mem::swap(&mut *bits_in, &mut *bits_out);
                    flipped = !flipped;
                }
                StepOut::Scores => {
                    if i + 1 != n_layers {
                        bail!("classifier layer {i} is not last");
                    }
                    if flipped {
                        std::mem::swap(&mut *bits_in, &mut *bits_out);
                    }
                    return Ok(());
                }
            }
        }
        bail!("model has no classifier layer")
    }

    /// Batch inference (images processed independently; the FPGA streaming
    /// architecture is batch-insensitive, and so is this loop).  Accepts
    /// owned (`Vec<i32>`) or borrowed (`&[i32]`) image rows.
    pub fn infer_batch<I: AsRef<[i32]>>(&self, images: &[I]) -> Result<Vec<Vec<f32>>> {
        let mut scratch = Scratch::default();
        images
            .iter()
            .map(|img| self.infer_with_scratch(img.as_ref(), &mut scratch))
            .collect()
    }

    /// Run the model's layer `index` — the layer-by-index API used by the
    /// FPGA phase simulator and the per-layer benches.  The prepared
    /// tap-major banks are selected by index, so they engage for every
    /// caller.  Outputs are owned clones of the scratch planes (this path
    /// trades the extra copy for the channel-friendly owned API; the
    /// zero-alloc pipeline is [`Engine::infer_into`]).
    pub fn run_layer_at(
        &self,
        index: usize,
        input: &Activation,
        scratch: &mut Scratch,
    ) -> Result<LayerOutput> {
        let Some(layer) = self.model.layers.get(index) else {
            bail!("layer index {index} out of range ({} layers)", self.model.layers.len());
        };
        run_prepared_layer(
            self.kernel,
            layer,
            self.fp_weights_t[index].as_slice(),
            self.bin_prepared[index].as_ref(),
            input,
            scratch,
        )
    }

    /// Run an arbitrary layer value: validates it, prepares its tap-major
    /// bank on the fly (allocates — fine off the hot path) and runs the
    /// same kernels as [`Engine::run_layer_at`].
    pub fn run_layer(&self, layer: &LayerWeights, input: &Activation) -> Result<LayerOutput> {
        // the layer value has no index of its own; relabel the validation
        // error so it doesn't masquerade as the model's layer 0
        if let Err(e) = validate_layer(0, layer) {
            bail!("invalid ad-hoc layer value: {e}");
        }
        let fp_t = prepare_fp(layer);
        let bin = prepare_bin(layer);
        run_prepared_layer(self.kernel, layer, &fp_t, bin.as_ref(), input, &mut Scratch::default())
    }
}

// ---------------------------------------------------------------------------
// row-granular stepping (the pipeline runtime's building block)

/// Static I/O geometry of one layer, produced by [`Engine::layer_shapes`].
///
/// `out_c` is the output channel count for conv layers and the output
/// feature count for FC layers (an FC output is a 1x1 feature map).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerShape {
    pub in_hw: usize,
    pub in_c: usize,
    pub out_hw: usize,
    pub out_c: usize,
    /// `true` for the classifier layer (emits scores, not a row).
    pub scores: bool,
}

impl LayerShape {
    /// Packed words per *input* row of this layer (`in_hw` pixels).
    pub fn in_row_words(&self) -> usize {
        self.in_hw * words_for(self.in_c)
    }

    /// Packed words per *output* row of this layer (`out_hw` pixels).
    pub fn out_row_words(&self) -> usize {
        self.out_hw * words_for(self.out_c)
    }
}

/// A borrowed input row for [`LayerStepper::push_row`].
///
/// `Int` rows (raw `in_hw * in_c` NHWC values) feed the first layer only;
/// every later layer consumes `Bits` rows — `in_hw` pixels of
/// `words_for(in_c)` packed words each, exactly one spatial row of a
/// [`BitFmap`].
#[derive(Debug, Clone, Copy)]
pub enum RowRef<'a> {
    Int(&'a [i32]),
    Bits(&'a [u64]),
}

/// One emission from a [`LayerStepper`]: a packed output row, or the
/// classifier scores (final layer, on [`LayerStepper::flush`]).
#[derive(Debug, Clone, PartialEq)]
pub enum StepperOut {
    /// `out_hw` pixels x `words_for(out_c)` packed words.
    Row(Vec<u64>),
    Scores(Vec<f32>),
}

/// Row-granular layer executor: the software analogue of one pipeline
/// stage of the paper's streaming architecture (§4, fig. 4).  Input rows
/// are pushed as they arrive; output rows are emitted as soon as their
/// 3x3 window (plus the fused 2x2/2 pool pair, for pooling layers) is
/// complete — so a downstream stage can start an image *before* the
/// upstream stage has finished it.
///
/// The stepper runs the same tap-major kernels as [`Engine::infer_into`]
/// over a 3-row sliding window instead of a whole plane, so its output is
/// bit-identical to whole-image inference (asserted by the property tests
/// in `rust/tests/pipeline_integration.rs`).
///
/// ## Channel partitions (stage-lane parallelism)
///
/// A stepper may be restricted to an output-channel subrange
/// ([`Engine::layer_stepper_part`]): it then accumulates only the filter
/// subrange `[lo, hi)` of the tap-major bank and its emitted packed rows
/// carry only bits `[lo, hi)` of each pixel (all other bits zero), so
/// the lanes of a disjoint cover of `0..out_c` OR-merge into exactly the
/// unpartitioned row — bit-identical by construction, since every output
/// channel's accumulator chain (conv counts, pool max, NormBinarize
/// compare, FC dot product, classifier affine) is independent of every
/// other channel's.  Partitioned classifier steppers emit the score
/// subrange `[lo, hi)`; lanes concatenate in ascending range order.
/// This is the host analogue of splitting a layer's filters across `P`
/// PEs (paper §4.2 spatial parallelism).
///
/// Lifecycle per image: exactly `in_hw` [`LayerStepper::push_row`] calls,
/// then one [`LayerStepper::flush`] (which emits the bottom border row,
/// or the FC/classifier output, and resets the stepper for the next
/// image).
pub struct LayerStepper<'e> {
    engine: &'e Engine,
    index: usize,
    shape: LayerShape,
    /// Output-channel (conv) / feature (FC) / class (classifier) subrange
    /// this stepper computes; `(0, shape.out_c)` for the full stepper.
    lo: usize,
    hi: usize,
    /// Input rows pushed so far this image.
    rows_seen: usize,
    state: StepperState,
}

enum StepperState {
    FpConv {
        /// Sliding window: input row `r` lives in `ring[r % 3]`.
        ring: [Vec<i32>; 3],
        /// Per-pixel `out_c` accumulator lanes.
        pix: Vec<i32>,
        /// One full-resolution conv output row of match counts.
        conv_row: Vec<i32>,
        /// Pooling: the even conv row awaiting its odd partner (empty =
        /// none pending).
        pending: Vec<i32>,
        /// Pooling: reused half-resolution max plane for one output row
        /// (keeps the per-row hot path allocation-free except for the
        /// emitted packed row, which must be owned to cross threads).
        pooled: Vec<i32>,
    },
    BinConv {
        ring: [Vec<u64>; 3],
        mism: Vec<u64>,
        conv_row: Vec<i32>,
        pending: Vec<i32>,
        pooled: Vec<i32>,
    },
    /// BinFc and BinFcOut: accumulate the packed flatten row, compute on
    /// flush.
    Fc {
        fc_row: Vec<u64>,
    },
}

impl Engine {
    /// Per-layer I/O geometry, in model order (the pool halving applied
    /// layer by layer exactly as [`Engine::new`] validated it).
    pub fn layer_shapes(&self) -> Vec<LayerShape> {
        let mut hw = self.model.input_hw;
        let mut c = self.model.input_channels;
        self.model
            .layers
            .iter()
            .map(|layer| match layer {
                LayerWeights::FpConv { out_c, pool, .. }
                | LayerWeights::BinConv { out_c, pool, .. } => {
                    let (in_hw, in_c) = (hw, c);
                    let out_hw = if *pool { hw / 2 } else { hw };
                    hw = out_hw;
                    c = *out_c;
                    LayerShape { in_hw, in_c, out_hw, out_c: *out_c, scores: false }
                }
                LayerWeights::BinFc { out_f, .. } => {
                    let s =
                        LayerShape { in_hw: hw, in_c: c, out_hw: 1, out_c: *out_f, scores: false };
                    hw = 1;
                    c = *out_f;
                    s
                }
                LayerWeights::BinFcOut { out_f, .. } => {
                    let s =
                        LayerShape { in_hw: hw, in_c: c, out_hw: 1, out_c: *out_f, scores: true };
                    hw = 1;
                    c = *out_f;
                    s
                }
            })
            .collect()
    }

    /// Build a row-granular stepper for the model's layer `index`.
    pub fn layer_stepper(&self, index: usize) -> Result<LayerStepper<'_>> {
        let shapes = self.layer_shapes();
        let Some(&shape) = shapes.get(index) else {
            bail!("layer index {index} out of range ({} layers)", shapes.len());
        };
        self.stepper_for(index, shape, 0, shape.out_c)
    }

    /// Build a *partitioned* stepper computing only output channels
    /// (features / classes) `[lo, hi)` of layer `index` — one lane of a
    /// stage lane group.  See the partition notes on [`LayerStepper`].
    pub fn layer_stepper_part(
        &self,
        index: usize,
        lo: usize,
        hi: usize,
    ) -> Result<LayerStepper<'_>> {
        let shapes = self.layer_shapes();
        let Some(&shape) = shapes.get(index) else {
            bail!("layer index {index} out of range ({} layers)", shapes.len());
        };
        if lo >= hi || hi > shape.out_c {
            bail!(
                "layer {index}: partition [{lo}, {hi}) out of range for {} output channels",
                shape.out_c
            );
        }
        self.stepper_for(index, shape, lo, hi)
    }

    fn stepper_for(
        &self,
        index: usize,
        shape: LayerShape,
        lo: usize,
        hi: usize,
    ) -> Result<LayerStepper<'_>> {
        // partition-local accumulators are compact (`plen` lanes); only
        // the emitted packed rows span the full channel width
        let plen = hi - lo;
        let state = match &self.model.layers[index] {
            LayerWeights::FpConv { .. } => StepperState::FpConv {
                ring: std::array::from_fn(|_| vec![0i32; shape.in_hw * shape.in_c]),
                pix: vec![0i32; plen],
                conv_row: vec![0i32; shape.in_hw * plen],
                pending: Vec::with_capacity(shape.in_hw * plen),
                pooled: Vec::with_capacity(shape.out_hw * plen),
            },
            LayerWeights::BinConv { .. } => StepperState::BinConv {
                ring: std::array::from_fn(|_| vec![0u64; shape.in_row_words()]),
                mism: vec![0u64; plen],
                conv_row: vec![0i32; shape.in_hw * plen],
                pending: Vec::with_capacity(shape.in_hw * plen),
                pooled: Vec::with_capacity(shape.out_hw * plen),
            },
            LayerWeights::BinFc { in_f, .. } | LayerWeights::BinFcOut { in_f, .. } => {
                StepperState::Fc { fc_row: vec![0u64; words_for(*in_f)] }
            }
        };
        Ok(LayerStepper { engine: self, index, shape, lo, hi, rows_seen: 0, state })
    }
}

impl LayerStepper<'_> {
    pub fn shape(&self) -> LayerShape {
        self.shape
    }

    /// The output-channel subrange this stepper computes
    /// (`(0, shape.out_c)` for an unpartitioned stepper).
    pub fn partition(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    /// Push one input row (row `rows_seen` of the current image).  Output
    /// rows whose windows are complete are handed to `emit` before this
    /// returns — zero or one conv row per push (zero or one *pooled* row
    /// for pooling layers), nothing for FC layers until flush.
    pub fn push_row(&mut self, row: RowRef<'_>, emit: &mut dyn FnMut(StepperOut)) -> Result<()> {
        let LayerShape { in_hw, .. } = self.shape;
        if self.rows_seen >= in_hw {
            bail!("layer {}: image already has all {in_hw} rows (missing flush?)", self.index);
        }
        let r = self.rows_seen;
        match (&mut self.state, row) {
            (StepperState::FpConv { ring, .. }, RowRef::Int(data)) => {
                if data.len() != in_hw * self.shape.in_c {
                    bail!(
                        "layer {}: int row has {} values, want {}",
                        self.index,
                        data.len(),
                        in_hw * self.shape.in_c
                    );
                }
                ring[r % 3].copy_from_slice(data);
            }
            (StepperState::BinConv { ring, .. }, RowRef::Bits(words)) => {
                if words.len() != self.shape.in_row_words() {
                    bail!(
                        "layer {}: packed row has {} words, want {}",
                        self.index,
                        words.len(),
                        self.shape.in_row_words()
                    );
                }
                ring[r % 3].copy_from_slice(words);
            }
            (StepperState::Fc { fc_row }, RowRef::Bits(words)) => {
                if words.len() != self.shape.in_row_words() {
                    bail!(
                        "layer {}: packed row has {} words, want {}",
                        self.index,
                        words.len(),
                        self.shape.in_row_words()
                    );
                }
                // append this spatial row's pixels to the flatten row in
                // (h, w, c) bit order — identical to BitFmap::flatten_into
                let c = self.shape.in_c;
                let cw = words_for(c);
                for x in 0..in_hw {
                    copy_bits(fc_row, (r * in_hw + x) * c, &words[x * cw..(x + 1) * cw], 0, c);
                }
                self.rows_seen += 1;
                return Ok(());
            }
            (StepperState::FpConv { .. }, _) => {
                bail!("layer {}: FpConv expects int rows", self.index)
            }
            (_, _) => bail!("layer {}: expects packed binary rows", self.index),
        }
        self.rows_seen += 1;
        // rows 0..=r are in the window: output row r-1 is now complete
        // (its 3x3 window needs input rows r-2, r-1, r)
        if r >= 1 {
            self.conv_out_row(r - 1, emit)?;
        }
        Ok(())
    }

    /// End of image: emit the bottom border row (conv) or the FC /
    /// classifier output, then reset for the next image.
    pub fn flush(&mut self, emit: &mut dyn FnMut(StepperOut)) -> Result<()> {
        let LayerShape { in_hw, .. } = self.shape;
        if self.rows_seen != in_hw {
            bail!(
                "layer {}: flush after {} of {in_hw} rows",
                self.index,
                self.rows_seen
            );
        }
        if matches!(self.state, StepperState::Fc { .. }) {
            self.flush_fc(emit);
        } else {
            // bottom output row: window is [in_hw-2, in_hw-1, pad]
            self.conv_out_row(in_hw - 1, emit)?;
        }
        self.rows_seen = 0;
        Ok(())
    }

    /// FC / classifier flush: the whole flatten row is in, compute the
    /// packed dot products (identical arithmetic to [`step_layer`]'s FC
    /// arms) for this stepper's feature subrange and zero the accumulator
    /// for the next image.
    fn flush_fc(&mut self, emit: &mut dyn FnMut(StepperOut)) {
        let (lo, hi) = (self.lo, self.hi);
        let kernel = self.engine.kernel;
        let layer = &self.engine.model.layers[self.index];
        let StepperState::Fc { fc_row } = &mut self.state else {
            unreachable!("flush_fc on a conv stepper");
        };
        match layer {
            LayerWeights::BinFc { out_f, .. } => {
                let mut out = vec![0u64; words_for(*out_f)];
                bin_fc_select(kernel, layer, &fc_row[..], lo, hi, |n| set_bit(&mut out, n, true));
                emit(StepperOut::Row(out));
            }
            LayerWeights::BinFcOut { .. } => {
                let mut scores = Vec::with_capacity(hi - lo);
                bin_fc_out_scores(kernel, layer, &fc_row[..], lo, hi, &mut scores);
                emit(StepperOut::Scores(scores));
            }
            _ => unreachable!("Fc state only built for FC layers"),
        }
        fc_row.fill(0);
    }

    /// Compute conv output row `y` (this stepper's channel subrange) from
    /// the sliding window and emit it (possibly folded through the fused
    /// 2x2/2 pool).
    fn conv_out_row(&mut self, y: usize, emit: &mut dyn FnMut(StepperOut)) -> Result<()> {
        let LayerShape { in_hw, in_c, out_c, .. } = self.shape;
        let (lo, hi) = (self.lo, self.hi);
        let layer = &self.engine.model.layers[self.index];
        match &mut self.state {
            StepperState::FpConv { ring, pix, conv_row, pending, pooled } => {
                let LayerWeights::FpConv { pool, thresholds, .. } = layer else {
                    unreachable!("FpConv state only built for FpConv layers");
                };
                let rows = window(ring, y, in_hw);
                fp_conv_row(
                    rows,
                    in_hw,
                    in_c,
                    out_c,
                    lo,
                    hi,
                    self.engine.fp_weights_t[self.index].as_slice(),
                    pix,
                    conv_row,
                );
                finish_conv_row(
                    conv_row, pending, pooled, *pool, y, in_hw, out_c, lo, hi, thresholds, emit,
                );
            }
            StepperState::BinConv { ring, mism, conv_row, pending, pooled } => {
                let LayerWeights::BinConv { pool, thresholds, .. } = layer else {
                    unreachable!("BinConv state only built for BinConv layers");
                };
                let prep = self.engine.bin_prepared[self.index]
                    .as_ref()
                    .expect("BinConv layer has a prepared bank");
                let rows = window(ring, y, in_hw);
                let kernel = self.engine.kernel;
                bin_conv_row(kernel, rows, in_hw, in_c, out_c, lo, hi, prep, mism, conv_row);
                finish_conv_row(
                    conv_row, pending, pooled, *pool, y, in_hw, out_c, lo, hi, thresholds, emit,
                );
            }
            StepperState::Fc { .. } => unreachable!("conv_out_row on an FC stepper"),
        }
        Ok(())
    }
}

/// The 3-row window `[above, centre, below]` for output row `y` (`None` =
/// the -1-padding border, exactly the whole-image kernels' semantics).
fn window<T>(ring: &[Vec<T>; 3], y: usize, hw: usize) -> [Option<&[T]>; 3] {
    [
        if y > 0 { Some(ring[(y - 1) % 3].as_slice()) } else { None },
        Some(ring[y % 3].as_slice()),
        if y + 1 < hw { Some(ring[(y + 1) % 3].as_slice()) } else { None },
    ]
}

/// Row-window variant of [`bin_conv3x3_tap_major`]: one output row of
/// match counts (channels `[lo, hi)`, compact `hi - lo` stride) from
/// three (optional) input rows.  Runs the identical tap-major kernels
/// ([`accumulate_tap_range`] / `tap_pop` borders) so counts are bit-exact
/// vs the whole-image path — per channel, a partition accumulates exactly
/// the lanes the full kernel does.
#[allow(clippy::too_many_arguments)]
fn bin_conv_row(
    kernel: Kernel,
    rows: [Option<&[u64]>; 3],
    hw: usize,
    in_c: usize,
    out_c: usize,
    lo: usize,
    hi: usize,
    prep: &PreparedBin,
    mism: &mut [u64],
    out_row: &mut [i32],
) {
    let cnum = (9 * in_c) as i32;
    let cw = prep.chan_words;
    let lane = cw * out_c;
    let plen = hi - lo;
    let interior_ok = hw >= 3 && rows.iter().all(|r| r.is_some());

    if !interior_ok {
        for x in 0..hw {
            bin_row_border(kernel, &rows, hw, prep, out_c, lo, hi, x, mism);
            store_row_pixel(out_row, mism, cnum, plen, x);
        }
        return;
    }
    bin_row_border(kernel, &rows, hw, prep, out_c, lo, hi, 0, mism);
    store_row_pixel(out_row, mism, cnum, plen, 0);
    for x in 1..hw - 1 {
        // all 9 taps in bounds: constant-trip, branch-free tap loop
        mism.fill(0);
        for t in 0..9usize {
            let row = rows[t / 3].unwrap();
            let sx = x + t % 3 - 1;
            accumulate_tap_range(
                kernel,
                &row[sx * cw..(sx + 1) * cw],
                &prep.tap_weights[t * lane..(t + 1) * lane],
                out_c,
                lo,
                hi,
                mism,
            );
        }
        store_row_pixel(out_row, mism, cnum, plen, x);
    }
    bin_row_border(kernel, &rows, hw, prep, out_c, lo, hi, hw - 1, mism);
    store_row_pixel(out_row, mism, cnum, plen, hw - 1);
}

/// Border pixel of a row window: clipped taps contribute their
/// precomputed weight popcount, exactly like [`border_pixel`].
#[allow(clippy::too_many_arguments)]
fn bin_row_border(
    kernel: Kernel,
    rows: &[Option<&[u64]>; 3],
    hw: usize,
    prep: &PreparedBin,
    out_c: usize,
    lo: usize,
    hi: usize,
    x: usize,
    mism: &mut [u64],
) {
    let cw = prep.chan_words;
    let lane = cw * out_c;
    mism.fill(0);
    for t in 0..9usize {
        let sx = x as isize + (t % 3) as isize - 1;
        match rows[t / 3] {
            Some(row) if sx >= 0 && (sx as usize) < hw => {
                let sx = sx as usize;
                accumulate_tap_range(
                    kernel,
                    &row[sx * cw..(sx + 1) * cw],
                    &prep.tap_weights[t * lane..(t + 1) * lane],
                    out_c,
                    lo,
                    hi,
                    mism,
                );
            }
            _ => {
                for (m, &p) in mism.iter_mut().zip(&prep.tap_pop[t * out_c + lo..t * out_c + hi]) {
                    *m += p as u64;
                }
            }
        }
    }
}

/// Write one pixel's match counts (`cnum - mismatches`) into a conv row
/// of `plen` channels per pixel.
fn store_row_pixel(out_row: &mut [i32], mism: &[u64], cnum: i32, plen: usize, x: usize) {
    for (a, &m) in out_row[x * plen..(x + 1) * plen].iter_mut().zip(mism) {
        *a = cnum - m as i32;
    }
}

/// Row-window variant of [`fp_conv3x3_tap_major`] (first layer, eq. 7):
/// true zero padding, tap-major MAC over the transposed ±1 weights,
/// restricted to output channels `[lo, hi)` (compact output stride).
#[allow(clippy::too_many_arguments)]
fn fp_conv_row(
    rows: [Option<&[i32]>; 3],
    hw: usize,
    in_c: usize,
    out_c: usize,
    lo: usize,
    hi: usize,
    weights_t: &[i32],
    pix: &mut [i32],
    out_row: &mut [i32],
) {
    let plen = hi - lo;
    for x in 0..hw {
        pix.fill(0);
        for (kh, row) in rows.iter().enumerate() {
            let Some(row) = row else {
                continue; // true zero padding: clipped taps add nothing
            };
            for kw in 0..3usize {
                let sx = x as isize + kw as isize - 1;
                if sx < 0 || sx >= hw as isize {
                    continue;
                }
                let src = sx as usize * in_c;
                let t = kh * 3 + kw;
                for ch in 0..in_c {
                    let p = row[src + ch];
                    if p == 0 {
                        continue; // zero taps contribute nothing
                    }
                    let wrow =
                        &weights_t[(t * in_c + ch) * out_c + lo..(t * in_c + ch) * out_c + hi];
                    for (a, &w) in pix.iter_mut().zip(wrow) {
                        *a += p * w;
                    }
                }
            }
        }
        out_row[x * plen..(x + 1) * plen].copy_from_slice(pix);
    }
}

/// Fold one full-resolution conv row (channels `[lo, hi)`, compact
/// stride) through the (optional) fused 2x2/2 pool and the NormBinarize
/// threshold, emitting a full-width packed output row with only bits
/// `[lo, hi)` of each pixel set.
///
/// Pooling layers emit one pooled row per *pair* of conv rows: the even
/// row is stashed in `pending`, the odd row maxes against it — the same
/// integers the whole-image kernel's fused `store_pixel` max produces.
#[allow(clippy::too_many_arguments)]
fn finish_conv_row(
    conv_row: &[i32],
    pending: &mut Vec<i32>,
    pooled: &mut Vec<i32>,
    pool: bool,
    y: usize,
    in_hw: usize,
    out_c: usize,
    lo: usize,
    hi: usize,
    thresholds: &[i32],
    emit: &mut dyn FnMut(StepperOut),
) {
    let plen = hi - lo;
    if !pool {
        emit(StepperOut::Row(threshold_row_part(conv_row, in_hw, out_c, lo, hi, thresholds)));
        return;
    }
    if y % 2 == 0 {
        pending.clear();
        pending.extend_from_slice(conv_row);
        return;
    }
    let out_hw = in_hw / 2;
    pooled.clear();
    pooled.resize(out_hw * plen, i32::MIN);
    for px in 0..out_hw {
        let dst = &mut pooled[px * plen..(px + 1) * plen];
        for src in [&pending[2 * px * plen..], &conv_row[2 * px * plen..]] {
            for half in 0..2 {
                for (a, &v) in dst.iter_mut().zip(&src[half * plen..(half + 1) * plen]) {
                    if v > *a {
                        *a = v;
                    }
                }
            }
        }
    }
    pending.clear();
    emit(StepperOut::Row(threshold_row_part(&pooled[..], out_hw, out_c, lo, hi, thresholds)));
}

/// Row variant of [`threshold_into`]: NormBinarize one row of `width`
/// pixels into a freshly-allocated packed row (owned because it is about
/// to cross a stage-thread boundary).  Same [`threshold_pixel`] packing
/// as the whole-image path by construction.
fn threshold_row(acc_row: &[i32], width: usize, c: usize, thresholds: &[i32]) -> Vec<u64> {
    let wpp = words_for(c);
    let mut out = vec![0u64; width * wpp];
    for p in 0..width {
        let words = &mut out[p * wpp..(p + 1) * wpp];
        threshold_pixel(&acc_row[p * c..(p + 1) * c], c, thresholds, words);
    }
    out
}

/// Partition variant of [`threshold_row`]: the accumulator row is compact
/// (`hi - lo` channels per pixel) and the emitted packed row is full
/// width with only bits `[lo, hi)` of each pixel set, so the rows of a
/// disjoint partition cover OR-merge into exactly the unpartitioned
/// [`threshold_row`] output (same `v >= t` compare per channel; the full
/// partition takes the chunked fast path unchanged).
fn threshold_row_part(
    acc_row: &[i32],
    width: usize,
    c: usize,
    lo: usize,
    hi: usize,
    thresholds: &[i32],
) -> Vec<u64> {
    if lo == 0 && hi == c {
        return threshold_row(acc_row, width, c, thresholds);
    }
    let plen = hi - lo;
    let wpp = words_for(c);
    let mut out = vec![0u64; width * wpp];
    for p in 0..width {
        let words = &mut out[p * wpp..(p + 1) * wpp];
        for (i, (&v, &t)) in acc_row[p * plen..(p + 1) * plen]
            .iter()
            .zip(&thresholds[lo..hi])
            .enumerate()
        {
            let ch = lo + i;
            if v >= t {
                words[ch / 64] |= 1u64 << (ch % 64);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// validation & weight preparation

fn validate_layer(index: usize, layer: &LayerWeights) -> std::result::Result<(), ModelError> {
    let len_err = |what: &'static str, got: usize, want: usize| ModelError::VectorLen {
        layer: index,
        what,
        got,
        want,
    };
    match layer {
        LayerWeights::FpConv { in_c, out_c, weights, thresholds, .. } => {
            let k = 9 * *in_c;
            if weights.len() != *out_c * k {
                return Err(len_err("weights", weights.len(), *out_c * k));
            }
            if thresholds.len() != *out_c {
                return Err(len_err("thresholds", thresholds.len(), *out_c));
            }
        }
        LayerWeights::BinConv { in_c, out_c, weights, words_per_row, thresholds, .. } => {
            let want = words_for(9 * *in_c);
            if *words_per_row != want {
                return Err(ModelError::WeightRowWidth { layer: index, got: *words_per_row, want });
            }
            if weights.len() != *out_c * *words_per_row {
                return Err(len_err("weights", weights.len(), *out_c * *words_per_row));
            }
            if thresholds.len() != *out_c {
                return Err(len_err("thresholds", thresholds.len(), *out_c));
            }
        }
        LayerWeights::BinFc { in_f, out_f, weights, words_per_row, thresholds } => {
            let want = words_for(*in_f);
            if *words_per_row != want {
                return Err(ModelError::WeightRowWidth { layer: index, got: *words_per_row, want });
            }
            if weights.len() != *out_f * *words_per_row {
                return Err(len_err("weights", weights.len(), *out_f * *words_per_row));
            }
            if thresholds.len() != *out_f {
                return Err(len_err("thresholds", thresholds.len(), *out_f));
            }
        }
        LayerWeights::BinFcOut { in_f, out_f, weights, words_per_row, scale, bias } => {
            let want = words_for(*in_f);
            if *words_per_row != want {
                return Err(ModelError::WeightRowWidth { layer: index, got: *words_per_row, want });
            }
            if weights.len() != *out_f * *words_per_row {
                return Err(len_err("weights", weights.len(), *out_f * *words_per_row));
            }
            if scale.len() != *out_f {
                return Err(len_err("scale", scale.len(), *out_f));
            }
            if bias.len() != *out_f {
                return Err(len_err("bias", bias.len(), *out_f));
            }
        }
    }
    Ok(())
}

/// `[k][out_c]` transposed i32 first-layer weights (empty for other kinds).
fn prepare_fp(layer: &LayerWeights) -> Vec<i32> {
    match layer {
        LayerWeights::FpConv { in_c, out_c, weights, .. } => {
            let k = 9 * *in_c;
            let mut t = vec![0i32; k * *out_c];
            for n in 0..*out_c {
                for kk in 0..k {
                    t[kk * *out_c + n] = weights[n * k + kk] as i32;
                }
            }
            t
        }
        _ => Vec::new(),
    }
}

/// Tap-major bank for a BinConv layer (None for other kinds).  Assumes the
/// layer already passed [`validate_layer`].
fn prepare_bin(layer: &LayerWeights) -> Option<PreparedBin> {
    let LayerWeights::BinConv { in_c, out_c, weights, words_per_row, .. } = layer else {
        return None;
    };
    let (in_c, out_c, words_per_row) = (*in_c, *out_c, *words_per_row);
    let cw = words_for(in_c);
    let mut tap_weights = vec![0u64; 9 * cw * out_c];
    let mut tap_pop = vec![0u32; 9 * out_c];
    for n in 0..out_c {
        let row = &weights[n * words_per_row..(n + 1) * words_per_row];
        for t in 0..9 {
            let mut pop = 0u32;
            for w in 0..cw {
                let lo = w * 64;
                let nbits = (in_c - lo).min(64);
                // re-align tap t's channel block [t*in_c, (t+1)*in_c) of
                // the packed row to word boundaries
                let bits = read_bits_u64(row, t * in_c + lo, nbits);
                tap_weights[(t * cw + w) * out_c + n] = bits;
                pop += bits.count_ones();
            }
            tap_pop[t * out_c + n] = pop;
        }
    }
    Some(PreparedBin { tap_weights, tap_pop, chan_words: cw })
}

// ---------------------------------------------------------------------------
// the layer step (shared by the zero-alloc pipeline and the owned API)

/// Borrowed activation view — the zero-alloc pipeline never owns planes.
enum ActRef<'a> {
    Int { hw: usize, c: usize, data: &'a [i32] },
    Bits(&'a BitFmap),
}

enum StepOut {
    Act,
    Scores,
}

/// Disjoint mutable views into the [`Scratch`] arena for one layer step.
struct StepBufs<'a> {
    acc: &'a mut Vec<i32>,
    mism: &'a mut Vec<u64>,
    pix: &'a mut Vec<i32>,
    bits_out: &'a mut BitFmap,
    fc_row: &'a mut Vec<u64>,
}

fn step_layer(
    kernel: Kernel,
    layer: &LayerWeights,
    fp_t: &[i32],
    bin: Option<&PreparedBin>,
    input: ActRef<'_>,
    bufs: StepBufs<'_>,
    scores: &mut Vec<f32>,
) -> Result<StepOut> {
    let StepBufs { acc, mism, pix, bits_out, fc_row } = bufs;
    match layer {
        LayerWeights::FpConv { in_c, out_c, pool, thresholds, .. } => {
            let ActRef::Int { hw, c, data } = input else {
                bail!("FpConv expects integer input");
            };
            if c != *in_c {
                bail!("FpConv channel mismatch: {c} != {in_c}");
            }
            if *pool && hw % 2 != 0 {
                bail!("2x2/2 max-pool at odd resolution {hw}");
            }
            let out_hw = fp_conv3x3_tap_major(data, hw, *in_c, *out_c, fp_t, *pool, acc, pix);
            threshold_into(acc, out_hw, *out_c, thresholds, bits_out);
            Ok(StepOut::Act)
        }
        LayerWeights::BinConv { in_c, out_c, pool, thresholds, .. } => {
            let ActRef::Bits(fmap) = input else {
                bail!("BinConv expects binary input");
            };
            if fmap.c != *in_c {
                bail!("BinConv channel mismatch: {} != {in_c}", fmap.c);
            }
            if *pool && fmap.hw % 2 != 0 {
                bail!("2x2/2 max-pool at odd resolution {}", fmap.hw);
            }
            let Some(prep) = bin else {
                bail!("BinConv layer without a prepared tap-major bank");
            };
            let out_hw = bin_conv3x3_tap_major(kernel, fmap, prep, *in_c, *out_c, *pool, acc, mism);
            threshold_into(acc, out_hw, *out_c, thresholds, bits_out);
            Ok(StepOut::Act)
        }
        LayerWeights::BinFc { in_f, out_f, .. } => {
            flatten_act(&input, *in_f, fc_row)?;
            bits_out.reset(1, *out_f);
            bin_fc_select(kernel, layer, &fc_row[..], 0, *out_f, |n| bits_out.set(0, 0, n, true));
            Ok(StepOut::Act)
        }
        LayerWeights::BinFcOut { in_f, out_f, .. } => {
            flatten_act(&input, *in_f, fc_row)?;
            bin_fc_out_scores(kernel, layer, &fc_row[..], 0, *out_f, scores);
            Ok(StepOut::Scores)
        }
    }
}

/// Shared hidden-FC forward (the single implementation behind both the
/// whole-image [`step_layer`] and the row-streaming
/// [`LayerStepper::flush`]): calls `on_set(n)` for every output feature
/// in `[lo, hi)` whose packed-dot-product match count clears its
/// threshold (eq. 8).  Features are computed independently, so a
/// partition's selections equal the full range's for every `n` it owns.
fn bin_fc_select(
    kernel: Kernel,
    layer: &LayerWeights,
    fc_row: &[u64],
    lo: usize,
    hi: usize,
    mut on_set: impl FnMut(usize),
) {
    let LayerWeights::BinFc { in_f, words_per_row, thresholds, .. } = layer else {
        unreachable!("bin_fc_select on a non-BinFc layer");
    };
    for n in lo..hi {
        let w = layer_weight_row(layer, n, *words_per_row);
        let matches = *in_f as i32 - kernel.xor_popcount(fc_row, w) as i32;
        if matches >= thresholds[n] {
            on_set(n);
        }
    }
}

/// Shared classifier forward (affine Norm, paper fig. 3 output layer) —
/// same single-implementation discipline as [`bin_fc_select`].  `scores`
/// receives classes `[lo, hi)` in order; partitions concatenate.
fn bin_fc_out_scores(
    kernel: Kernel,
    layer: &LayerWeights,
    fc_row: &[u64],
    lo: usize,
    hi: usize,
    scores: &mut Vec<f32>,
) {
    let LayerWeights::BinFcOut { in_f, words_per_row, scale, bias, .. } = layer else {
        unreachable!("bin_fc_out_scores on a non-classifier layer");
    };
    scores.clear();
    for n in lo..hi {
        let w = layer_weight_row(layer, n, *words_per_row);
        let matches = *in_f as i32 - kernel.xor_popcount(fc_row, w) as i32;
        scores.push(matches as f32 * scale[n] + bias[n]);
    }
}

/// Owned-output wrapper around [`step_layer`] for the layer-at-a-time API.
fn run_prepared_layer(
    kernel: Kernel,
    layer: &LayerWeights,
    fp_t: &[i32],
    bin: Option<&PreparedBin>,
    input: &Activation,
    scratch: &mut Scratch,
) -> Result<LayerOutput> {
    let input_ref = match input {
        Activation::Int { hw, c, data } => ActRef::Int { hw: *hw, c: *c, data },
        Activation::Bits(f) => ActRef::Bits(f),
    };
    let mut scores = Vec::new();
    let Scratch { acc, mismatch, pix, bits_out, fc_row, .. } = scratch;
    let out = step_layer(
        kernel,
        layer,
        fp_t,
        bin,
        input_ref,
        StepBufs {
            acc: &mut *acc,
            mism: &mut *mismatch,
            pix: &mut *pix,
            bits_out: &mut *bits_out,
            fc_row: &mut *fc_row,
        },
        &mut scores,
    )?;
    Ok(match out {
        StepOut::Act => LayerOutput::Act(Activation::Bits(bits_out.clone())),
        StepOut::Scores => LayerOutput::Scores(scores),
    })
}

fn layer_weight_row<'a>(layer: &'a LayerWeights, n: usize, words_per_row: usize) -> &'a [u64] {
    match layer {
        LayerWeights::BinConv { weights, .. }
        | LayerWeights::BinFc { weights, .. }
        | LayerWeights::BinFcOut { weights, .. } => {
            &weights[n * words_per_row..(n + 1) * words_per_row]
        }
        LayerWeights::FpConv { .. } => unreachable!(),
    }
}

/// Flatten a binary activation into the packed FC input row of `in_f` bits.
fn flatten_act(input: &ActRef<'_>, in_f: usize, out: &mut Vec<u64>) -> Result<()> {
    match input {
        ActRef::Bits(fmap) => {
            let total = fmap.hw * fmap.hw * fmap.c;
            if total != in_f {
                bail!("FC input features {total} != {in_f}");
            }
            fmap.flatten_into(out);
            Ok(())
        }
        ActRef::Int { .. } => bail!("FC layer expects binary input"),
    }
}

// ---------------------------------------------------------------------------
// conv kernels

/// First-layer integer conv (eq. 7): 3x3, stride 1, true zero padding,
/// tap-major over the `[k][out_c]` transposed ±1 weights — each tap's
/// channel values MAC straight out of the input plane (no patch copy)
/// across all filters at unit stride.  `pool` fuses the 2x2/2 max into
/// the output write.  Returns the output resolution.
#[allow(clippy::too_many_arguments)]
fn fp_conv3x3_tap_major(
    data: &[i32],
    hw: usize,
    in_c: usize,
    out_c: usize,
    weights_t: &[i32],
    pool: bool,
    acc: &mut Vec<i32>,
    pix: &mut Vec<i32>,
) -> usize {
    let out_hw = if pool { hw / 2 } else { hw };
    acc.clear();
    acc.resize(out_hw * out_hw * out_c, if pool { i32::MIN } else { 0 });
    pix.clear();
    pix.resize(out_c, 0);
    for y in 0..hw {
        for x in 0..hw {
            pix.fill(0);
            for kh in 0..3usize {
                let sy = y as isize + kh as isize - 1;
                if sy < 0 || sy >= hw as isize {
                    continue; // true zero padding: clipped taps add nothing
                }
                for kw in 0..3usize {
                    let sx = x as isize + kw as isize - 1;
                    if sx < 0 || sx >= hw as isize {
                        continue;
                    }
                    let src = (sy as usize * hw + sx as usize) * in_c;
                    let t = kh * 3 + kw;
                    for ch in 0..in_c {
                        let p = data[src + ch];
                        if p == 0 {
                            continue; // zero taps contribute nothing
                        }
                        let row =
                            &weights_t[(t * in_c + ch) * out_c..(t * in_c + ch + 1) * out_c];
                        for (a, &w) in pix.iter_mut().zip(row) {
                            *a += p * w;
                        }
                    }
                }
            }
            store_pixel_i32(acc, pix, pool, out_hw, out_c, y, x);
        }
    }
    out_hw
}

/// Hidden binary conv, tap-major and gather-free (see module docs).
/// Returns the output resolution (`hw/2` when `pool` is fused).
#[allow(clippy::too_many_arguments)]
fn bin_conv3x3_tap_major(
    kernel: Kernel,
    fmap: &BitFmap,
    prep: &PreparedBin,
    in_c: usize,
    out_c: usize,
    pool: bool,
    acc: &mut Vec<i32>,
    mism: &mut Vec<u64>,
) -> usize {
    let hw = fmap.hw;
    let cnum = (9 * in_c) as i32;
    debug_assert_eq!(prep.chan_words, fmap.words_per_pixel);
    let lane = prep.chan_words * out_c; // words per tap bank
    let out_hw = if pool { hw / 2 } else { hw };
    acc.clear();
    acc.resize(out_hw * out_hw * out_c, if pool { i32::MIN } else { 0 });
    mism.clear();
    mism.resize(out_c, 0);
    let tw = prep.tap_weights.as_slice();
    for y in 0..hw {
        if hw < 3 || y == 0 || y + 1 == hw {
            for x in 0..hw {
                border_pixel(kernel, fmap, prep, out_c, y, x, mism);
                store_pixel(acc, mism, cnum, pool, out_hw, out_c, y, x);
            }
            continue;
        }
        // interior row: only x = 0 and x = hw-1 need border handling
        border_pixel(kernel, fmap, prep, out_c, y, 0, mism);
        store_pixel(acc, mism, cnum, pool, out_hw, out_c, y, 0);
        for x in 1..hw - 1 {
            interior_pixel(kernel, fmap, tw, lane, out_c, y, x, mism);
            store_pixel(acc, mism, cnum, pool, out_hw, out_c, y, x);
        }
        border_pixel(kernel, fmap, prep, out_c, y, hw - 1, mism);
        store_pixel(acc, mism, cnum, pool, out_hw, out_c, y, hw - 1);
    }
    out_hw
}

/// One tap: XOR the pixel's packed channel words against the tap's bank
/// slice, accumulating mismatches per filter lane.
#[inline(always)]
fn accumulate_tap(kernel: Kernel, src: &[u64], tap_bank: &[u64], out_c: usize, mism: &mut [u64]) {
    accumulate_tap_range(kernel, src, tap_bank, out_c, 0, out_c, mism);
}

/// [`accumulate_tap`] restricted to the filter lanes `[lo, hi)` of the
/// tap bank (`mism` holds `hi - lo` lanes) — identical arithmetic per
/// filter, so a partition's counts equal the full kernel's for every
/// channel it owns.  The bank slice is contiguous and unit-stride for
/// any `[lo, hi)`, so partitioned lanes ride the same wide kernel.
#[inline(always)]
fn accumulate_tap_range(
    kernel: Kernel,
    src: &[u64],
    tap_bank: &[u64],
    out_c: usize,
    lo: usize,
    hi: usize,
    mism: &mut [u64],
) {
    for (w, &p) in src.iter().enumerate() {
        kernel.xor_popcount_lanes(p, &tap_bank[w * out_c + lo..w * out_c + hi], mism);
    }
}

/// All 9 taps in bounds: constant-trip, branch-free tap loop.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn interior_pixel(
    kernel: Kernel,
    fmap: &BitFmap,
    tw: &[u64],
    lane: usize,
    out_c: usize,
    y: usize,
    x: usize,
    mism: &mut [u64],
) {
    mism.fill(0);
    for t in 0..9usize {
        // caller guarantees 1 <= y, x <= hw-2, so no bounds checks
        let src = fmap.pixel(y + t / 3 - 1, x + t % 3 - 1);
        accumulate_tap(kernel, src, &tw[t * lane..(t + 1) * lane], out_c, mism);
    }
}

/// Border pixel: clipped taps contribute their precomputed weight
/// popcount (zero activation bits = all -1 padding, paper semantics).
#[inline(always)]
fn border_pixel(
    kernel: Kernel,
    fmap: &BitFmap,
    prep: &PreparedBin,
    out_c: usize,
    y: usize,
    x: usize,
    mism: &mut [u64],
) {
    let hw = fmap.hw as isize;
    let lane = prep.chan_words * out_c;
    mism.fill(0);
    for t in 0..9usize {
        let sy = y as isize + (t / 3) as isize - 1;
        let sx = x as isize + (t % 3) as isize - 1;
        if sy < 0 || sy >= hw || sx < 0 || sx >= hw {
            for (m, &p) in mism.iter_mut().zip(&prep.tap_pop[t * out_c..(t + 1) * out_c]) {
                *m += p as u64;
            }
        } else {
            accumulate_tap(
                kernel,
                fmap.pixel(sy as usize, sx as usize),
                &prep.tap_weights[t * lane..(t + 1) * lane],
                out_c,
                mism,
            );
        }
    }
}

/// Write one output pixel's match counts (`cnum - mismatches`) into the
/// accumulator plane; for pooling layers the 2x2/2 max is fused here, so
/// the plane is already at the pooled resolution.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn store_pixel(
    acc: &mut [i32],
    mism: &[u64],
    cnum: i32,
    pool: bool,
    out_hw: usize,
    out_c: usize,
    y: usize,
    x: usize,
) {
    if pool {
        let dst = ((y / 2) * out_hw + x / 2) * out_c;
        for (a, &m) in acc[dst..dst + out_c].iter_mut().zip(mism) {
            let v = cnum - m as i32;
            if v > *a {
                *a = v;
            }
        }
    } else {
        let dst = (y * out_hw + x) * out_c;
        for (a, &m) in acc[dst..dst + out_c].iter_mut().zip(mism) {
            *a = cnum - m as i32;
        }
    }
}

/// Integer-plane variant of [`store_pixel`] for the first layer.
#[inline(always)]
fn store_pixel_i32(
    acc: &mut [i32],
    vals: &[i32],
    pool: bool,
    out_hw: usize,
    out_c: usize,
    y: usize,
    x: usize,
) {
    if pool {
        let dst = ((y / 2) * out_hw + x / 2) * out_c;
        for (a, &v) in acc[dst..dst + out_c].iter_mut().zip(vals) {
            if v > *a {
                *a = v;
            }
        }
    } else {
        let dst = (y * out_hw + x) * out_c;
        acc[dst..dst + out_c].copy_from_slice(vals);
    }
}

/// NormBinarize (eq. 8) over an integer plane, into a reused [`BitFmap`].
///
/// PERF (EXPERIMENTS.md §Perf iter 3): builds each packed word from a
/// 64-wide chunk of compares instead of per-bit read-modify-writes — the
/// chunked compare loop lowers to AVX512 mask ops (vpcmpd/kmov) and this
/// function fell from ~60% of layer-1 time to noise.
fn threshold_into(y: &[i32], hw: usize, c: usize, thresholds: &[i32], out: &mut BitFmap) {
    // every word (pad bits included) is written in full below, so the
    // reshape skips the redundant zero-fill
    out.reshape_for_overwrite(hw, c);
    let wpp = out.words_per_pixel;
    for p in 0..hw * hw {
        let words = &mut out.data[p * wpp..(p + 1) * wpp];
        threshold_pixel(&y[p * c..(p + 1) * c], c, thresholds, words);
    }
}

/// Pack one pixel's NormBinarize compares into its packed words — the
/// single implementation behind both [`threshold_into`] (whole plane) and
/// [`threshold_row`] (row stream), so the two paths cannot drift.  Every
/// word is written in full (pad bits zero), so callers may skip
/// pre-zeroing; the 64-wide chunked compare is the vectorizable shape the
/// PERF note above describes.
#[inline]
fn threshold_pixel(row: &[i32], c: usize, thresholds: &[i32], words: &mut [u64]) {
    for (w, word_out) in words.iter_mut().enumerate() {
        let lo = w * 64;
        let n = (c - lo).min(64);
        let mut word = 0u64;
        for (b, (&v, &t)) in row[lo..lo + n]
            .iter()
            .zip(&thresholds[lo..lo + n])
            .enumerate()
        {
            word |= ((v >= t) as u64) << b;
        }
        *word_out = word;
    }
}
