//! The packed XNOR+popcount inference engine (paper fig. 3, bit-exact).
//!
//! Per layer: `XnorDotProduct` = `cnum - popcount(patch ^ weights)` over
//! packed `u64` rows (paper eq. 5/6), optional 2x2/2 max-pool on the
//! *integer* accumulator plane, then the folded `NormBinarize` threshold
//! compare (eq. 8).  The first layer is the 6-bit x ±1 integer dot product
//! of eq. 7.  Padding inserts zero bits = -1 activations, keeping
//! `cnum = FW*FH*FD` constant across the border exactly like the paper's
//! fixed-size PE datapath.
//!
//! The engine is allocation-free on the per-image path after construction:
//! patch/accumulator scratch lives in a per-call [`Scratch`] arena that the
//! coordinator reuses across requests.

use anyhow::{bail, Result};

use crate::bcnn::tensor::{Activation, BitFmap};
use crate::model::{BcnnModel, LayerWeights};
use crate::util::bits::{copy_bits, words_for, xor_popcount};

/// Output of one layer.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerOutput {
    Act(Activation),
    /// Classifier scores (only from the final layer).
    Scores(Vec<f32>),
}

/// Reusable scratch buffers (one per worker thread).
#[derive(Debug, Default, Clone)]
pub struct Scratch {
    patch: Vec<u64>,
    int_patch: Vec<i32>,
    mismatch: Vec<u64>,
}

/// Packed-u64 inference engine over a loaded model.
#[derive(Debug, Clone)]
pub struct Engine {
    model: BcnnModel,
    /// PERF (EXPERIMENTS.md §Perf iter 2): first-layer weights transposed
    /// to `[k][out_c]` and widened to i32 at load time, so the per-tap
    /// filter loop is a unit-stride vectorizable MAC over out_c lanes.
    fp_weights_t: Vec<Vec<i32>>,
    /// PERF (EXPERIMENTS.md §Perf iter 4): binary conv weights transposed
    /// to `[word][out_c]` so the XNOR dot products of all filters
    /// accumulate *vertically* (one vpopcntq lane per filter) instead of
    /// horizontally reducing per filter.
    bin_weights_t: Vec<Vec<u64>>,
}

impl Engine {
    pub fn new(model: BcnnModel) -> Self {
        let fp_weights_t = model
            .layers
            .iter()
            .map(|layer| match layer {
                LayerWeights::FpConv { in_c, out_c, weights, .. } => {
                    let k = 9 * in_c;
                    let mut t = vec![0i32; k * out_c];
                    for n in 0..*out_c {
                        for kk in 0..k {
                            t[kk * out_c + n] = weights[n * k + kk] as i32;
                        }
                    }
                    t
                }
                _ => Vec::new(),
            })
            .collect();
        let bin_weights_t = model
            .layers
            .iter()
            .map(|layer| match layer {
                LayerWeights::BinConv { out_c, weights, words_per_row, .. } => {
                    let mut t = vec![0u64; weights.len()];
                    for n in 0..*out_c {
                        for w in 0..*words_per_row {
                            t[w * out_c + n] = weights[n * words_per_row + w];
                        }
                    }
                    t
                }
                _ => Vec::new(),
            })
            .collect();
        Self { model, fp_weights_t, bin_weights_t }
    }

    pub fn model(&self) -> &BcnnModel {
        &self.model
    }

    /// Classify one image (`hw*hw*input_channels` NHWC int values in the
    /// 6-bit range).  Returns per-class scores.
    pub fn infer(&self, image: &[i32]) -> Result<Vec<f32>> {
        self.infer_with_scratch(image, &mut Scratch::default())
    }

    /// Allocation-reusing variant for the serving hot path.
    pub fn infer_with_scratch(&self, image: &[i32], scratch: &mut Scratch) -> Result<Vec<f32>> {
        let hw = self.model.input_hw;
        let c = self.model.input_channels;
        if image.len() != hw * hw * c {
            bail!("image size {} != {}", image.len(), hw * hw * c);
        }
        let mut act = Activation::Int { hw, c, data: image.to_vec() };
        for i in 0..self.model.layers.len() {
            match self.run_layer_at(i, &act, scratch)? {
                LayerOutput::Act(next) => act = next,
                LayerOutput::Scores(s) => {
                    if i + 1 != self.model.layers.len() {
                        bail!("classifier layer {i} is not last");
                    }
                    return Ok(s);
                }
            }
        }
        bail!("model has no classifier layer")
    }

    /// Batch inference (images processed independently; the FPGA streaming
    /// architecture is batch-insensitive, and so is this loop).  Accepts
    /// owned (`Vec<i32>`) or borrowed (`&[i32]`) image rows.
    pub fn infer_batch<I: AsRef<[i32]>>(&self, images: &[I]) -> Result<Vec<Vec<f32>>> {
        let mut scratch = Scratch::default();
        images
            .iter()
            .map(|img| self.infer_with_scratch(img.as_ref(), &mut scratch))
            .collect()
    }

    /// Run the model's layer `index` — the layer-by-index API used by the
    /// inference loop, the FPGA phase simulator, and the per-layer benches.
    /// The transposed-weight fast paths are selected by index (no pointer
    /// identity games), so they engage for every caller.
    pub fn run_layer_at(
        &self,
        index: usize,
        input: &Activation,
        scratch: &mut Scratch,
    ) -> Result<LayerOutput> {
        let Some(layer) = self.model.layers.get(index) else {
            bail!("layer index {index} out of range ({} layers)", self.model.layers.len());
        };
        let fp_t = self.fp_weights_t[index].as_slice();
        let bin_t = self.bin_weights_t[index].as_slice();
        self.run_layer_impl(
            layer,
            (!fp_t.is_empty()).then_some(fp_t),
            (!bin_t.is_empty()).then_some(bin_t),
            input,
            scratch,
        )
    }

    /// Run an arbitrary layer value through the portable (untransposed)
    /// path.  Prefer [`Engine::run_layer_at`] for the model's own layers —
    /// it engages the prepared-weight fast paths.
    pub fn run_layer(&self, layer: &LayerWeights, input: &Activation) -> Result<LayerOutput> {
        self.run_layer_impl(layer, None, None, input, &mut Scratch::default())
    }

    fn run_layer_impl(
        &self,
        layer: &LayerWeights,
        fp_transposed: Option<&[i32]>,
        bin_transposed: Option<&[u64]>,
        input: &Activation,
        scratch: &mut Scratch,
    ) -> Result<LayerOutput> {
        match layer {
            LayerWeights::FpConv { in_c, out_c, pool, weights, thresholds } => {
                let Activation::Int { hw, c, data } = input else {
                    bail!("FpConv expects integer input");
                };
                if c != in_c {
                    bail!("FpConv channel mismatch: {c} != {in_c}");
                }
                let y = match fp_transposed {
                    Some(wt) => fp_conv3x3_transposed(data, *hw, *in_c, *out_c, wt, scratch),
                    None => fp_conv3x3(data, *hw, *in_c, *out_c, weights, scratch),
                };
                let (y, out_hw) = maybe_pool(y, *hw, *out_c, *pool);
                Ok(LayerOutput::Act(Activation::Bits(threshold_plane(
                    &y, out_hw, *out_c, thresholds,
                ))))
            }
            LayerWeights::BinConv { in_c, out_c, pool, words_per_row, thresholds, .. } => {
                let Activation::Bits(fmap) = input else {
                    bail!("BinConv expects binary input");
                };
                if fmap.c != *in_c {
                    bail!("BinConv channel mismatch: {} != {in_c}", fmap.c);
                }
                let transposed = bin_transposed;
                // (PERF iter 5, REVERTED: fusing NormBinarize into the
                // conv loop for non-pooling layers measured -3% — the
                // accumulator plane is L2-resident, so skipping it bought
                // nothing.  See EXPERIMENTS.md §Perf.)
                let y = match transposed {
                    Some(wt) => bin_conv3x3_transposed(
                        fmap,
                        wt,
                        *in_c,
                        *out_c,
                        *words_per_row,
                        scratch,
                    ),
                    None => bin_conv3x3(fmap, layer, *in_c, *out_c, *words_per_row, scratch),
                };
                let (y, out_hw) = maybe_pool(y, fmap.hw, *out_c, *pool);
                Ok(LayerOutput::Act(Activation::Bits(threshold_plane(
                    &y, out_hw, *out_c, thresholds,
                ))))
            }
            LayerWeights::BinFc { in_f, out_f, words_per_row, thresholds, .. } => {
                let row = flatten_input(input, *in_f)?;
                let mut bits = BitFmap::zeros(1, *out_f);
                for n in 0..*out_f {
                    let w = layer_weight_row(layer, n, *words_per_row);
                    let matches = *in_f as i32 - xor_popcount(&row, w) as i32;
                    bits.set(0, 0, n, matches >= thresholds[n]);
                }
                Ok(LayerOutput::Act(Activation::Bits(bits)))
            }
            LayerWeights::BinFcOut { in_f, out_f, words_per_row, scale, bias, .. } => {
                let row = flatten_input(input, *in_f)?;
                let mut scores = Vec::with_capacity(*out_f);
                for n in 0..*out_f {
                    let w = layer_weight_row(layer, n, *words_per_row);
                    let matches = *in_f as i32 - xor_popcount(&row, w) as i32;
                    scores.push(matches as f32 * scale[n] + bias[n]);
                }
                Ok(LayerOutput::Scores(scores))
            }
        }
    }
}

fn layer_weight_row<'a>(layer: &'a LayerWeights, n: usize, words_per_row: usize) -> &'a [u64] {
    match layer {
        LayerWeights::BinConv { weights, .. }
        | LayerWeights::BinFc { weights, .. }
        | LayerWeights::BinFcOut { weights, .. } => {
            &weights[n * words_per_row..(n + 1) * words_per_row]
        }
        LayerWeights::FpConv { .. } => unreachable!(),
    }
}

/// First-layer integer conv (eq. 7): 3x3, stride 1, true zero padding.
fn fp_conv3x3(
    data: &[i32],
    hw: usize,
    in_c: usize,
    out_c: usize,
    weights: &[i8],
    scratch: &mut Scratch,
) -> Vec<i32> {
    let k = 9 * in_c;
    scratch.int_patch.resize(k, 0);
    let mut out = vec![0i32; hw * hw * out_c];
    for y in 0..hw {
        for x in 0..hw {
            let patch = &mut scratch.int_patch;
            patch.iter_mut().for_each(|v| *v = 0);
            for kh in 0..3usize {
                let sy = y as isize + kh as isize - 1;
                if sy < 0 || sy >= hw as isize {
                    continue;
                }
                for kw in 0..3usize {
                    let sx = x as isize + kw as isize - 1;
                    if sx < 0 || sx >= hw as isize {
                        continue;
                    }
                    let src = (sy as usize * hw + sx as usize) * in_c;
                    let dst = (kh * 3 + kw) * in_c;
                    patch[dst..dst + in_c].copy_from_slice(&data[src..src + in_c]);
                }
            }
            let base = (y * hw + x) * out_c;
            for n in 0..out_c {
                let w = &weights[n * k..(n + 1) * k];
                let mut acc = 0i32;
                for (p, wv) in patch.iter().zip(w.iter()) {
                    acc += p * (*wv as i32);
                }
                out[base + n] = acc;
            }
        }
    }
    out
}

/// First-layer integer conv with `[k][out_c]` transposed ±1 weights: for
/// each patch tap, a unit-stride MAC across all filters (vectorizes to
/// i32 lanes; PERF iter 2).
fn fp_conv3x3_transposed(
    data: &[i32],
    hw: usize,
    in_c: usize,
    out_c: usize,
    weights_t: &[i32],
    scratch: &mut Scratch,
) -> Vec<i32> {
    let k = 9 * in_c;
    scratch.int_patch.resize(k, 0);
    let mut out = vec![0i32; hw * hw * out_c];
    for y in 0..hw {
        for x in 0..hw {
            let patch = &mut scratch.int_patch;
            patch.iter_mut().for_each(|v| *v = 0);
            for kh in 0..3usize {
                let sy = y as isize + kh as isize - 1;
                if sy < 0 || sy >= hw as isize {
                    continue;
                }
                for kw in 0..3usize {
                    let sx = x as isize + kw as isize - 1;
                    if sx < 0 || sx >= hw as isize {
                        continue;
                    }
                    let src = (sy as usize * hw + sx as usize) * in_c;
                    let dst = (kh * 3 + kw) * in_c;
                    patch[dst..dst + in_c].copy_from_slice(&data[src..src + in_c]);
                }
            }
            let acc = &mut out[(y * hw + x) * out_c..(y * hw + x + 1) * out_c];
            for (kk, &p) in patch.iter().enumerate() {
                if p == 0 {
                    continue; // padded taps contribute nothing
                }
                let w_row = &weights_t[kk * out_c..(kk + 1) * out_c];
                for (a, &w) in acc.iter_mut().zip(w_row) {
                    *a += p * w;
                }
            }
        }
    }
    out
}

/// Hidden binary conv: packed patch gather + XNOR dot product.
fn bin_conv3x3(
    fmap: &BitFmap,
    layer: &LayerWeights,
    in_c: usize,
    out_c: usize,
    words_per_row: usize,
    scratch: &mut Scratch,
) -> Vec<i32> {
    let hw = fmap.hw;
    let k = 9 * in_c;
    let cnum = k as i32;
    let patch_words = words_for(k);
    scratch.patch.resize(patch_words, 0);
    let mut out = vec![0i32; hw * hw * out_c];
    for y in 0..hw {
        for x in 0..hw {
            let patch = &mut scratch.patch;
            patch.iter_mut().for_each(|v| *v = 0);
            for kh in 0..3usize {
                let sy = y as isize + kh as isize - 1;
                if sy < 0 || sy >= hw as isize {
                    continue; // zero bits = -1 activations (paper padding)
                }
                for kw in 0..3usize {
                    let sx = x as isize + kw as isize - 1;
                    if sx < 0 || sx >= hw as isize {
                        continue;
                    }
                    let src = fmap.pixel(sy as usize, sx as usize);
                    copy_bits(patch, (kh * 3 + kw) * in_c, src, 0, in_c);
                }
            }
            let base = (y * hw + x) * out_c;
            for n in 0..out_c {
                let w = layer_weight_row(layer, n, words_per_row);
                out[base + n] = cnum - xor_popcount(patch, w) as i32;
            }
        }
    }
    out
}

/// Hidden binary conv with `[word][out_c]` transposed weights (PERF iter
/// 4): for each patch word, XOR it (broadcast) against the same word of
/// all filters and accumulate popcounts per filter — unit-stride over the
/// transposed weights, so the whole filter bank advances through AVX512
/// vpopcntq lanes with no horizontal reductions.
fn bin_conv3x3_transposed(
    fmap: &BitFmap,
    weights_t: &[u64],
    in_c: usize,
    out_c: usize,
    words_per_row: usize,
    scratch: &mut Scratch,
) -> Vec<i32> {
    let hw = fmap.hw;
    let k = 9 * in_c;
    let cnum = k as i32;
    let patch_words = words_for(k);
    debug_assert!(patch_words <= words_per_row || patch_words == words_per_row);
    scratch.patch.resize(patch_words, 0);
    scratch.mismatch.resize(out_c, 0);
    let mut out = vec![0i32; hw * hw * out_c];
    for y in 0..hw {
        for x in 0..hw {
            let patch = &mut scratch.patch;
            patch.iter_mut().for_each(|v| *v = 0);
            for kh in 0..3usize {
                let sy = y as isize + kh as isize - 1;
                if sy < 0 || sy >= hw as isize {
                    continue; // zero bits = -1 activations (paper padding)
                }
                for kw in 0..3usize {
                    let sx = x as isize + kw as isize - 1;
                    if sx < 0 || sx >= hw as isize {
                        continue;
                    }
                    let src = fmap.pixel(sy as usize, sx as usize);
                    copy_bits(patch, (kh * 3 + kw) * in_c, src, 0, in_c);
                }
            }
            let mism = &mut scratch.mismatch;
            mism.iter_mut().for_each(|v| *v = 0);
            for (w, &p) in patch.iter().enumerate() {
                let row = &weights_t[w * out_c..(w + 1) * out_c];
                for (m, &wv) in mism.iter_mut().zip(row) {
                    *m += (p ^ wv).count_ones() as u64;
                }
            }
            let base = (y * hw + x) * out_c;
            for (o, &m) in out[base..base + out_c].iter_mut().zip(mism.iter()) {
                *o = cnum - m as i32;
            }
        }
    }
    out
}

/// Max-pool 2x2/2 over an integer plane if `pool`, else pass through.
fn maybe_pool(y: Vec<i32>, hw: usize, c: usize, pool: bool) -> (Vec<i32>, usize) {
    if !pool {
        return (y, hw);
    }
    let oh = hw / 2;
    let mut out = vec![i32::MIN; oh * oh * c];
    for py in 0..oh {
        for px in 0..oh {
            for dy in 0..2 {
                for dx in 0..2 {
                    let src = ((py * 2 + dy) * hw + px * 2 + dx) * c;
                    let dst = (py * oh + px) * c;
                    for ch in 0..c {
                        let v = y[src + ch];
                        if v > out[dst + ch] {
                            out[dst + ch] = v;
                        }
                    }
                }
            }
        }
    }
    (out, oh)
}

/// NormBinarize (eq. 8) over an integer plane.
///
/// PERF (EXPERIMENTS.md §Perf iter 3): builds each packed word from a
/// 64-wide chunk of compares instead of per-bit read-modify-writes — the
/// chunked compare loop lowers to AVX512 mask ops (vpcmpd/kmov) and this
/// function fell from ~60% of layer-1 time to noise.
fn threshold_plane(y: &[i32], hw: usize, c: usize, thresholds: &[i32]) -> BitFmap {
    let mut bits = BitFmap::zeros(hw, c);
    let wpp = bits.words_per_pixel;
    for pix in 0..hw * hw {
        let row = &y[pix * c..(pix + 1) * c];
        let out = &mut bits.data[pix * wpp..(pix + 1) * wpp];
        for (w, word_out) in out.iter_mut().enumerate() {
            let lo = w * 64;
            let n = (c - lo).min(64);
            let mut word = 0u64;
            for (b, (&v, &t)) in row[lo..lo + n]
                .iter()
                .zip(&thresholds[lo..lo + n])
                .enumerate()
            {
                word |= ((v >= t) as u64) << b;
            }
            *word_out = word;
        }
    }
    bits
}

/// Flatten any activation into a packed FC input row of `in_f` bits.
fn flatten_input(input: &Activation, in_f: usize) -> Result<Vec<u64>> {
    match input {
        Activation::Bits(fmap) => {
            let total = fmap.hw * fmap.hw * fmap.c;
            if total != in_f {
                bail!("FC input features {total} != {in_f}");
            }
            Ok(fmap.flatten())
        }
        Activation::Int { .. } => bail!("FC layer expects binary input"),
    }
}
