//! Event-driven TCP front-end: a hand-rolled epoll reactor.
//!
//! Replaces the thread-per-connection accept loops with a small fixed pool
//! of event-loop threads owning *nonblocking* multiplexed connections —
//! the front-end shape the paper's host needs so thousands of online
//! clients can hit the batch-insensitive datapath without a thread each.
//!
//! Design:
//!
//! * **Raw syscalls, no new deps.**  `epoll_create1`/`epoll_ctl`/
//!   `epoll_wait`/`eventfd` via a thin `extern "C"` shim (std already
//!   links libc on Linux).  Non-Linux builds keep the full protocol stack
//!   but [`run_reactor`] reports unsupported and callers fall back to the
//!   threaded accept loop ([`reactor_supported`]).
//! * **Incremental frame decoding.**  Protocol logic lives behind
//!   [`FrameService`]: the reactor hands it the connection's buffered
//!   bytes, the service replies [`FrameOutcome`] — consume a frame, need
//!   more bytes, start an oversized-payload discard, or close.  Requests
//!   pipeline freely on one connection.
//! * **Responses matched by request id.**  The reactor assigns each
//!   decoded frame a per-connection sequence number; asynchronous replies
//!   come back through a [`CompletionQueue`] (eventfd-woken) tagged with
//!   that id, and a `BTreeMap` reorder stage guarantees replies hit the
//!   wire in request order even when shards finish out of order.
//! * **Write backpressure by interest re-registration.**  A slow reader's
//!   outbound buffer crossing the high-water mark pauses that
//!   connection's *read* interest (counted in
//!   [`FrontendStats::paused_reads`]) instead of blocking the loop;
//!   drained buffers re-arm it.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::qos::FrontendStats;
use crate::util::sync::lock_recover;

/// True when this build can run the epoll reactor (Linux).  Callers fall
/// back to the threaded accept loop when false.
pub fn reactor_supported() -> bool {
    cfg!(target_os = "linux")
}

// ---------------------------------------------------------------------------
// Service interface (cross-platform: protocol impls compile everywhere)

/// What a [`FrameService`] decided about the bytes it was shown.
pub enum FrameOutcome {
    /// No complete frame yet — wait for more bytes.
    Incomplete,
    /// Consumed `.0` bytes; reply with `.1` immediately (in sequence).
    Reply(usize, Vec<u8>),
    /// Consumed `.0` bytes; an asynchronous reply will arrive later on the
    /// ticket's completion queue under this frame's sequence number.
    Pending(usize),
    /// Consumed `consumed` bytes of header; swallow the next `skip` raw
    /// payload bytes without parsing, replying `reply` first (oversized
    /// frame: typed error, connection stays alive).
    Discard { consumed: usize, skip: u64, reply: Vec<u8> },
    /// Consumed `.0` bytes; clean client-initiated shutdown — flush
    /// whatever is in flight, then close.
    Close(usize),
    /// Consumed `.0` bytes; reply with `.1`, then close (unrecoverable
    /// framing: resynchronization is impossible).
    Fatal(usize, Vec<u8>),
}

/// Handle a service uses to deliver an asynchronous reply for one frame.
/// Cheap to clone; delivering more than once for the same ticket would
/// wedge the connection's reorder stage, so services must deliver exactly
/// once per `Pending` outcome.
#[derive(Clone)]
pub struct ReplyTicket {
    queue: Arc<CompletionQueue>,
    token: u64,
    seq: u64,
    trace_id: u64,
}

impl ReplyTicket {
    /// The trace id minted for this frame (threads read→dispatch→write
    /// spans together; services carry it into wire replies).
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    /// Deliver the wire reply for this frame (thread-safe, any thread).
    pub fn deliver(&self, bytes: Vec<u8>) {
        self.queue.push(Completion {
            token: self.token,
            seq: self.seq,
            trace_id: self.trace_id,
            t_push_ns: crate::obs::now_ns(),
            bytes,
        });
    }
}

/// A wire protocol served by the reactor: incremental decode + dispatch.
pub trait FrameService: Send + Sync {
    /// Inspect `buf` (everything buffered on one connection).  If it holds
    /// a complete frame, consume and act on it; `ticket` is this frame's
    /// reply handle (only meaningful for [`FrameOutcome::Pending`]).
    fn on_frame(&self, buf: &[u8], ticket: ReplyTicket) -> FrameOutcome;

    /// Called once per event-loop iteration on every loop thread (QoS
    /// pump, registry housekeeping).  Return `true` while queued work
    /// remains so the loop polls with a short timeout.
    fn on_loop_tick(&self) -> bool {
        false
    }

    /// Called once after every loop thread has exited (drain queued
    /// admissions with typed replies).
    fn on_shutdown(&self) {}
}

// ---------------------------------------------------------------------------
// Completion queue: async replies routed back to the owning loop thread

struct Completion {
    token: u64,
    seq: u64,
    trace_id: u64,
    t_push_ns: u64,
    bytes: Vec<u8>,
}

/// Per-loop-thread completion mailbox.  Owns the eventfd that wakes its
/// loop (kept alive by the `Arc` inside every outstanding [`ReplyTicket`],
/// so a late completion can never write into a recycled fd).
pub struct CompletionQueue {
    items: Mutex<Vec<Completion>>,
    wake: WakeFd,
}

impl CompletionQueue {
    fn new() -> std::io::Result<Arc<CompletionQueue>> {
        Ok(Arc::new(CompletionQueue { items: Mutex::new(Vec::new()), wake: WakeFd::new()? }))
    }

    fn push(&self, c: Completion) {
        lock_recover(&self.items).push(c);
        self.wake.wake();
    }

    fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *lock_recover(&self.items))
    }
}

// ---------------------------------------------------------------------------
// Linux: eventfd + epoll wrappers

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_MOD: c_int = 3;

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EFD_CLOEXEC: c_int = 0o2000000;
    pub const EFD_NONBLOCK: c_int = 0o4000;

    /// Mirrors `struct epoll_event`; packed on x86_64 (kernel ABI).
    #[derive(Clone, Copy)]
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn eventfd(initval: u32, flags: c_int) -> c_int;
        pub fn close(fd: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }

    pub fn cvt(ret: c_int) -> std::io::Result<c_int> {
        if ret < 0 {
            Err(std::io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }
}

/// Eventfd-backed waker (no-op stub off Linux so the service types still
/// compile; the reactor itself never runs there).
#[cfg(target_os = "linux")]
struct WakeFd {
    fd: i32,
}

#[cfg(target_os = "linux")]
impl WakeFd {
    fn new() -> std::io::Result<WakeFd> {
        let fd = sys::cvt(unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) })?;
        Ok(WakeFd { fd })
    }

    fn wake(&self) {
        let one: u64 = 1;
        // EAGAIN (counter saturated) still leaves the fd readable: fine
        unsafe {
            let _ = sys::write(self.fd, (&one as *const u64).cast(), 8);
        }
    }

    fn drain(&self) {
        let mut buf = [0u8; 8];
        loop {
            let n = unsafe { sys::read(self.fd, buf.as_mut_ptr().cast(), 8) };
            if n <= 0 {
                break;
            }
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.fd);
        }
    }
}

#[cfg(not(target_os = "linux"))]
struct WakeFd;

#[cfg(not(target_os = "linux"))]
impl WakeFd {
    fn new() -> std::io::Result<WakeFd> {
        Ok(WakeFd)
    }

    fn wake(&self) {}
}

#[cfg(target_os = "linux")]
struct Epoll {
    fd: i32,
}

#[cfg(target_os = "linux")]
impl Epoll {
    fn new() -> std::io::Result<Epoll> {
        let fd = sys::cvt(unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: std::os::raw::c_int, fd: i32, token: u64, mask: u32) -> std::io::Result<()> {
        let mut ev = sys::EpollEvent { events: mask, data: token };
        sys::cvt(unsafe { sys::epoll_ctl(self.fd, op, fd, &mut ev) })?;
        Ok(())
    }

    fn add(&self, fd: i32, token: u64, mask: u32) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, mask)
    }

    fn modify(&self, fd: i32, token: u64, mask: u32) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, mask)
    }

    fn wait(&self, events: &mut [sys::EpollEvent], timeout_ms: i32) -> std::io::Result<usize> {
        loop {
            let n = unsafe {
                sys::epoll_wait(self.fd, events.as_mut_ptr(), events.len() as i32, timeout_ms)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.fd);
        }
    }
}

// ---------------------------------------------------------------------------
// Reactor entry point

/// Run the reactor: the calling thread becomes the accept loop, `threads`
/// event-loop workers own the connections (round-robin handoff).  Returns
/// when `stop` is set and every worker has exited; `on_idle` runs on the
/// accept thread between accepts (registry housekeeping).
///
/// Off Linux this errors immediately — check [`reactor_supported`] first.
#[cfg(not(target_os = "linux"))]
pub fn run_reactor(
    _listener: std::net::TcpListener,
    _stop: Arc<std::sync::atomic::AtomicBool>,
    _service: Arc<dyn FrameService>,
    _threads: usize,
    _stats: Arc<FrontendStats>,
    _on_idle: impl FnMut(),
) -> anyhow::Result<()> {
    anyhow::bail!("epoll reactor unsupported on this platform (use the threaded front-end)")
}

#[cfg(target_os = "linux")]
pub fn run_reactor(
    listener: std::net::TcpListener,
    stop: Arc<std::sync::atomic::AtomicBool>,
    service: Arc<dyn FrameService>,
    threads: usize,
    stats: Arc<FrontendStats>,
    mut on_idle: impl FnMut(),
) -> anyhow::Result<()> {
    use anyhow::Context;

    let threads = threads.max(1);
    let instance = crate::obs::next_instance_id();
    stats.reactor_threads.store(threads, Ordering::Relaxed);

    // build all workers up front so fd allocation failures surface here
    let mut workers = Vec::with_capacity(threads);
    for i in 0..threads {
        workers.push(Worker::new(i as u32, instance, Arc::clone(&service), Arc::clone(&stats))?)
    }
    let inboxes: Vec<(Arc<Mutex<Vec<std::net::TcpStream>>>, Arc<CompletionQueue>)> =
        workers.iter().map(|w| (Arc::clone(&w.incoming), Arc::clone(&w.comp))).collect();

    let worker_err: Arc<Mutex<Option<std::io::Error>>> = Arc::new(Mutex::new(None));
    let handles: Vec<std::thread::JoinHandle<()>> = workers
        .into_iter()
        .map(|mut w| {
            let stop = Arc::clone(&stop);
            let err_slot = Arc::clone(&worker_err);
            std::thread::Builder::new()
                .name(format!("reactor{}", w.index))
                .spawn(move || {
                    // A worker that exits for any reason — epoll failure or
                    // a panic unwinding through it — must stop the whole
                    // front-end: the accept thread would otherwise keep
                    // round-robin-assigning sockets into a loop nobody
                    // runs, hanging those clients silently.
                    struct StopOnExit(Arc<std::sync::atomic::AtomicBool>);
                    impl Drop for StopOnExit {
                        fn drop(&mut self) {
                            self.0.store(true, Ordering::Relaxed);
                        }
                    }
                    let _guard = StopOnExit(Arc::clone(&stop));
                    if let Err(e) = w.run(&stop) {
                        let mut slot = lock_recover(&err_slot);
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                    }
                })
                .expect("spawn reactor worker")
        })
        .collect();

    listener.set_nonblocking(true).context("nonblocking listener")?;
    let mut rr = 0usize;
    let mut accept_err = None;
    let mut last_transient_log: Option<std::time::Instant> = None;
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let (inbox, comp) = &inboxes[rr % inboxes.len()];
                rr = rr.wrapping_add(1);
                lock_recover(inbox).push(stream);
                comp.wake.wake();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                on_idle();
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) if accept_transient(&e) => {
                // aborted handshakes and fd exhaustion are per-connection
                // or momentary; killing the listener for them would take
                // the whole front-end down.  Back off a beat and keep
                // accepting (log rate-limited — EMFILE can persist).
                if last_transient_log.map_or(true, |t| t.elapsed() >= Duration::from_secs(1)) {
                    eprintln!("frontend accept: transient error (continuing): {e}");
                    last_transient_log = Some(std::time::Instant::now());
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                accept_err = Some(e);
                stop.store(true, Ordering::Relaxed);
            }
        }
    }
    // wake everyone so the stop flag is seen promptly
    for (_, comp) in &inboxes {
        comp.wake.wake();
    }
    // join everything and run the shutdown drain even when a worker
    // panicked: queued admissions still get their typed replies (the
    // one-reply-per-admitted-request invariant survives crashes)
    let mut panic_err: Option<anyhow::Error> = None;
    for h in handles {
        if let Err(p) = h.join() {
            let msg = crate::util::sync::panic_message(&*p);
            panic_err.get_or_insert_with(|| anyhow::anyhow!("reactor worker panicked: {msg}"));
        }
    }
    service.on_shutdown();
    if let Some(e) = panic_err {
        return Err(e);
    }
    if let Some(e) = lock_recover(&worker_err).take() {
        return Err(anyhow::anyhow!("reactor worker event loop failed: {e}"));
    }
    match accept_err {
        Some(e) => Err(anyhow::anyhow!("accept: {e}")),
        None => Ok(()),
    }
}

/// Accept errors that must not tear the listener down: the kernel reports
/// these for a single doomed connection (peer aborted the handshake) or a
/// momentary resource shortage (out of fds at the 1k+-connection scale
/// this front-end targets), and `accept` is immediately usable again.
#[cfg(target_os = "linux")]
fn accept_transient(e: &std::io::Error) -> bool {
    use std::io::ErrorKind;
    if matches!(
        e.kind(),
        ErrorKind::ConnectionAborted | ErrorKind::ConnectionReset | ErrorKind::Interrupted
    ) {
        return true;
    }
    // raw Linux errno: ENOMEM, ENFILE, EMFILE, EPROTO, ENOBUFS
    matches!(e.raw_os_error(), Some(12 | 23 | 24 | 71 | 105))
}

// ---------------------------------------------------------------------------
// Worker: one event loop thread

/// Outbound buffer high-water mark: beyond this the connection's read
/// interest is paused (write backpressure) until it drains below
/// [`WBUF_LOW`].  Deliberately small so a slow reader trips it quickly.
#[cfg(target_os = "linux")]
const WBUF_HIGH: usize = 64 * 1024;
#[cfg(target_os = "linux")]
const WBUF_LOW: usize = 16 * 1024;

/// Max reads (of `READ_CHUNK`) per readiness event: bounds time spent on
/// one connection so a firehose peer cannot starve its loop siblings
/// (level-triggered epoll re-reports leftover data immediately).
#[cfg(target_os = "linux")]
const READS_PER_EVENT: usize = 4;
#[cfg(target_os = "linux")]
const READ_CHUNK: usize = 64 * 1024;

/// Oversized-payload discards must complete within this bound or the
/// connection is dropped (mirrors the threaded path's `DISCARD_TIMEOUT`).
#[cfg(target_os = "linux")]
const DISCARD_TIMEOUT: Duration = Duration::from_secs(10);

#[cfg(target_os = "linux")]
const WAKE_TOKEN: u64 = u64::MAX;

#[cfg(target_os = "linux")]
struct Worker {
    index: u32,
    ep: Epoll,
    conns: std::collections::HashMap<u64, Conn>,
    comp: Arc<CompletionQueue>,
    incoming: Arc<Mutex<Vec<std::net::TcpStream>>>,
    service: Arc<dyn FrameService>,
    stats: Arc<FrontendStats>,
    ring: Arc<crate::obs::SpanRing>,
    next_token: u64,
}

#[cfg(target_os = "linux")]
impl Worker {
    fn new(
        index: u32,
        instance: u32,
        service: Arc<dyn FrameService>,
        stats: Arc<FrontendStats>,
    ) -> std::io::Result<Worker> {
        let ep = Epoll::new()?;
        let comp = CompletionQueue::new()?;
        ep.add(comp.wake.fd, WAKE_TOKEN, sys::EPOLLIN)?;
        Ok(Worker {
            index,
            ep,
            conns: std::collections::HashMap::new(),
            comp,
            incoming: Arc::new(Mutex::new(Vec::new())),
            service,
            stats,
            ring: crate::obs::SpanRing::new(
                format!("frontend{instance}/loop{index}"),
                crate::obs::DEFAULT_RING_CAPACITY,
            ),
            // workers interleave token allocation: token % threads == index
            next_token: u64::from(index),
        })
    }

    fn run(&mut self, stop: &std::sync::atomic::AtomicBool) -> std::io::Result<()> {
        let mut events = vec![sys::EpollEvent { events: 0, data: 0 }; 256];
        let mut scratch = vec![0u8; READ_CHUNK];
        let mut lanes_pending = false;
        let mut result = Ok(());
        while !stop.load(Ordering::Relaxed) {
            let timeout = if lanes_pending { 1 } else { 10 };
            let n = match self.ep.wait(&mut events, timeout) {
                Ok(n) => n,
                Err(e) => {
                    // surfaced to run_reactor; the spawn wrapper's stop
                    // guard tears the whole front-end down with us
                    result = Err(e);
                    break;
                }
            };
            for i in 0..n {
                let ev = events[i];
                let token = ev.data;
                let bits = ev.events;
                if token == WAKE_TOKEN {
                    self.comp.wake.drain();
                    continue;
                }
                if bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
                    self.drop_conn(token);
                    continue;
                }
                if bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0 {
                    self.read_token(token, &mut scratch);
                }
                if bits & sys::EPOLLOUT != 0 {
                    self.flush_token(token);
                }
            }
            self.adopt_incoming();
            self.route_completions();
            lanes_pending = self.service.on_loop_tick();
            self.sweep_discards();
        }
        // shutdown: connections drop (close); queued replies are lost the
        // same way the threaded path loses them — peers see EOF
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            self.drop_conn(t);
        }
        result
    }

    fn adopt_incoming(&mut self) {
        let fresh: Vec<std::net::TcpStream> = {
            let mut inbox = lock_recover(&self.incoming);
            std::mem::take(&mut *inbox)
        };
        for stream in fresh {
            if stream.set_nonblocking(true).is_err() {
                continue;
            }
            let _ = stream.set_nodelay(true);
            let token = self.next_token;
            // stride by a constant so tokens stay unique per worker without
            // cross-thread coordination (worker w owns token % stride == w)
            self.next_token = self.next_token.wrapping_add(TOKEN_STRIDE);
            let fd = {
                use std::os::unix::io::AsRawFd;
                stream.as_raw_fd()
            };
            let conn = Conn::new(stream, token);
            if self.ep.add(fd, token, conn.mask()).is_err() {
                continue;
            }
            self.conns.insert(token, conn);
            self.stats.connections.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn read_token(&mut self, token: u64, scratch: &mut [u8]) {
        let alive = {
            let Some(conn) = self.conns.get_mut(&token) else {
                return;
            };
            conn.handle_read(
                scratch,
                self.service.as_ref(),
                &self.comp,
                &self.ring,
                self.index,
            )
        };
        if !alive {
            self.drop_conn(token);
        } else {
            self.flush_token(token);
        }
    }

    fn flush_token(&mut self, token: u64) {
        let alive = {
            let Some(conn) = self.conns.get_mut(&token) else {
                return;
            };
            conn.flush(&self.ep, &self.stats)
        };
        if !alive {
            self.drop_conn(token);
        }
    }

    fn route_completions(&mut self) {
        let completions = self.comp.drain();
        if completions.is_empty() {
            return;
        }
        let mut touched: Vec<u64> = Vec::with_capacity(completions.len());
        let traced = crate::obs::enabled();
        let now = crate::obs::now_ns();
        for c in completions {
            if let Some(conn) = self.conns.get_mut(&c.token) {
                if traced {
                    self.ring.record(&crate::obs::SpanEvent {
                        trace_id: c.trace_id,
                        kind: crate::obs::SpanKind::Write,
                        t_start_ns: c.t_push_ns,
                        t_end_ns: now,
                        shard: self.index,
                        layer: None,
                        batch: 1,
                    });
                }
                conn.pending.insert(c.seq, c.bytes);
                if !touched.contains(&c.token) {
                    touched.push(c.token);
                }
            }
            // token already gone: the peer vanished before its reply did
        }
        for token in touched {
            self.flush_token(token);
        }
    }

    fn sweep_discards(&mut self) {
        let overdue: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                c.discard > 0
                    && c.discard_started.map(|t| t.elapsed() > DISCARD_TIMEOUT).unwrap_or(false)
            })
            .map(|(t, _)| *t)
            .collect();
        for token in overdue {
            self.drop_conn(token);
        }
    }

    fn drop_conn(&mut self, token: u64) {
        if self.conns.remove(&token).is_some() {
            self.stats.connections.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Token allocation stride (max loop threads a front-end may run).
#[cfg(target_os = "linux")]
const TOKEN_STRIDE: u64 = 64;

// ---------------------------------------------------------------------------
// Conn: one multiplexed connection's state machine

#[cfg(target_os = "linux")]
struct Conn {
    stream: std::net::TcpStream,
    token: u64,
    /// Inbound bytes not yet consumed (`rpos` = consumed prefix).
    rbuf: Vec<u8>,
    rpos: usize,
    /// Replies waiting for earlier sequence numbers (reorder stage).
    pending: BTreeMap<u64, Vec<u8>>,
    /// In-order outbound bytes (`wpos` = written prefix).
    wbuf: Vec<u8>,
    wpos: usize,
    /// Next sequence number to assign to a decoded frame.
    next_seq: u64,
    /// Next sequence number to move into `wbuf`.
    next_write: u64,
    /// Oversized-payload bytes still to swallow unparsed.
    discard: u64,
    discard_started: Option<std::time::Instant>,
    /// Peer closed its write half (EOF / RDHUP).
    read_closed: bool,
    /// Flush in-flight replies, then close.
    closing: bool,
    /// Read interest withdrawn for write backpressure.
    paused: bool,
    /// Interest mask currently registered with epoll.
    registered_mask: u32,
    /// `now_ns` when the current partial frame's first byte arrived.
    t_first_byte: Option<u64>,
}

#[cfg(target_os = "linux")]
impl Conn {
    fn new(stream: std::net::TcpStream, token: u64) -> Conn {
        Conn {
            stream,
            token,
            rbuf: Vec::new(),
            rpos: 0,
            pending: BTreeMap::new(),
            wbuf: Vec::new(),
            wpos: 0,
            next_seq: 0,
            next_write: 0,
            discard: 0,
            discard_started: None,
            read_closed: false,
            closing: false,
            paused: false,
            registered_mask: sys::EPOLLIN | sys::EPOLLRDHUP,
            t_first_byte: None,
        }
    }

    fn mask(&self) -> u32 {
        // Once reads are over (peer half-closed, service-initiated close,
        // or backpressure pause) RDHUP must come off too: it is
        // level-triggered, so a half-closed connection that kept it
        // registered would be re-reported on every wait and busy-spin the
        // loop.  A connection waiting only on in-flight completions sleeps
        // with an empty mask — the completion's eventfd wakes the loop,
        // and EPOLLERR/EPOLLHUP are always reported regardless of mask.
        let mut m = 0;
        if !(self.paused || self.closing || self.read_closed) {
            m |= sys::EPOLLIN | sys::EPOLLRDHUP;
        }
        if self.wbuf.len() > self.wpos {
            m |= sys::EPOLLOUT;
        }
        m
    }

    /// Read + parse until `WouldBlock` (bounded).  Returns false when the
    /// connection must be dropped.
    fn handle_read(
        &mut self,
        scratch: &mut [u8],
        service: &dyn FrameService,
        comp: &Arc<CompletionQueue>,
        ring: &crate::obs::SpanRing,
        worker: u32,
    ) -> bool {
        use std::io::Read;
        if self.paused || self.closing {
            return true;
        }
        for _ in 0..READS_PER_EVENT {
            match self.stream.read(scratch) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => {
                    self.ingest(&scratch[..n]);
                    if !self.parse(service, comp, ring, worker) {
                        return false;
                    }
                    if n < scratch.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if self.read_closed {
            // drain whatever was already buffered before the EOF
            if !self.parse(service, comp, ring, worker) {
                return false;
            }
            // a partial frame tail or unfinished oversize discard can
            // never complete now — no more input will ever arrive, so
            // drop instead of waiting forever
            if self.rpos < self.rbuf.len() || self.discard > 0 {
                return false;
            }
            let in_flight = self.next_write < self.next_seq;
            if !in_flight && self.wbuf.len() == self.wpos {
                return false; // nothing left to say
            }
        }
        true
    }

    fn ingest(&mut self, bytes: &[u8]) {
        self.rbuf.extend_from_slice(bytes);
    }

    /// Run the service over buffered bytes until it needs more.
    fn parse(
        &mut self,
        service: &dyn FrameService,
        comp: &Arc<CompletionQueue>,
        ring: &crate::obs::SpanRing,
        worker: u32,
    ) -> bool {
        loop {
            // swallow an in-progress oversized payload unparsed
            if self.discard > 0 {
                let avail = (self.rbuf.len() - self.rpos) as u64;
                let take = self.discard.min(avail);
                self.rpos += take as usize;
                self.discard -= take;
                if self.discard > 0 {
                    break;
                }
                self.discard_started = None;
            }
            if self.closing || self.rpos >= self.rbuf.len() {
                break;
            }
            if self.t_first_byte.is_none() {
                self.t_first_byte = Some(crate::obs::now_ns());
            }
            let ticket = ReplyTicket {
                queue: Arc::clone(comp),
                token: self.token,
                seq: self.next_seq,
                trace_id: crate::obs::mint_trace_id(),
            };
            let trace_id = ticket.trace_id;
            let outcome = service.on_frame(&self.rbuf[self.rpos..], ticket);
            let consumed = match outcome {
                FrameOutcome::Incomplete => break,
                FrameOutcome::Reply(consumed, bytes) => {
                    self.pending.insert(self.next_seq, bytes);
                    self.next_seq += 1;
                    consumed
                }
                FrameOutcome::Pending(consumed) => {
                    self.next_seq += 1;
                    consumed
                }
                FrameOutcome::Discard { consumed, skip, reply } => {
                    self.pending.insert(self.next_seq, reply);
                    self.next_seq += 1;
                    self.discard = skip;
                    self.discard_started = Some(std::time::Instant::now());
                    consumed
                }
                FrameOutcome::Close(consumed) => {
                    self.closing = true;
                    consumed
                }
                FrameOutcome::Fatal(consumed, bytes) => {
                    self.pending.insert(self.next_seq, bytes);
                    self.next_seq += 1;
                    self.closing = true;
                    consumed
                }
            };
            self.rpos += consumed;
            if crate::obs::enabled() {
                let t_end = crate::obs::now_ns();
                ring.record(&crate::obs::SpanEvent {
                    trace_id,
                    kind: crate::obs::SpanKind::Read,
                    t_start_ns: self.t_first_byte.unwrap_or(t_end),
                    t_end_ns: t_end,
                    shard: worker,
                    layer: None,
                    batch: 1,
                });
            }
            self.t_first_byte = None;
        }
        // compact the consumed prefix
        if self.rpos > 0 {
            self.rbuf.drain(..self.rpos);
            self.rpos = 0;
        }
        true
    }

    /// Stage in-order replies, write what the socket accepts, manage
    /// interest + backpressure.  Returns false when the connection is done.
    fn flush(&mut self, ep: &Epoll, stats: &FrontendStats) -> bool {
        use std::io::Write;
        // reorder stage -> in-order outbound buffer
        while let Some(bytes) = self.pending.remove(&self.next_write) {
            self.wbuf.extend_from_slice(&bytes);
            self.next_write += 1;
        }
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return false,
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > WBUF_HIGH {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        let outstanding = self.wbuf.len() - self.wpos;
        // write backpressure: pause reads rather than buffer unboundedly
        if !self.paused && outstanding > WBUF_HIGH {
            self.paused = true;
            stats.paused_reads.fetch_add(1, Ordering::Relaxed);
        } else if self.paused && outstanding < WBUF_LOW {
            self.paused = false;
        }
        // closing ignores residual input by design; after a half-close the
        // residue can never complete a frame, so it counts as done too
        let in_flight = self.next_write < self.next_seq;
        if (self.closing || self.read_closed) && !in_flight && outstanding == 0 {
            return false;
        }
        let want = self.mask();
        if want != self.registered_mask {
            let fd = {
                use std::os::unix::io::AsRawFd;
                self.stream.as_raw_fd()
            };
            if ep.modify(fd, self.token, want).is_err() {
                return false;
            }
            self.registered_mask = want;
        }
        true
    }
}

// ---------------------------------------------------------------------------

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::sync::atomic::AtomicBool;

    /// Toy protocol: 1-byte length + payload; reply = same frame echoed.
    /// Length 0 = close.  Odd first byte => reply delivered asynchronously
    /// from a helper thread (exercises the completion queue + reordering).
    struct EchoService;

    impl FrameService for EchoService {
        fn on_frame(&self, buf: &[u8], ticket: ReplyTicket) -> FrameOutcome {
            let len = buf[0] as usize;
            if len == 0 {
                return FrameOutcome::Close(1);
            }
            if buf.len() < 1 + len {
                return FrameOutcome::Incomplete;
            }
            let payload = buf[1..1 + len].to_vec();
            let mut reply = vec![len as u8];
            reply.extend_from_slice(&payload);
            if payload[0] % 2 == 1 {
                // async path: deliver from another thread after a beat so a
                // later even frame's inline reply must wait for this seq
                std::thread::spawn(move || {
                    std::thread::sleep(Duration::from_millis(5));
                    ticket.deliver(reply);
                });
                FrameOutcome::Pending(1 + len)
            } else {
                FrameOutcome::Reply(1 + len, reply)
            }
        }
    }

    type Running =
        (std::net::SocketAddr, Arc<AtomicBool>, std::thread::JoinHandle<anyhow::Result<()>>);

    fn start(service: Arc<dyn FrameService>) -> Running {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stats = FrontendStats::new_registered();
        let s = Arc::clone(&stop);
        let h = std::thread::spawn(move || run_reactor(listener, s, service, 2, stats, || ()));
        (addr, stop, h)
    }

    fn read_exact_frame(stream: &mut TcpStream) -> Vec<u8> {
        let mut len = [0u8; 1];
        stream.read_exact(&mut len).unwrap();
        let mut payload = vec![0u8; len[0] as usize];
        stream.read_exact(&mut payload).unwrap();
        payload
    }

    #[test]
    fn echo_round_trip_and_split_frames() {
        let (addr, stop, h) = start(Arc::new(EchoService));
        let mut c = TcpStream::connect(addr).unwrap();
        // frame split across three writes
        c.write_all(&[3]).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        c.write_all(&[2, 4]).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        c.write_all(&[6]).unwrap();
        assert_eq!(read_exact_frame(&mut c), vec![2, 4, 6]);
        stop.store(true, Ordering::Relaxed);
        h.join().unwrap().unwrap();
    }

    #[test]
    fn pipelined_replies_come_back_in_request_order() {
        let (addr, stop, h) = start(Arc::new(EchoService));
        let mut c = TcpStream::connect(addr).unwrap();
        // odd payloads reply async-late, even ones inline: order must hold
        let mut burst = Vec::new();
        for v in [1u8, 2, 3, 4, 5, 6] {
            burst.extend_from_slice(&[1, v]);
        }
        c.write_all(&burst).unwrap();
        for v in [1u8, 2, 3, 4, 5, 6] {
            assert_eq!(read_exact_frame(&mut c), vec![v], "reply order broke at {v}");
        }
        stop.store(true, Ordering::Relaxed);
        h.join().unwrap().unwrap();
    }

    #[test]
    fn many_connections_multiplex_on_two_loops() {
        let (addr, stop, h) = start(Arc::new(EchoService));
        let mut conns: Vec<TcpStream> =
            (0..32).map(|_| TcpStream::connect(addr).unwrap()).collect();
        for (i, c) in conns.iter_mut().enumerate() {
            c.write_all(&[2, (i % 128) as u8, 2]).unwrap();
        }
        for (i, c) in conns.iter_mut().enumerate() {
            assert_eq!(read_exact_frame(c), vec![(i % 128) as u8, 2]);
        }
        stop.store(true, Ordering::Relaxed);
        h.join().unwrap().unwrap();
    }

    #[test]
    fn half_close_with_partial_frame_drops_connection() {
        let (addr, stop, h) = start(Arc::new(EchoService));
        let mut c = TcpStream::connect(addr).unwrap();
        // header promises 5 payload bytes, only 1 ever arrives, then FIN:
        // the frame can never complete, so the server must drop us rather
        // than hold (and busy-poll) the connection forever
        c.write_all(&[5, 1]).unwrap();
        c.shutdown(std::net::Shutdown::Write).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut tail = Vec::new();
        c.read_to_end(&mut tail).unwrap(); // errs (timeout) if the server hangs on to us
        assert!(tail.is_empty());
        stop.store(true, Ordering::Relaxed);
        h.join().unwrap().unwrap();
    }

    #[test]
    fn half_close_flushes_in_flight_reply_then_closes() {
        let (addr, stop, h) = start(Arc::new(EchoService));
        let mut c = TcpStream::connect(addr).unwrap();
        // odd payload: the echo arrives asynchronously after the peer has
        // already half-closed — the reply must still be delivered, then EOF
        c.write_all(&[1, 7]).unwrap();
        c.shutdown(std::net::Shutdown::Write).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        assert_eq!(read_exact_frame(&mut c), vec![7]);
        let mut tail = Vec::new();
        c.read_to_end(&mut tail).unwrap();
        assert!(tail.is_empty());
        stop.store(true, Ordering::Relaxed);
        h.join().unwrap().unwrap();
    }

    /// Panics on every frame; records whether the shutdown hook ran.
    struct PanicService(Arc<AtomicBool>);

    impl FrameService for PanicService {
        fn on_frame(&self, _buf: &[u8], _ticket: ReplyTicket) -> FrameOutcome {
            panic!("frame handler blew up")
        }

        fn on_shutdown(&self) {
            self.0.store(true, Ordering::Relaxed);
        }
    }

    #[test]
    fn worker_panic_stops_front_end_and_still_drains_shutdown() {
        let drained = Arc::new(AtomicBool::new(false));
        let (addr, _stop, h) = start(Arc::new(PanicService(Arc::clone(&drained))));
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(&[1, 1]).unwrap();
        // the panicking worker's stop guard tears the front-end down: the
        // run_reactor call must return (no hang), surface the panic, and
        // still have run the service's shutdown drain
        let err = h.join().unwrap().expect_err("worker panic must surface as an error");
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(drained.load(Ordering::Relaxed), "on_shutdown must run after a panic");
    }

    #[test]
    fn close_frame_closes_cleanly() {
        let (addr, stop, h) = start(Arc::new(EchoService));
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(&[2, 8, 8, 0]).unwrap(); // one frame, then close marker
        assert_eq!(read_exact_frame(&mut c), vec![8, 8]);
        let mut tail = Vec::new();
        c.read_to_end(&mut tail).unwrap(); // server closes after flushing
        assert!(tail.is_empty());
        stop.store(true, Ordering::Relaxed);
        h.join().unwrap().unwrap();
    }
}
