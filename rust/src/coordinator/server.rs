//! The coordinator proper: a *sharded worker pool* mirroring the paper's
//! spatially-parallel accelerator in host software.
//!
//! ```text
//! client ──try_send──► bounded queue (shard 0) ──► batcher ──► worker 0 ──► backend replica 0
//!        └─dispatch──► bounded queue (shard 1) ──► batcher ──► worker 1 ──► backend replica 1
//!            ...                 ...                                ...
//! ```
//!
//! * Each shard owns one backend replica (built on its worker thread via a
//!   [`BackendFactory`] — required for non-`Send` backends like PJRT) and a
//!   bounded `sync_channel` submission queue.
//! * Dispatch is round-robin with a least-loaded pick: the cursor sets the
//!   tie-break order, then shards are tried in ascending queued+in-flight
//!   depth.  When *every* queue is full, [`Client::submit`] returns
//!   [`SubmitError::QueueFull`] — explicit backpressure, never unbounded
//!   growth.
//! * Batch formation is zero-copy: workers lend request buffers to
//!   [`Backend::infer_batch`] as `&[&[i32]]`.
//! * Backend failures produce typed error replies (and an `errors` metric);
//!   requests are never silently dropped.
//!
//! A minimal TCP front-end (length-prefixed binary protocol, thread per
//! connection) rides on top.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::backend::{Backend, BackendFactory};
use crate::coordinator::batcher::{BatchPolicy, Batcher, Msg};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::qos::{FrontendConfig, FrontendStats, Lane, QosAdmission};
use crate::coordinator::reactor::{
    reactor_supported, run_reactor, FrameOutcome, FrameService, ReplyTicket,
};
use crate::coordinator::request::{InferError, InferReply, InferRequest, ReplyTo, SubmitError};
use crate::coordinator::supervisor::{PoolHealth, RestartPolicy, ShardHealth, ShardState};
use crate::obs::{self, SpanEvent, SpanKind, SpanRing};
use crate::util::faults;
use crate::util::sync::{lock_recover, panic_message};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub policy: BatchPolicy,
    /// Worker shards; each owns one backend replica (>= 1).
    pub workers: usize,
    /// Bounded submission-queue capacity *per shard* (>= 1).
    pub queue_depth: usize,
    /// Crash supervision: backoff + circuit breaker per shard.
    pub restart: RestartPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::default(),
            workers: 1,
            queue_depth: 256,
            restart: RestartPolicy::default(),
        }
    }
}

impl CoordinatorConfig {
    /// Default policy/depth with `workers` shards.
    pub fn with_workers(workers: usize) -> Self {
        Self { workers, ..Self::default() }
    }
}

/// One shard as the client sees it: a bounded sender, a load gauge
/// (queued + in-flight requests), health, and the shutdown latch.
#[derive(Clone)]
struct ShardHandle {
    tx: SyncSender<Msg>,
    depth: Arc<AtomicUsize>,
    /// Set by `stop_shard` before it enqueues the poison: submitters stop
    /// competing for queue slots, so the `Stop` message cannot be starved
    /// by `submit_blocking` retry loops.
    stopping: Arc<AtomicBool>,
    /// Written by the shard's supervisor loop, read by dispatch (skip
    /// broken shards) and health probes.
    health: Arc<ShardHealth>,
    /// This shard's span ring (track `pool{P}/shard{S}` in the trace
    /// export).  Admission spans are recorded here by `submit`; queue/
    /// batch/reply spans by the shard worker.
    ring: Arc<SpanRing>,
}

/// Handle clients use to submit work.  Cheap to clone; clones share the
/// same shard queues and request-id counter, and every clone is `Send`,
/// so M client threads can drive the pool concurrently.
#[derive(Clone)]
pub struct Client {
    shards: Vec<ShardHandle>,
    rr: Arc<AtomicUsize>,
    next_id: Arc<AtomicU64>,
}

/// How long `submit_blocking` sleeps between backpressure retries.
const BACKPRESSURE_RETRY: Duration = Duration::from_micros(50);
/// Ceiling for `submit_deadline`'s exponential retry backoff.
const MAX_SUBMIT_BACKOFF: Duration = Duration::from_millis(10);

impl Client {
    /// Submit one image; returns the receiver for its reply, or a
    /// backpressure/shutdown error.
    ///
    /// Dispatch policy: the round-robin cursor fixes the tie-break order,
    /// then shards are tried least-loaded first; shards whose circuit
    /// breaker is open ([`ShardState::Broken`]) are skipped entirely.
    /// `QueueFull` hands the image back so callers can retry without
    /// re-allocating; `ShardDown` means every worker is dead without a
    /// graceful shutdown — callers should fail over.
    pub fn submit(&self, image: Vec<i32>) -> std::result::Result<Receiver<InferReply>, SubmitError> {
        let (reply_tx, reply_rx) = mpsc::channel();
        // trace identity is minted at admission and rides the request
        // end-to-end; the admission span covers dispatch + queue handoff
        self.submit_with(image, obs::mint_trace_id(), ReplyTo::Channel(reply_tx))?;
        Ok(reply_rx)
    }

    /// `submit` with an explicit trace id and reply destination — the
    /// event-driven front-end registers a completion callback instead of
    /// blocking on a channel.  Same dispatch policy and errors.
    pub fn submit_with(
        &self,
        image: Vec<i32>,
        trace_id: u64,
        reply: ReplyTo,
    ) -> std::result::Result<(), SubmitError> {
        if faults::fire(faults::SITE_SUBMIT) {
            // injected queue-full storm: indistinguishable from real
            // backpressure, so retry loops get exercised end-to-end
            return Err(SubmitError::QueueFull { image });
        }
        let n = self.shards.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        // snapshot the depth gauges ONCE (they move under concurrent
        // traffic, and a comparator over live atomics is not a total
        // order); the stable sort keeps round-robin rotation for ties
        let mut order: Vec<(usize, usize)> = (0..n)
            .map(|k| {
                let i = (start + k) % n;
                (self.shards[i].depth.load(Ordering::Relaxed), i)
            })
            .collect();
        order.sort_by_key(|&(depth, _)| depth);

        let tracing = obs::enabled();
        let admit_start = if tracing { obs::now_ns() } else { 0 };
        let mut msg = Msg::Req(InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            trace_id,
            image,
            enqueued: Instant::now(),
            reply,
        });
        let mut dead = 0usize;
        for &(_, i) in &order {
            if self.shards[i].stopping.load(Ordering::Relaxed)
                || !self.shards[i].health.state().accepts_work()
            {
                dead += 1;
                continue;
            }
            // gauge up BEFORE the send: the worker's decrement must always
            // observe a prior increment, or the usize gauge could wrap
            self.shards[i].depth.fetch_add(1, Ordering::Relaxed);
            match self.shards[i].tx.try_send(msg) {
                Ok(()) => {
                    if tracing {
                        self.shards[i].ring.record(&SpanEvent {
                            trace_id,
                            kind: SpanKind::Admission,
                            t_start_ns: admit_start,
                            t_end_ns: obs::now_ns(),
                            shard: i as u32,
                            layer: None,
                            batch: 0,
                        });
                    }
                    return Ok(());
                }
                Err(TrySendError::Full(m)) => {
                    self.shards[i].depth.fetch_sub(1, Ordering::Relaxed);
                    msg = m;
                }
                Err(TrySendError::Disconnected(m)) => {
                    self.shards[i].depth.fetch_sub(1, Ordering::Relaxed);
                    dead += 1;
                    msg = m;
                }
            }
        }
        let Msg::Req(req) = msg else { unreachable!("submit only builds Req") };
        if dead < n {
            return Err(SubmitError::QueueFull { image: req.image });
        }
        // every shard refused: a graceful shutdown anywhere means the pool
        // is going away (Shutdown); otherwise the workers crashed out from
        // under us and the caller should fail over (ShardDown)
        let stopping = self
            .shards
            .iter()
            .any(|s| s.stopping.load(Ordering::Relaxed) || s.health.state() == ShardState::Stopped);
        if stopping {
            Err(SubmitError::Shutdown)
        } else {
            Err(SubmitError::ShardDown { image: req.image })
        }
    }

    /// Submit, waiting out backpressure (bounded memory, unbounded time).
    /// `ShardDown` is terminal here: a pool whose every breaker is open
    /// will never drain, so waiting would hang forever.
    pub fn submit_blocking(
        &self,
        mut image: Vec<i32>,
    ) -> std::result::Result<Receiver<InferReply>, SubmitError> {
        loop {
            match self.submit(image) {
                Ok(rx) => return Ok(rx),
                Err(SubmitError::QueueFull { image: img }) => {
                    image = img;
                    std::thread::sleep(BACKPRESSURE_RETRY);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Submit with bounded retry: waits out `QueueFull`/`ShardDown` with
    /// exponential backoff (doubling from [`BACKPRESSURE_RETRY`], capped)
    /// for at most `deadline`.  `ShardDown` is retried because a shard
    /// whose supervisor is mid-restart comes back within a backoff window;
    /// on expiry the image is handed back in the last error so callers
    /// (e.g. the TCP handler) can signal overload instead of stalling.
    pub fn submit_deadline(
        &self,
        mut image: Vec<i32>,
        deadline: Duration,
    ) -> std::result::Result<Receiver<InferReply>, SubmitError> {
        let start = Instant::now();
        let mut backoff = BACKPRESSURE_RETRY;
        loop {
            let down = match self.submit(image) {
                Ok(rx) => return Ok(rx),
                Err(SubmitError::QueueFull { image: img }) => {
                    image = img;
                    false
                }
                Err(SubmitError::ShardDown { image: img }) => {
                    image = img;
                    true
                }
                Err(e @ SubmitError::Shutdown) => return Err(e),
            };
            let elapsed = start.elapsed();
            if elapsed >= deadline {
                return Err(if down {
                    SubmitError::ShardDown { image }
                } else {
                    SubmitError::QueueFull { image }
                });
            }
            std::thread::sleep(backoff.min(deadline - elapsed));
            backoff = (backoff * 2).min(MAX_SUBMIT_BACKOFF);
        }
    }

    /// Submit (waiting out backpressure) and wait for the reply.
    pub fn infer(&self, image: Vec<i32>) -> Result<InferReply> {
        self.submit_blocking(image)
            .map_err(|e| anyhow!("{e}"))?
            .recv()
            .map_err(|_| anyhow!("coordinator shut down before replying"))
    }

    /// Per-shard queued+in-flight depths (dispatch introspection).
    pub fn shard_depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.depth.load(Ordering::Relaxed)).collect()
    }
}

/// One running shard: its worker thread (which is also its supervisor
/// loop) plus that shard's metrics.
struct Shard {
    handle: ShardHandle,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
}

/// A running coordinator: N worker shards over N backend replicas.
pub struct Coordinator {
    client: Client,
    shards: Vec<Shard>,
    started: Instant,
}

impl Coordinator {
    /// Spawn a single-shard coordinator around an already-built `Send`
    /// backend.  For a multi-worker pool use [`Coordinator::start_sharded`]
    /// (a boxed backend cannot be replicated).
    ///
    /// # Panics
    /// If `config.workers > 1` — replication needs a factory.
    pub fn start(backend: Box<dyn Backend + Send>, config: CoordinatorConfig) -> Self {
        assert!(
            config.workers <= 1,
            "Coordinator::start cannot replicate a boxed backend; use start_sharded"
        );
        let cell = Mutex::new(Some(backend));
        let factory: BackendFactory = Arc::new(move || {
            lock_recover(&cell)
                .take()
                .map(|b| {
                    let b: Box<dyn Backend> = b;
                    b
                })
                .ok_or_else(|| anyhow!("single backend already claimed"))
        });
        Self::start_sharded(factory, CoordinatorConfig { workers: 1, ..config })
            .expect("single-shard startup cannot fail")
    }

    /// Backwards-compatible alias for [`Coordinator::start_sharded`].
    pub fn start_with(factory: BackendFactory, config: CoordinatorConfig) -> Result<Self> {
        Self::start_sharded(factory, config)
    }

    /// Spawn `config.workers` shards; the factory runs once on each worker
    /// thread (required for non-`Send` backends like PJRT).  Fails if any
    /// factory call fails — already-started shards are shut down.
    pub fn start_sharded(factory: BackendFactory, config: CoordinatorConfig) -> Result<Self> {
        let workers = config.workers.max(1);
        let queue_depth = config.queue_depth.max(1);
        // distinct trace tracks per pool instance, so replicas/restarts
        // don't alias: labels are pool{P}/shard{S}
        let pool = obs::next_instance_id();
        let mut shards = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        let mut startup_err = None;
        for shard_id in 0..workers {
            match spawn_shard(
                shard_id,
                pool,
                Arc::clone(&factory),
                config.policy,
                queue_depth,
                config.restart,
            ) {
                Ok(shard) => {
                    handles.push(shard.handle.clone());
                    shards.push(shard);
                }
                Err(e) => {
                    startup_err = Some(e.context(format!("starting shard {shard_id}")));
                    break;
                }
            }
        }
        if let Some(e) = startup_err {
            for shard in &mut shards {
                stop_shard(shard);
            }
            return Err(e);
        }
        Ok(Self {
            client: Client {
                shards: handles,
                rr: Arc::new(AtomicUsize::new(0)),
                next_id: Arc::new(AtomicU64::new(0)),
            },
            shards,
            started: Instant::now(),
        })
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Snapshot the aggregate metrics across shards (wall time filled in).
    pub fn metrics(&self) -> Metrics {
        let mut total = Metrics::new();
        for shard in &self.shards {
            total.merge(&lock_recover(&shard.metrics));
        }
        total.wall = self.started.elapsed();
        total
    }

    /// Per-shard metrics snapshots (dispatch-distribution introspection).
    pub fn shard_metrics(&self) -> Vec<Metrics> {
        self.shards.iter().map(|s| lock_recover(&s.metrics).clone()).collect()
    }

    /// Per-shard supervision health (state + crash/restart counters).
    pub fn health(&self) -> PoolHealth {
        PoolHealth {
            shards: self.shards.iter().map(|s| s.handle.health.snapshot()).collect(),
        }
    }

    /// Graceful shutdown: poison every queue (queued requests are still
    /// served first), join the workers, then snapshot the metrics — so the
    /// requests drained during shutdown are included.  Works even while
    /// client handles remain alive — their later submits see
    /// `SubmitError::Shutdown`.
    pub fn shutdown(mut self) -> Metrics {
        for shard in &mut self.shards {
            stop_shard(shard);
        }
        self.metrics()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for shard in &mut self.shards {
            stop_shard(shard);
        }
    }
}

/// Send the stop poison (waiting out a full queue) and join the worker.
/// The `stopping` latch is raised first so submitters stop competing for
/// freed queue slots — the poison cannot be starved.
fn stop_shard(shard: &mut Shard) {
    if shard.worker.is_none() {
        return;
    }
    shard.handle.stopping.store(true, Ordering::Relaxed);
    let mut msg = Msg::Stop;
    loop {
        match shard.handle.tx.try_send(msg) {
            Ok(()) => break,
            Err(TrySendError::Full(m)) => {
                msg = m;
                std::thread::sleep(BACKPRESSURE_RETRY);
            }
            Err(TrySendError::Disconnected(_)) => break,
        }
    }
    if let Some(w) = shard.worker.take() {
        let _ = w.join();
    }
}

/// Spawn one shard: bounded queue + worker thread building its replica
/// and supervising it (restart-in-place on crash).
fn spawn_shard(
    shard_id: usize,
    pool: u32,
    factory: BackendFactory,
    policy: BatchPolicy,
    queue_depth: usize,
    restart: RestartPolicy,
) -> Result<Shard> {
    let (tx, rx) = mpsc::sync_channel(queue_depth);
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let depth = Arc::new(AtomicUsize::new(0));
    let stopping = Arc::new(AtomicBool::new(false));
    let health = Arc::new(ShardHealth::new());
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let ring = SpanRing::new(format!("pool{pool}/shard{shard_id}"), obs::DEFAULT_RING_CAPACITY);
    let worker = std::thread::Builder::new()
        .name(format!("coordinator-shard-{shard_id}"))
        .spawn({
            let depth = Arc::clone(&depth);
            let health = Arc::clone(&health);
            let metrics = Arc::clone(&metrics);
            let ring = Arc::clone(&ring);
            move || {
                let backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        health.set_state(ShardState::Broken);
                        return;
                    }
                };
                supervise(
                    shard_id, backend, &factory, rx, policy, restart, &metrics, &depth, &health,
                    &ring,
                );
            }
        })
        .context("spawn coordinator shard thread")?;
    ready_rx
        .recv()
        .map_err(|_| anyhow!("shard worker died during startup"))??;
    Ok(Shard {
        handle: ShardHandle { tx, depth, stopping, health, ring },
        worker: Some(worker),
        metrics,
    })
}

/// How one run of [`shard_loop`] ended.
enum LoopExit {
    /// Stop poison / all senders gone: graceful.
    Stopped,
    /// The replica panicked mid-batch (contained; the batch already got
    /// typed error replies).  The supervisor should rebuild.
    Crashed,
}

/// The shard supervisor: run the serving loop, and on a contained crash
/// rebuild the replica from the factory with exponential backoff +
/// jitter.  `restart.max_consecutive` crashes without an intervening
/// successful batch trip the circuit breaker: queued requests are failed
/// typed (the client retries them onto a healthy shard — that's the
/// failover count), the shard marks itself [`ShardState::Broken`] and the
/// worker exits, closing the queue.
#[allow(clippy::too_many_arguments)]
fn supervise(
    shard_id: usize,
    mut backend: Box<dyn Backend>,
    factory: &BackendFactory,
    rx: Receiver<Msg>,
    policy: BatchPolicy,
    restart: RestartPolicy,
    metrics: &Mutex<Metrics>,
    depth: &AtomicUsize,
    health: &ShardHealth,
    ring: &SpanRing,
) {
    // the batcher (and thus the queue receiver) outlives replica rebuilds:
    // queued requests survive a crash and are served by the next replica
    let mut batcher = Batcher::new(rx, policy);
    let max_consecutive = restart.max_consecutive.max(1);
    loop {
        match shard_loop(shard_id, backend.as_mut(), &mut batcher, metrics, depth, health, ring) {
            LoopExit::Stopped => {
                health.set_state(ShardState::Stopped);
                return;
            }
            LoopExit::Crashed => {
                let mut consecutive = health.note_crash();
                lock_recover(metrics).crashes += 1;
                health.set_state(ShardState::Restarting);
                loop {
                    if consecutive >= max_consecutive {
                        trip_breaker(shard_id, &mut batcher, consecutive, metrics, depth, health);
                        return;
                    }
                    std::thread::sleep(restart.backoff_delay(consecutive, shard_id as u64));
                    // a queued Stop poison must win over rebuilding
                    if batcher.is_stopped() {
                        health.set_state(ShardState::Stopped);
                        return;
                    }
                    match factory() {
                        Ok(b) => {
                            backend = b;
                            health.note_restart();
                            lock_recover(metrics).restarts += 1;
                            health.set_state(ShardState::Ready);
                            break;
                        }
                        Err(e) => {
                            // rebuild failure counts against the breaker too
                            eprintln!("shard {shard_id}: replica rebuild failed: {e:#}");
                            consecutive = health.note_crash();
                            lock_recover(metrics).crashes += 1;
                        }
                    }
                }
            }
        }
    }
}

/// Circuit breaker: fail every queued request typed, mark the shard
/// broken, and let the worker exit (dropping the queue receiver so later
/// sends see `Disconnected`).  Nothing hangs, nothing is dropped.
fn trip_breaker(
    shard_id: usize,
    batcher: &mut Batcher,
    consecutive: u32,
    metrics: &Mutex<Metrics>,
    depth: &AtomicUsize,
    health: &ShardHealth,
) {
    health.set_state(ShardState::Broken);
    let drained = batcher.drain_pending();
    let message = format!(
        "shard {shard_id} circuit breaker open after {consecutive} consecutive crashes"
    );
    let n = drained.len();
    if n > 0 {
        let mut m = lock_recover(metrics);
        m.errors += n as u64;
        m.requests_failed_over += n as u64;
    }
    for req in drained {
        let queue_time = req.enqueued.elapsed();
        let _ = req.reply.send(InferReply {
            id: req.id,
            trace_id: req.trace_id,
            scores: Err(InferError::backend(message.clone())),
            queue_time,
            service_time: Duration::ZERO,
            batch_size: 0,
            shard: shard_id,
            modeled_device_time: None,
        });
        depth.fetch_sub(1, Ordering::Relaxed);
    }
    eprintln!("{message} ({n} queued request(s) failed over)");
}

/// The per-shard serving loop: form batches, lend buffers zero-copy to
/// the replica, fan replies (or typed errors) back out.  The replica call
/// runs under `catch_unwind`: a panicking backend fails its batch typed
/// (every request replies, no hangs) and returns [`LoopExit::Crashed`] so
/// the supervisor rebuilds the replica.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    shard_id: usize,
    backend: &mut dyn Backend,
    batcher: &mut Batcher,
    metrics: &Mutex<Metrics>,
    depth: &AtomicUsize,
    health: &ShardHealth,
    ring: &SpanRing,
) -> LoopExit {
    // degradation/crash counters are cumulative per *replica*; track the
    // last fold so rebuilt replicas (fresh counters) don't lose history
    let mut folded_failovers = 0u64;
    let mut folded_crashes = 0u64;
    while let Some(batch) = batcher.next_batch() {
        let formed = Instant::now();
        let tracing = obs::enabled();
        let formed_ns = if tracing { obs::now_ns() } else { 0 };
        let batch_len = batch.len();
        let trace_ids: Vec<u64> = batch.iter().map(|r| r.trace_id).collect();
        let views: Vec<&[i32]> = batch.iter().map(|r| r.image.as_slice()).collect();
        // AssertUnwindSafe: on a caught panic the replica is discarded and
        // rebuilt from the factory, so torn internal state never escapes.
        // The batch vec lives *outside* the closure, so its reply senders
        // survive the unwind and every request still gets a typed error.
        let caught = catch_unwind(AssertUnwindSafe(|| {
            if faults::fire(faults::SITE_BACKEND_INFER) {
                return Err(anyhow!("injected fault: backend_infer denied"));
            }
            backend.infer_batch_traced(&views, &trace_ids)
        }));
        drop(views);
        let service = formed.elapsed();
        // per-request queue/batch/reply spans are recorded just before the
        // reply send, so by the time a client holds its scores the spans
        // are already in the ring (trace fetches cannot race them)
        let record_spans = |req: &InferRequest, queue_time: Duration| {
            if !tracing {
                return;
            }
            let service_end = formed_ns + service.as_nanos() as u64;
            ring.record(&SpanEvent {
                trace_id: req.trace_id,
                kind: SpanKind::Queue,
                t_start_ns: formed_ns.saturating_sub(queue_time.as_nanos() as u64),
                t_end_ns: formed_ns,
                shard: shard_id as u32,
                layer: None,
                batch: 0,
            });
            ring.record(&SpanEvent {
                trace_id: req.trace_id,
                kind: SpanKind::Batch,
                t_start_ns: formed_ns,
                t_end_ns: service_end,
                shard: shard_id as u32,
                layer: None,
                batch: batch_len as u32,
            });
            ring.record(&SpanEvent {
                trace_id: req.trace_id,
                kind: SpanKind::Reply,
                t_start_ns: service_end,
                t_end_ns: obs::now_ns(),
                shard: shard_id as u32,
                layer: None,
                batch: 0,
            });
        };
        let (mut result, crashed) = match caught {
            Ok(r) => (r, false),
            Err(payload) => (
                Err(anyhow!(
                    "shard {shard_id} replica panicked: {}",
                    panic_message(payload.as_ref())
                )),
                true,
            ),
        };
        if let Ok(out) = &result {
            if out.scores.len() != batch_len {
                result = Err(anyhow!(
                    "backend returned {} score rows for a batch of {batch_len}",
                    out.scores.len()
                ));
            }
        }
        // pipeline-backed replicas expose cumulative per-stage busy/stall
        // counters; snapshot them into this shard's metrics (replace, not
        // add — the counters are running totals) so STATS shows which
        // stage bottlenecks.  Empty for stage-less backends.  Skipped for
        // a crashed replica: its internals are not worth trusting.
        let stage_stats = if crashed { Vec::new() } else { backend.stage_stats() };
        let kernel = if crashed { "" } else { backend.kernel() };
        let (failovers, crashes) = if crashed {
            (folded_failovers, folded_crashes)
        } else {
            (backend.failovers(), backend.crashes())
        };
        match result {
            Ok(out) => {
                let mut m = lock_recover(metrics);
                if !stage_stats.is_empty() {
                    m.stages = stage_stats;
                }
                if m.kernel.is_empty() && !kernel.is_empty() {
                    m.kernel = kernel.to_string();
                }
                m.requests_failed_over += failovers.saturating_sub(folded_failovers);
                m.crashes += crashes.saturating_sub(folded_crashes);
                m.record_batch(batch_len, service, out.modeled_device_time);
                for (req, scores) in batch.into_iter().zip(out.scores) {
                    let queue_time = formed.duration_since(req.enqueued);
                    m.record_request(queue_time, queue_time + service);
                    record_spans(&req, queue_time);
                    let _ = req.reply.send(InferReply {
                        id: req.id,
                        trace_id: req.trace_id,
                        scores: Ok(scores),
                        queue_time,
                        service_time: service,
                        batch_size: batch_len,
                        shard: shard_id,
                        modeled_device_time: out.modeled_device_time,
                    });
                }
                health.note_success();
            }
            Err(e) => {
                // No silent drops: every request in the failed batch gets
                // a typed error reply, and the failure is counted.
                let message = format!("{e:#}");
                {
                    let mut m = lock_recover(metrics);
                    if !stage_stats.is_empty() {
                        m.stages = stage_stats;
                    }
                    if m.kernel.is_empty() && !kernel.is_empty() {
                        m.kernel = kernel.to_string();
                    }
                    m.requests_failed_over += failovers.saturating_sub(folded_failovers);
                    m.crashes += crashes.saturating_sub(folded_crashes);
                    m.record_batch_error(batch_len, service);
                }
                for req in batch {
                    let queue_time = formed.duration_since(req.enqueued);
                    record_spans(&req, queue_time);
                    let _ = req.reply.send(InferReply {
                        id: req.id,
                        trace_id: req.trace_id,
                        scores: Err(InferError::backend(message.clone())),
                        queue_time,
                        service_time: service,
                        batch_size: batch_len,
                        shard: shard_id,
                        modeled_device_time: None,
                    });
                }
            }
        }
        folded_failovers = failovers;
        folded_crashes = crashes;
        depth.fetch_sub(batch_len, Ordering::Relaxed);
        if crashed {
            return LoopExit::Crashed;
        }
    }
    LoopExit::Stopped
}

// ---------------------------------------------------------------------------
// TCP front-end (protocol v1; the v2 model-routed front-end rides the
// same framing from `crate::serving::admin`)
// ---------------------------------------------------------------------------
//
// Wire protocol (little-endian):
//   request:  u32 n_values, then n_values x i32 (one NHWC image)
//   reply:    u32 n_scores, then n_scores x f32
//   error:    u32 0xFFFF_FFFF, u32 msg_len, msg bytes
// A zero-length request closes the connection.  An error frame does NOT
// close it: oversized requests have their payload discarded and
// backend/backpressure failures are per-request, so the next request on
// the connection can still succeed.

/// Error sentinel in the reply length slot.
pub const WIRE_ERROR: u32 = u32::MAX;
/// Largest accepted request, in i32 values.
pub const MAX_WIRE_VALUES: usize = 1 << 22;
/// How long the TCP handler waits out backpressure before answering with
/// an overload error frame instead of stalling the connection.
pub const TCP_SUBMIT_DEADLINE: Duration = Duration::from_secs(5);

/// Shared nonblocking accept loop (v1 and v2 front-ends): thread per
/// connection, finished handlers pruned as connections churn, everything
/// joined on shutdown.  `on_idle` runs on every empty poll — the v2
/// front-end reaps drained retired pools there.
pub(crate) fn serve_connections(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    handler: Arc<dyn Fn(TcpStream) + Send + Sync>,
    on_idle: impl FnMut(),
) -> Result<()> {
    serve_connections_gauged(listener, stop, handler, on_idle, Arc::new(AtomicUsize::new(0)))
}

/// `serve_connections` with an observable live-handler gauge: `live`
/// tracks the join list's length after reaping, so tests can assert that
/// connection churn does not leak finished handler threads.
pub(crate) fn serve_connections_gauged(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    handler: Arc<dyn Fn(TcpStream) + Send + Sync>,
    mut on_idle: impl FnMut(),
    live: Arc<AtomicUsize>,
) -> Result<()> {
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                conns.retain(|c| !c.is_finished());
                let handler = Arc::clone(&handler);
                conns.push(std::thread::spawn(move || handler(stream)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // reap on idle too — a server accepting one long-lived
                // connection after thousands of short ones must not hold
                // thousands of finished JoinHandles until the next accept
                conns.retain(|c| !c.is_finished());
                on_idle();
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => bail!("accept: {e}"),
        }
        live.store(conns.len(), Ordering::Relaxed);
    }
    for c in conns {
        let _ = c.join();
    }
    live.store(0, Ordering::Relaxed);
    Ok(())
}

/// Serve a TCP listener until `stop` flips.  On Linux this runs the epoll
/// reactor front-end with default QoS ([`FrontendConfig::default`]: every
/// v1 request rides the online lane with the legacy 5 s overload bound);
/// elsewhere it falls back to the threaded accept loop.
pub fn serve_tcp(listener: TcpListener, client: Client, stop: Arc<AtomicBool>) -> Result<()> {
    serve_tcp_frontend(listener, client, stop, FrontendConfig::default())
}

/// The legacy thread-per-connection front-end (baseline for the
/// reactor-vs-threaded benchmark, and the non-Linux fallback).
pub fn serve_tcp_threaded(
    listener: TcpListener,
    client: Client,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let handler: Arc<dyn Fn(TcpStream) + Send + Sync> = Arc::new(move |stream| {
        let _ = handle_conn(stream, client.clone());
    });
    serve_connections(listener, stop, handler, || {})
}

/// Event-driven front-end with explicit reactor/QoS configuration.
pub fn serve_tcp_frontend(
    listener: TcpListener,
    client: Client,
    stop: Arc<AtomicBool>,
    cfg: FrontendConfig,
) -> Result<()> {
    if !reactor_supported() {
        return serve_tcp_threaded(listener, client, stop);
    }
    let stats = FrontendStats::new_registered();
    let qos = QosAdmission::new(cfg.qos, Arc::clone(&stats));
    let service: Arc<dyn FrameService> = Arc::new(V1Service { client, qos });
    run_reactor(listener, stop, service, cfg.resolved_threads(), stats, || {})
}

/// Incremental decoder + dispatcher for the v1 wire protocol.
struct V1Service {
    client: Client,
    qos: Arc<QosAdmission>,
}

impl FrameService for V1Service {
    fn on_frame(&self, buf: &[u8], ticket: ReplyTicket) -> FrameOutcome {
        if buf.len() < 4 {
            return FrameOutcome::Incomplete;
        }
        let n = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        if n == 0 {
            return FrameOutcome::Close(4);
        }
        if n > MAX_WIRE_VALUES {
            let msg = format!("request too large: {n} values");
            let skip = n as u64 * 4;
            if skip > MAX_DISCARD_BYTES as u64 {
                // protocol garbage, not a client mistake: error then close
                return FrameOutcome::Fatal(4, error_frame(&msg));
            }
            return FrameOutcome::Discard { consumed: 4, skip, reply: error_frame(&msg) };
        }
        let need = 4 + n * 4;
        if buf.len() < need {
            return FrameOutcome::Incomplete;
        }
        let image: Vec<i32> = buf[4..need]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        if faults::fire(faults::SITE_SERVER_READ) {
            // injected shed after the frame was consumed: the connection
            // stays framed and usable
            return FrameOutcome::Reply(
                need,
                error_frame("injected fault: request shed at server_read"),
            );
        }
        let trace_id = ticket.trace_id();
        self.qos.admit(
            image,
            trace_id,
            Lane::Online,
            None,
            self.client.clone(),
            v1_completion(ticket),
        );
        FrameOutcome::Pending(need)
    }

    fn on_loop_tick(&self) -> bool {
        self.qos.pump()
    }

    fn on_shutdown(&self) {
        self.qos.drain_shutdown();
    }
}

/// v1 error frame bytes (`WIRE_ERROR`, length, message).
pub(crate) fn error_frame(msg: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + msg.len());
    out.extend_from_slice(&WIRE_ERROR.to_le_bytes());
    out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    out.extend_from_slice(msg.as_bytes());
    out
}

/// v1 scores frame bytes (count, then f32 LE values).
pub(crate) fn scores_frame(scores: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + scores.len() * 4);
    out.extend_from_slice(&(scores.len() as u32).to_le_bytes());
    for s in scores {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out
}

/// Completion callback encoding an [`InferReply`] as a v1 wire frame and
/// delivering it on the frame's ticket.  The `server_write` fault site
/// fires here — the reactor's equivalent of dropping a reply at write.
fn v1_completion(ticket: ReplyTicket) -> Arc<dyn Fn(InferReply) + Send + Sync> {
    Arc::new(move |reply: InferReply| {
        let bytes = if faults::fire(faults::SITE_SERVER_WRITE) {
            error_frame("injected fault: reply dropped at server_write")
        } else {
            match &reply.scores {
                Ok(scores) => scores_frame(scores),
                Err(e) => error_frame(&e.message),
            }
        };
        ticket.deliver(bytes);
    })
}

pub(crate) fn write_error(stream: &mut TcpStream, msg: &str) -> std::io::Result<()> {
    stream.write_all(&WIRE_ERROR.to_le_bytes())?;
    stream.write_all(&(msg.len() as u32).to_le_bytes())?;
    stream.write_all(msg.as_bytes())
}

/// Longest payload the server will read-and-discard to keep a connection
/// framed after rejecting a request (4x the largest valid request).  A
/// claimed length beyond this is protocol garbage rather than a client
/// mistake, and is not worth draining gigabytes for.
pub(crate) const MAX_DISCARD_BYTES: usize = 4 * MAX_WIRE_VALUES * 4;
/// Read timeout while discarding a rejected payload: a peer that claims a
/// length and then stalls must not pin the connection thread forever.
const DISCARD_TIMEOUT: Duration = Duration::from_secs(10);

/// Read and drop `bytes` from the stream.  Oversized-request recovery:
/// the peer already committed to sending the payload, so consuming it is
/// the only way to keep the connection framed (closing instead would RST
/// away the error frame before the client reads it).  Bounded on both
/// axes — an implausible length, or a peer that has not delivered the
/// whole payload within [`DISCARD_TIMEOUT`] *total* (trickling counts),
/// returns an error and the caller closes the connection.
pub(crate) fn discard_payload(stream: &mut TcpStream, bytes: usize) -> std::io::Result<()> {
    if bytes > MAX_DISCARD_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("implausible payload of {bytes} bytes"),
        ));
    }
    let start = Instant::now();
    let result = (|| {
        let mut remaining = bytes;
        let mut sink = [0u8; 65536];
        while remaining > 0 {
            let elapsed = start.elapsed();
            if elapsed >= DISCARD_TIMEOUT {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "peer stalled while its rejected payload was drained",
                ));
            }
            // cap each read by the *remaining* overall budget, so a
            // trickling peer cannot reset the clock chunk by chunk
            stream.set_read_timeout(Some(DISCARD_TIMEOUT - elapsed))?;
            let take = remaining.min(sink.len());
            stream.read_exact(&mut sink[..take])?;
            remaining -= take;
        }
        Ok(())
    })();
    // restore blocking reads for the normal request path
    stream.set_read_timeout(None)?;
    result
}

/// Reject a request whose `n_values` length was refused: drain the
/// committed payload, send `msg` as an error frame, and keep the
/// connection usable.  Returns `Err` (caller closes) when the payload is
/// implausible or the peer stalls.
pub(crate) fn reject_payload(stream: &mut TcpStream, n_values: usize, msg: &str) -> Result<()> {
    if discard_payload(stream, n_values.saturating_mul(4)).is_err() {
        let _ = write_error(stream, msg);
        bail!("{msg}: implausible or stalled payload");
    }
    write_error(stream, msg)?;
    Ok(())
}

fn handle_conn(mut stream: TcpStream, client: Client) -> Result<()> {
    stream.set_nodelay(true).ok();
    loop {
        let mut len_buf = [0u8; 4];
        if stream.read_exact(&mut len_buf).is_err() {
            return Ok(()); // peer closed
        }
        let n = u32::from_le_bytes(len_buf) as usize;
        if n == 0 {
            return Ok(());
        }
        if n > MAX_WIRE_VALUES {
            reject_payload(&mut stream, n, &format!("request too large: {n} values"))?;
            continue;
        }
        let mut raw = vec![0u8; n * 4];
        stream.read_exact(&mut raw)?;
        let image: Vec<i32> = raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        if faults::fire(faults::SITE_SERVER_READ) {
            // injected shed: the request is refused after the frame was
            // read, so the connection stays usable
            write_error(&mut stream, "injected fault: request shed at server_read")?;
            continue;
        }
        // a saturated pool answers with a typed overload frame instead of
        // parking the connection on an unbounded submit_blocking retry
        let rx = match client.submit_deadline(image, TCP_SUBMIT_DEADLINE) {
            Ok(rx) => rx,
            Err(SubmitError::QueueFull { .. }) => {
                write_error(&mut stream, "server overloaded: all shard queues full")?;
                continue;
            }
            Err(SubmitError::ShardDown { .. }) => {
                // the pool is down but the process is alive: answer typed
                // so the client can fail over to another server
                write_error(&mut stream, "service degraded: all shards down")?;
                continue;
            }
            Err(SubmitError::Shutdown) => {
                let _ = write_error(&mut stream, "coordinator shut down");
                bail!("coordinator shut down");
            }
        };
        let reply = match rx.recv() {
            Ok(r) => r,
            Err(_) => {
                let _ = write_error(&mut stream, "coordinator shut down before replying");
                bail!("coordinator shut down before replying");
            }
        };
        if faults::fire(faults::SITE_SERVER_WRITE) {
            write_error(&mut stream, "injected fault: reply dropped at server_write")?;
            continue;
        }
        match &reply.scores {
            Ok(scores) => {
                stream.write_all(&(scores.len() as u32).to_le_bytes())?;
                let mut out = Vec::with_capacity(scores.len() * 4);
                for s in scores {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                stream.write_all(&out)?;
            }
            Err(e) => {
                // typed failure: forward it and keep the connection open
                // (the next request may land on a healthy batch)
                write_error(&mut stream, &e.message)?;
            }
        }
    }
}

/// Blocking TCP client for the wire protocol (used by tests/examples).
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: &str) -> Result<Self> {
        Ok(Self { stream: TcpStream::connect(addr).context("connect")? })
    }

    pub fn infer(&mut self, image: &[i32]) -> Result<Vec<f32>> {
        self.stream.write_all(&(image.len() as u32).to_le_bytes())?;
        let mut out = Vec::with_capacity(image.len() * 4);
        for v in image {
            out.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&out)?;
        let mut len_buf = [0u8; 4];
        self.stream.read_exact(&mut len_buf)?;
        let n = u32::from_le_bytes(len_buf);
        if n == WIRE_ERROR {
            let mut msg_len = [0u8; 4];
            self.stream.read_exact(&mut msg_len)?;
            let mut msg = vec![0u8; u32::from_le_bytes(msg_len) as usize];
            self.stream.read_exact(&mut msg)?;
            bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        let mut raw = vec![0u8; n as usize * 4];
        self.stream.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn close(mut self) -> Result<()> {
        self.stream.write_all(&0u32.to_le_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression for the handler-thread leak: finished handlers used to
    /// be reaped only when a *new* connection arrived, so churn followed by
    /// quiet grew the join list without bound.  With reap-on-idle the live
    /// gauge must fall back to zero once the churned connections finish.
    #[test]
    fn connection_churn_does_not_grow_join_list() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let live = Arc::new(AtomicUsize::new(0));
        let handler: Arc<dyn Fn(TcpStream) + Send + Sync> = Arc::new(|mut stream: TcpStream| {
            // read until the peer closes, then finish
            let mut sink = [0u8; 64];
            while let Ok(n) = stream.read(&mut sink) {
                if n == 0 {
                    break;
                }
            }
        });
        let server = {
            let stop = Arc::clone(&stop);
            let live = Arc::clone(&live);
            std::thread::spawn(move || {
                serve_connections_gauged(listener, stop, handler, || {}, live)
            })
        };
        // churn: open and close connections in waves
        for _ in 0..3 {
            let conns: Vec<TcpStream> =
                (0..8).map(|_| TcpStream::connect(addr).unwrap()).collect();
            drop(conns);
            std::thread::sleep(Duration::from_millis(30));
        }
        // idle long enough for reap-on-idle to observe the finished
        // handlers, then check the gauge went back down
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut ok = false;
        while Instant::now() < deadline {
            if live.load(Ordering::Relaxed) == 0 {
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(ok, "finished handlers were not reaped: live={}", live.load(Ordering::Relaxed));
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap().unwrap();
    }
}
