//! The coordinator proper: client handles -> channel -> batcher -> worker
//! thread -> backend, with shared metrics.  Plus a minimal TCP front-end
//! (length-prefixed binary protocol, thread per connection).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::backend::Backend;
use crate::coordinator::batcher::{BatchPolicy, Batcher, Msg};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferReply, InferRequest};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default() }
    }
}

/// Handle clients use to submit work.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Msg>,
    next_id: Arc<AtomicU64>,
}

impl Client {
    /// Submit one image; returns the receiver for its reply.
    pub fn submit(&self, image: Vec<i32>) -> Receiver<InferReply> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        // a send error means the coordinator shut down; the client sees a
        // disconnected reply channel.
        let _ = self.tx.send(Msg::Req(req));
        reply_rx
    }

    /// Submit and wait.
    pub fn infer(&self, image: Vec<i32>) -> Result<InferReply> {
        self.submit(image)
            .recv()
            .map_err(|_| anyhow!("coordinator shut down before replying"))
    }
}

/// A running coordinator (one worker thread over one backend).
pub struct Coordinator {
    client: Client,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    started: Instant,
}

impl Coordinator {
    /// Spawn the worker thread around a `Send` backend.
    pub fn start(backend: Box<dyn Backend + Send>, config: CoordinatorConfig) -> Self {
        Self::start_with(Box::new(move || Ok(backend as Box<dyn Backend>)), config)
            .expect("infallible factory")
    }

    /// Spawn the worker thread; the backend is constructed *on* the worker
    /// (required for non-`Send` backends like PJRT).  Fails if the factory
    /// fails.
    pub fn start_with(
        factory: crate::coordinator::backend::BackendFactory,
        config: CoordinatorConfig,
    ) -> Result<Self> {
        let (tx, rx) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let metrics_worker = Arc::clone(&metrics);
        let worker = std::thread::Builder::new()
            .name("coordinator-worker".into())
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut batcher = Batcher::new(rx, config.policy);
                while let Some(batch) = batcher.next_batch() {
                    let formed = Instant::now();
                    let images: Vec<Vec<i32>> =
                        batch.iter().map(|r| r.image.clone()).collect();
                    let result = backend.infer_batch(&images);
                    let service = formed.elapsed();
                    match result {
                        Ok(out) => {
                            let mut m = metrics_worker.lock().unwrap();
                            m.record_batch(batch.len(), service, out.modeled_device_time);
                            for (req, scores) in batch.into_iter().zip(out.scores) {
                                let queue_time = formed.duration_since(req.enqueued);
                                m.record_request(queue_time, queue_time + service);
                                let _ = req.reply.send(InferReply {
                                    id: req.id,
                                    scores,
                                    queue_time,
                                    service_time: service,
                                    batch_size: images.len(),
                                    modeled_device_time: out.modeled_device_time,
                                });
                            }
                        }
                        Err(e) => {
                            // drop the batch; clients observe disconnect
                            eprintln!("[coordinator] backend error: {e:#}");
                        }
                    }
                }
            })
            .expect("spawn coordinator worker");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("coordinator worker died during startup"))??;
        Ok(Self {
            client: Client { tx, next_id: Arc::new(AtomicU64::new(0)) },
            worker: Some(worker),
            metrics,
            started: Instant::now(),
        })
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Snapshot the metrics (wall time filled in).
    pub fn metrics(&self) -> Metrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.wall = self.started.elapsed();
        m
    }

    /// Graceful shutdown: poison the queue (queued requests are still
    /// served first), join the worker.  Works even while client handles
    /// remain alive — their later submits see a dead reply channel.
    pub fn shutdown(mut self) -> Metrics {
        let metrics = self.metrics();
        let _ = self.client.tx.send(Msg::Stop);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        metrics
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.client.tx.send(Msg::Stop);
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// TCP front-end
// ---------------------------------------------------------------------------
//
// Wire protocol (little-endian):
//   request:  u32 n_values, then n_values x i32 (one NHWC image)
//   reply:    u32 n_scores, then n_scores x f32
// A zero-length request closes the connection.

/// Serve a TCP listener until `stop` flips (thread per connection).
pub fn serve_tcp(listener: TcpListener, client: Client, stop: Arc<AtomicBool>) -> Result<()> {
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let client = client.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, client);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => bail!("accept: {e}"),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(mut stream: TcpStream, client: Client) -> Result<()> {
    stream.set_nodelay(true).ok();
    loop {
        let mut len_buf = [0u8; 4];
        if stream.read_exact(&mut len_buf).is_err() {
            return Ok(()); // peer closed
        }
        let n = u32::from_le_bytes(len_buf) as usize;
        if n == 0 {
            return Ok(());
        }
        if n > 1 << 22 {
            bail!("request too large: {n}");
        }
        let mut raw = vec![0u8; n * 4];
        stream.read_exact(&mut raw)?;
        let image: Vec<i32> = raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let reply = client.infer(image)?;
        stream.write_all(&(reply.scores.len() as u32).to_le_bytes())?;
        let mut out = Vec::with_capacity(reply.scores.len() * 4);
        for s in &reply.scores {
            out.extend_from_slice(&s.to_le_bytes());
        }
        stream.write_all(&out)?;
    }
}

/// Blocking TCP client for the wire protocol (used by tests/examples).
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: &str) -> Result<Self> {
        Ok(Self { stream: TcpStream::connect(addr).context("connect")? })
    }

    pub fn infer(&mut self, image: &[i32]) -> Result<Vec<f32>> {
        self.stream.write_all(&(image.len() as u32).to_le_bytes())?;
        let mut out = Vec::with_capacity(image.len() * 4);
        for v in image {
            out.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&out)?;
        let mut len_buf = [0u8; 4];
        self.stream.read_exact(&mut len_buf)?;
        let n = u32::from_le_bytes(len_buf) as usize;
        let mut raw = vec![0u8; n * 4];
        self.stream.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn close(mut self) -> Result<()> {
        self.stream.write_all(&0u32.to_le_bytes())?;
        Ok(())
    }
}
