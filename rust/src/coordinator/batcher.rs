//! Dynamic batcher: max-batch + deadline policy (vLLM-router style).
//!
//! Blocks for the first request, then keeps admitting until either the
//! batch is full or the oldest request's deadline (`max_wait`) expires.
//! `max_wait = 0` degenerates to pure online serving (batch = whatever is
//! already queued) — the regime where Fig. 7 shows the FPGA winning 8.3x.
//!
//! The queue carries [`Msg`]: requests plus an explicit `Stop` poison so
//! the coordinator can shut the worker down even while client handles
//! (and their channel senders) are still alive.
//!
//! The batcher is queue-flavor agnostic: it consumes any `Receiver<Msg>`,
//! and in the sharded coordinator that receiver is the consumption side of
//! a *bounded* `sync_channel` — admission control (backpressure on a full
//! queue) happens at the sender, so nothing here ever grows unboundedly.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::Instant;

use crate::coordinator::request::InferRequest;

/// Queue message: a request, or the shutdown poison.
#[derive(Debug)]
pub enum Msg {
    Req(InferRequest),
    Stop,
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: std::time::Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 16, max_wait: std::time::Duration::from_millis(2) }
    }
}

/// Pulls requests off a channel and forms batches.
pub struct Batcher {
    rx: Receiver<Msg>,
    policy: BatchPolicy,
    stopped: bool,
}

impl Batcher {
    pub fn new(rx: Receiver<Msg>, policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1, "max_batch must be >= 1");
        Self { rx, policy, stopped: false }
    }

    /// Next batch; `None` on `Stop` or when all senders are gone.  A
    /// partially-formed batch is returned before the stop takes effect on
    /// the *next* call (no request is dropped).
    pub fn next_batch(&mut self) -> Option<Vec<InferRequest>> {
        if self.stopped {
            return None;
        }
        // block for the first request
        let first = loop {
            match self.rx.recv() {
                Ok(Msg::Req(r)) => break r,
                Ok(Msg::Stop) | Err(_) => {
                    self.stopped = true;
                    return None;
                }
            }
        };
        // deadline counts from the first request's arrival: if the queue
        // backed up, the deadline is already past and we only drain what is
        // queued (no extra waiting under load).
        let deadline = first.enqueued + self.policy.max_wait;
        let mut batch = vec![first];
        while batch.len() < self.policy.max_batch && !self.stopped {
            let now = Instant::now();
            let msg = if now >= deadline {
                // deadline passed: take only what is already queued
                match self.rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            } else {
                match self.rx.recv_timeout(deadline - now) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
                }
            };
            match msg {
                Msg::Req(r) => batch.push(r),
                Msg::Stop => self.stopped = true,
            }
        }
        Some(batch)
    }

    /// Drain everything already queued without blocking (circuit-breaker
    /// trip: the supervisor fails these typed instead of serving them).
    /// A queued `Stop` poison still takes effect.
    pub fn drain_pending(&mut self) -> Vec<InferRequest> {
        let mut out = Vec::new();
        loop {
            match self.rx.try_recv() {
                Ok(Msg::Req(r)) => out.push(r),
                Ok(Msg::Stop) => self.stopped = true,
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        out
    }

    /// True once a `Stop` poison or sender disconnect has been observed.
    pub fn is_stopped(&self) -> bool {
        self.stopped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::{Duration, Instant};

    fn req(id: u64) -> (Msg, mpsc::Receiver<crate::coordinator::InferReply>) {
        let (tx, rx) = mpsc::channel();
        (
            Msg::Req(InferRequest {
                id,
                trace_id: 0,
                image: vec![],
                enqueued: Instant::now(),
                reply: crate::coordinator::request::ReplyTo::Channel(tx),
            }),
            rx,
        )
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, k) = req(i);
            keep.push(k);
            tx.send(r).unwrap();
        }
        let mut b = Batcher::new(rx, BatchPolicy { max_batch: 3, max_wait: Duration::ZERO });
        assert_eq!(b.next_batch().unwrap().len(), 3);
        assert_eq!(b.next_batch().unwrap().len(), 2);
    }

    #[test]
    fn zero_wait_takes_only_queued() {
        let (tx, rx) = mpsc::channel();
        let (r, _k) = req(0);
        tx.send(r).unwrap();
        let mut b = Batcher::new(rx, BatchPolicy { max_batch: 16, max_wait: Duration::ZERO });
        assert_eq!(b.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn returns_none_on_disconnect() {
        let (tx, rx) = mpsc::channel::<Msg>();
        drop(tx);
        let mut b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn stop_poison_terminates_even_with_live_senders() {
        let (tx, rx) = mpsc::channel();
        let tx2 = tx.clone(); // a "client" that never goes away
        tx.send(Msg::Stop).unwrap();
        let mut b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
        assert!(b.next_batch().is_none()); // stays stopped
        drop(tx2);
    }

    #[test]
    fn stop_after_requests_flushes_batch_first() {
        let (tx, rx) = mpsc::channel();
        let (r0, _k0) = req(0);
        let (r1, _k1) = req(1);
        tx.send(r0).unwrap();
        tx.send(r1).unwrap();
        tx.send(Msg::Stop).unwrap();
        let mut b = Batcher::new(rx, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2, "queued requests must be served before stop");
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn waits_for_deadline_to_fill() {
        let (tx, rx) = mpsc::channel();
        let mut b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(100) },
        );
        let (r0, _k0) = req(0);
        tx.send(r0).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let (r1, k1) = req(1);
            tx.send(r1).unwrap();
            k1
        });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2, "second request should arrive before deadline");
        let _ = handle.join();
    }

    #[test]
    #[should_panic(expected = "max_batch")]
    fn zero_max_batch_rejected() {
        let (_tx, rx) = mpsc::channel::<Msg>();
        let _ = Batcher::new(rx, BatchPolicy { max_batch: 0, max_wait: Duration::ZERO });
    }
}
