//! Request/reply types flowing through the coordinator.

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// A classification request: one image, NHWC `i32` in the 6-bit range.
#[derive(Debug)]
pub struct InferRequest {
    pub id: u64,
    pub image: Vec<i32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<InferReply>,
}

/// The reply, with per-request serving telemetry.
#[derive(Debug, Clone)]
pub struct InferReply {
    pub id: u64,
    pub scores: Vec<f32>,
    /// Time spent queued before the batch formed.
    pub queue_time: Duration,
    /// Backend execution time for the whole batch this request rode in.
    pub service_time: Duration,
    /// Size of that batch.
    pub batch_size: usize,
    /// Modeled device time, if the backend is a simulator (FPGA/GPU).
    pub modeled_device_time: Option<Duration>,
}

impl InferReply {
    /// End-to-end latency as the client experienced it.
    pub fn latency(&self) -> Duration {
        self.queue_time + self.service_time
    }

    pub fn argmax(&self) -> usize {
        self.scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_peak() {
        let r = InferReply {
            id: 0,
            scores: vec![0.1, 2.0, -1.0],
            queue_time: Duration::ZERO,
            service_time: Duration::ZERO,
            batch_size: 1,
            modeled_device_time: None,
        };
        assert_eq!(r.argmax(), 1);
    }

    #[test]
    fn latency_sums() {
        let r = InferReply {
            id: 0,
            scores: vec![],
            queue_time: Duration::from_millis(2),
            service_time: Duration::from_millis(3),
            batch_size: 4,
            modeled_device_time: None,
        };
        assert_eq!(r.latency(), Duration::from_millis(5));
    }
}
