//! Request/reply types flowing through the coordinator.

use std::fmt;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// A classification request: one image, NHWC `i32` in the 6-bit range.
#[derive(Debug)]
pub struct InferRequest {
    pub id: u64,
    /// Tracing identity, minted at admission ([`crate::obs::mint_trace_id`])
    /// and carried through every span this request produces — coordinator
    /// queue/batch/reply, pipeline stages — and into the wire reply.
    pub trace_id: u64,
    pub image: Vec<i32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<InferReply>,
}

/// Typed backend failure carried back to the client (no silent drops:
/// when `infer_batch` errors, every request in the batch receives this).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InferError {
    pub message: String,
}

impl fmt::Display for InferError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "backend error: {}", self.message)
    }
}

impl std::error::Error for InferError {}

/// Submission failure from a bounded-queue [`crate::coordinator::Client`].
#[derive(Debug)]
pub enum SubmitError {
    /// Every shard queue is at capacity.  The image is handed back so the
    /// caller can retry (backpressure, not data loss).
    QueueFull { image: Vec<i32> },
    /// Every shard worker is dead (crashed / circuit breaker open) but the
    /// pool was *not* gracefully shut down.  The image is handed back; the
    /// caller should fail over to another replica or model version.
    ShardDown { image: Vec<i32> },
    /// The coordinator has shut down; no worker will ever reply.
    Shutdown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { .. } => write!(f, "all shard queues full (backpressure)"),
            SubmitError::ShardDown { .. } => {
                write!(f, "all shards down (crashed or circuit breaker open)")
            }
            SubmitError::Shutdown => write!(f, "coordinator shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// The reply, with per-request serving telemetry.
#[derive(Debug, Clone)]
pub struct InferReply {
    pub id: u64,
    /// The request's end-to-end trace ID (correlates this reply with its
    /// spans in the `OP_TRACE` export; 0 means untraced).
    pub trace_id: u64,
    /// Per-class scores, or the typed failure of the batch this request
    /// rode in.
    pub scores: Result<Vec<f32>, InferError>,
    /// Time spent queued before the batch formed.
    pub queue_time: Duration,
    /// Backend execution time for the whole batch this request rode in.
    pub service_time: Duration,
    /// Size of that batch.
    pub batch_size: usize,
    /// Which shard of the worker pool served it.
    pub shard: usize,
    /// Modeled device time, if the backend is a simulator (FPGA/GPU).
    pub modeled_device_time: Option<Duration>,
}

impl InferReply {
    /// End-to-end latency as the client experienced it.
    pub fn latency(&self) -> Duration {
        self.queue_time + self.service_time
    }

    /// Scores or a typed error (convenience over matching on the field).
    pub fn ok_scores(&self) -> Result<&[f32], InferError> {
        match &self.scores {
            Ok(s) => Ok(s.as_slice()),
            Err(e) => Err(e.clone()),
        }
    }

    /// Predicted class, `None` for an error reply.
    pub fn argmax(&self) -> Option<usize> {
        let scores = self.scores.as_ref().ok()?;
        scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reply(scores: Result<Vec<f32>, InferError>) -> InferReply {
        InferReply {
            id: 0,
            trace_id: 0,
            scores,
            queue_time: Duration::from_millis(2),
            service_time: Duration::from_millis(3),
            batch_size: 4,
            shard: 0,
            modeled_device_time: None,
        }
    }

    #[test]
    fn argmax_picks_peak() {
        let r = reply(Ok(vec![0.1, 2.0, -1.0]));
        assert_eq!(r.argmax(), Some(1));
    }

    #[test]
    fn argmax_none_on_error() {
        let r = reply(Err(InferError { message: "boom".into() }));
        assert_eq!(r.argmax(), None);
        assert!(r.ok_scores().is_err());
    }

    #[test]
    fn latency_sums() {
        let r = reply(Ok(vec![]));
        assert_eq!(r.latency(), Duration::from_millis(5));
    }

    #[test]
    fn submit_error_returns_image() {
        let e = SubmitError::QueueFull { image: vec![1, 2, 3] };
        match e {
            SubmitError::QueueFull { image } => assert_eq!(image, vec![1, 2, 3]),
            SubmitError::ShardDown { .. } | SubmitError::Shutdown => panic!("wrong variant"),
        }
        let e = SubmitError::ShardDown { image: vec![4, 5] };
        match e {
            SubmitError::ShardDown { image } => assert_eq!(image, vec![4, 5]),
            SubmitError::QueueFull { .. } | SubmitError::Shutdown => panic!("wrong variant"),
        }
    }
}
