//! Request/reply types flowing through the coordinator.

use std::fmt;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Where a reply goes.  Blocking submitters hold the receiving half of a
/// per-request channel; the event-driven TCP front-end instead registers
/// a completion callback (invoked exactly once, on whichever thread
/// finishes the request — a shard worker, the QoS scheduler on a shed,
/// or the breaker on a drain).
#[derive(Clone)]
pub enum ReplyTo {
    /// Per-request channel: the submitter blocks on the receiver.
    Channel(mpsc::Sender<InferReply>),
    /// Asynchronous completion callback (event-driven front-end).
    Callback(Arc<dyn Fn(InferReply) + Send + Sync>),
}

impl ReplyTo {
    /// Deliver the reply.  Mirrors `mpsc::Sender::send` so reply sites
    /// are agnostic to how the submitter waits; a callback cannot
    /// observe a hung-up peer, so it always reports success.
    pub fn send(&self, reply: InferReply) -> Result<(), mpsc::SendError<InferReply>> {
        match self {
            ReplyTo::Channel(tx) => tx.send(reply),
            ReplyTo::Callback(f) => {
                f(reply);
                Ok(())
            }
        }
    }
}

impl fmt::Debug for ReplyTo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplyTo::Channel(_) => write!(f, "ReplyTo::Channel"),
            ReplyTo::Callback(_) => write!(f, "ReplyTo::Callback"),
        }
    }
}

/// A classification request: one image, NHWC `i32` in the 6-bit range.
#[derive(Debug)]
pub struct InferRequest {
    pub id: u64,
    /// Tracing identity, minted at admission ([`crate::obs::mint_trace_id`])
    /// and carried through every span this request produces — coordinator
    /// queue/batch/reply, pipeline stages — and into the wire reply.
    pub trace_id: u64,
    pub image: Vec<i32>,
    pub enqueued: Instant,
    pub reply: ReplyTo,
}

/// Why a request failed, beyond the human-readable message.  The wire
/// front-ends map `Expired` to a typed expired frame (protocol v2 QoS)
/// so deadline sheds are distinguishable from backend faults.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferErrorKind {
    /// The backend (or its supervision) failed the batch.
    Backend,
    /// The QoS admission layer shed the request past its deadline.
    Expired,
    /// The admission layer shed the request for capacity (lane full or
    /// the dispatch wait bound elapsed) — overload, not a deadline miss.
    Overload,
}

/// Typed request failure carried back to the client (no silent drops:
/// when `infer_batch` errors, every request in the batch receives this;
/// when the QoS layer sheds, the shed request receives one too).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InferError {
    pub message: String,
    pub kind: InferErrorKind,
}

impl InferError {
    pub fn backend(message: impl Into<String>) -> Self {
        Self { message: message.into(), kind: InferErrorKind::Backend }
    }

    pub fn expired(message: impl Into<String>) -> Self {
        Self { message: message.into(), kind: InferErrorKind::Expired }
    }

    pub fn overload(message: impl Into<String>) -> Self {
        Self { message: message.into(), kind: InferErrorKind::Overload }
    }
}

impl fmt::Display for InferError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            InferErrorKind::Backend => write!(f, "backend error: {}", self.message),
            InferErrorKind::Expired => write!(f, "expired: {}", self.message),
            InferErrorKind::Overload => write!(f, "overloaded: {}", self.message),
        }
    }
}

impl std::error::Error for InferError {}

/// Submission failure from a bounded-queue [`crate::coordinator::Client`].
#[derive(Debug)]
pub enum SubmitError {
    /// Every shard queue is at capacity.  The image is handed back so the
    /// caller can retry (backpressure, not data loss).
    QueueFull { image: Vec<i32> },
    /// Every shard worker is dead (crashed / circuit breaker open) but the
    /// pool was *not* gracefully shut down.  The image is handed back; the
    /// caller should fail over to another replica or model version.
    ShardDown { image: Vec<i32> },
    /// The coordinator has shut down; no worker will ever reply.
    Shutdown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { .. } => write!(f, "all shard queues full (backpressure)"),
            SubmitError::ShardDown { .. } => {
                write!(f, "all shards down (crashed or circuit breaker open)")
            }
            SubmitError::Shutdown => write!(f, "coordinator shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// The reply, with per-request serving telemetry.
#[derive(Debug, Clone)]
pub struct InferReply {
    pub id: u64,
    /// The request's end-to-end trace ID (correlates this reply with its
    /// spans in the `OP_TRACE` export; 0 means untraced).
    pub trace_id: u64,
    /// Per-class scores, or the typed failure of the batch this request
    /// rode in.
    pub scores: Result<Vec<f32>, InferError>,
    /// Time spent queued before the batch formed.
    pub queue_time: Duration,
    /// Backend execution time for the whole batch this request rode in.
    pub service_time: Duration,
    /// Size of that batch.
    pub batch_size: usize,
    /// Which shard of the worker pool served it.
    pub shard: usize,
    /// Modeled device time, if the backend is a simulator (FPGA/GPU).
    pub modeled_device_time: Option<Duration>,
}

impl InferReply {
    /// End-to-end latency as the client experienced it.
    pub fn latency(&self) -> Duration {
        self.queue_time + self.service_time
    }

    /// Scores or a typed error (convenience over matching on the field).
    pub fn ok_scores(&self) -> Result<&[f32], InferError> {
        match &self.scores {
            Ok(s) => Ok(s.as_slice()),
            Err(e) => Err(e.clone()),
        }
    }

    /// Predicted class, `None` for an error reply.
    pub fn argmax(&self) -> Option<usize> {
        let scores = self.scores.as_ref().ok()?;
        scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reply(scores: Result<Vec<f32>, InferError>) -> InferReply {
        InferReply {
            id: 0,
            trace_id: 0,
            scores,
            queue_time: Duration::from_millis(2),
            service_time: Duration::from_millis(3),
            batch_size: 4,
            shard: 0,
            modeled_device_time: None,
        }
    }

    #[test]
    fn argmax_picks_peak() {
        let r = reply(Ok(vec![0.1, 2.0, -1.0]));
        assert_eq!(r.argmax(), Some(1));
    }

    #[test]
    fn argmax_none_on_error() {
        let r = reply(Err(InferError::backend("boom")));
        assert_eq!(r.argmax(), None);
        assert!(r.ok_scores().is_err());
    }

    #[test]
    fn error_kinds_render_distinctly() {
        assert_eq!(InferError::backend("x").to_string(), "backend error: x");
        assert_eq!(InferError::expired("x").to_string(), "expired: x");
        assert_eq!(InferError::overload("x").to_string(), "overloaded: x");
        assert_eq!(InferError::expired("x").kind, InferErrorKind::Expired);
    }

    #[test]
    fn reply_to_callback_delivers_inline() {
        use std::sync::Mutex;
        let got: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&got);
        let cb = ReplyTo::Callback(Arc::new(move |r: InferReply| {
            sink.lock().unwrap().push(r.id);
        }));
        cb.send(reply(Ok(vec![]))).unwrap();
        assert_eq!(*got.lock().unwrap(), vec![0]);
    }

    #[test]
    fn latency_sums() {
        let r = reply(Ok(vec![]));
        assert_eq!(r.latency(), Duration::from_millis(5));
    }

    #[test]
    fn submit_error_returns_image() {
        let e = SubmitError::QueueFull { image: vec![1, 2, 3] };
        match e {
            SubmitError::QueueFull { image } => assert_eq!(image, vec![1, 2, 3]),
            SubmitError::ShardDown { .. } | SubmitError::Shutdown => panic!("wrong variant"),
        }
        let e = SubmitError::ShardDown { image: vec![4, 5] };
        match e {
            SubmitError::ShardDown { image } => assert_eq!(image, vec![4, 5]),
            SubmitError::QueueFull { .. } | SubmitError::Shutdown => panic!("wrong variant"),
        }
    }
}
