//! Two-class QoS admission for the event-driven front-end.
//!
//! The paper's Fig. 7 result is *batch-insensitivity*: the FPGA pipeline
//! serves small online batches 8.3x faster than the GPU while matching it
//! on large offline batches.  To make that distinction actionable on the
//! host, the front-end classifies every request into one of two lanes:
//!
//! * **online** — small-batch, deadline-tagged, p99-latency-bound (the
//!   8.3x scenario).  Requests past their deadline are *shed* with a typed
//!   `Expired` reply instead of queueing uselessly.
//! * **offline** — large-batch throughput work ("static data" scenario).
//!   No latency promise; sheds only on overload.
//!
//! Lanes drain by **weighted deficit round-robin** (default 8:1 online) so
//! an offline flood cannot starve online traffic, and head-of-line expiry
//! checks run before every dispatch so a stale online request never burns
//! shard capacity.  Blanket `QueueFull` rejection is replaced by typed
//! sheds: every admitted request gets exactly one reply — scores, a
//! backend error, `Expired`, or `Overload` — never a silent drop.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::LaneCounters;
use crate::coordinator::request::{InferError, InferErrorKind, InferReply, ReplyTo, SubmitError};
use crate::coordinator::server::{Client, TCP_SUBMIT_DEADLINE};
use crate::obs::{self, SpanEvent, SpanKind, SpanRing};
use crate::util::json::Json;
use crate::util::sync::lock_recover;

/// Request class, carried in the protocol-v2 QoS frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Latency-bound interactive traffic (paper's online scenario).
    Online,
    /// Throughput-bound bulk traffic (paper's static-data scenario).
    Offline,
}

impl Lane {
    pub const COUNT: usize = 2;

    pub fn index(self) -> usize {
        match self {
            Lane::Online => 0,
            Lane::Offline => 1,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Lane::Online => "online",
            Lane::Offline => "offline",
        }
    }

    /// Wire encoding (v2 QoS frame `lane` field).
    pub fn wire(self) -> u32 {
        self.index() as u32
    }

    pub fn from_wire(v: u32) -> Option<Lane> {
        match v {
            0 => Some(Lane::Online),
            1 => Some(Lane::Offline),
            _ => None,
        }
    }

    pub fn all() -> [Lane; 2] {
        [Lane::Online, Lane::Offline]
    }
}

/// Lane weights and shed policy.
#[derive(Debug, Clone, Copy)]
pub struct QosConfig {
    /// DRR quantum for the online lane (dispatches per replenish round).
    pub online_weight: u32,
    /// DRR quantum for the offline lane.
    pub offline_weight: u32,
    /// Deadline applied to *online* requests that carry none of their own
    /// (`--deadline-ms`).  `None` preserves the pre-QoS contract: requests
    /// wait up to [`max_wait`](Self::max_wait) and shed as `Overload`,
    /// exactly like the threaded path's 5 s submit bound.
    pub default_deadline: Option<Duration>,
    /// Per-lane queue capacity; admission beyond it sheds immediately.
    pub lane_capacity: usize,
    /// Upper bound on time queued at admission before an `Overload` shed
    /// (applies to every request as a backstop, deadline or not).
    pub max_wait: Duration,
}

impl Default for QosConfig {
    fn default() -> Self {
        Self {
            online_weight: 8,
            offline_weight: 1,
            default_deadline: None,
            lane_capacity: 4096,
            max_wait: TCP_SUBMIT_DEADLINE,
        }
    }
}

/// Parse a `--qos online:offline` weight spec (e.g. `"8:1"`).
pub fn parse_qos_weights(spec: &str) -> anyhow::Result<(u32, u32)> {
    let parse = |s: &str| -> anyhow::Result<u32> {
        let v: u32 = s
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --qos weight {s:?} (want online:offline)"))?;
        anyhow::ensure!(v >= 1, "--qos weights must be >= 1, got {v}");
        Ok(v)
    };
    let (on, off) = spec
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("invalid --qos spec {spec:?} (want online:offline)"))?;
    Ok((parse(on)?, parse(off)?))
}

/// Front-end (reactor + QoS) configuration.
#[derive(Debug, Clone, Copy, Default)]
pub struct FrontendConfig {
    /// Event-loop threads; `0` = auto (half the available parallelism,
    /// clamped to `[1, 4]`).
    pub reactor_threads: usize,
    pub qos: QosConfig,
}

impl FrontendConfig {
    pub fn resolved_threads(&self) -> usize {
        if self.reactor_threads > 0 {
            return self.reactor_threads;
        }
        let par = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        (par / 2).clamp(1, 4)
    }
}

// ---------------------------------------------------------------------------
// Stats: per-front-end atomics, globally registered (Weak) so `STATS` /
// `repro top` can aggregate without plumbing handles through the registry.

#[derive(Default)]
pub struct LaneStats {
    admitted: AtomicU64,
    dispatched: AtomicU64,
    shed_expired: AtomicU64,
    shed_overload: AtomicU64,
    depth: AtomicU64,
}

impl LaneStats {
    fn snapshot(&self) -> LaneCounters {
        LaneCounters {
            admitted: self.admitted.load(Ordering::Relaxed),
            dispatched: self.dispatched.load(Ordering::Relaxed),
            shed_expired: self.shed_expired.load(Ordering::Relaxed),
            shed_overload: self.shed_overload.load(Ordering::Relaxed),
            depth: self.depth.load(Ordering::Relaxed),
        }
    }
}

/// Shared counters for one front-end instance (reactor + admission).
#[derive(Default)]
pub struct FrontendStats {
    lanes: [LaneStats; Lane::COUNT],
    /// Event-loop threads actually running.
    pub reactor_threads: AtomicUsize,
    /// Live multiplexed connections across all loops.
    pub connections: AtomicUsize,
    /// Times a connection's read interest was paused for write
    /// backpressure (slow reader with a full outbound buffer).
    pub paused_reads: AtomicU64,
}

impl FrontendStats {
    /// Create and register in the process-global roster.
    pub fn new_registered() -> Arc<FrontendStats> {
        let s = Arc::new(FrontendStats::default());
        let mut reg = lock_recover(registry());
        reg.retain(|w: &Weak<FrontendStats>| w.strong_count() > 0);
        reg.push(Arc::downgrade(&s));
        drop(reg);
        s
    }

    pub fn lane(&self, lane: Lane) -> &LaneStats {
        &self.lanes[lane.index()]
    }

    pub fn snapshot(&self) -> FrontendSnapshot {
        FrontendSnapshot {
            lanes: [self.lanes[0].snapshot(), self.lanes[1].snapshot()],
            reactor_threads: self.reactor_threads.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            paused_reads: self.paused_reads.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time aggregate across live front-ends.
#[derive(Debug, Clone, Copy, Default)]
pub struct FrontendSnapshot {
    pub lanes: [LaneCounters; Lane::COUNT],
    pub reactor_threads: usize,
    pub connections: usize,
    pub paused_reads: u64,
}

impl FrontendSnapshot {
    pub fn lane(&self, lane: Lane) -> &LaneCounters {
        &self.lanes[lane.index()]
    }

    fn merge(&self, other: &FrontendSnapshot) -> FrontendSnapshot {
        FrontendSnapshot {
            lanes: [self.lanes[0].merge(&other.lanes[0]), self.lanes[1].merge(&other.lanes[1])],
            reactor_threads: self.reactor_threads + other.reactor_threads,
            connections: self.connections + other.connections,
            paused_reads: self.paused_reads + other.paused_reads,
        }
    }

    /// Stable-keyed JSON (pinned by the stats-schema test).
    pub fn to_json(&self) -> Json {
        let mut lanes = std::collections::BTreeMap::new();
        for lane in Lane::all() {
            lanes.insert(lane.label().to_string(), self.lane(lane).to_json());
        }
        let mut m = std::collections::BTreeMap::new();
        m.insert("connections".to_string(), Json::Num(self.connections as f64));
        m.insert("lanes".to_string(), Json::Obj(lanes));
        m.insert("paused_reads".to_string(), Json::Num(self.paused_reads as f64));
        m.insert("reactor_threads".to_string(), Json::Num(self.reactor_threads as f64));
        Json::Obj(m)
    }
}

fn registry() -> &'static Mutex<Vec<Weak<FrontendStats>>> {
    static REG: OnceLock<Mutex<Vec<Weak<FrontendStats>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

/// Aggregate snapshot over every live front-end in the process (zeros when
/// none is running — the `"frontend"` stats section is always present).
pub fn frontend_snapshot() -> FrontendSnapshot {
    let reg = lock_recover(registry());
    reg.iter()
        .filter_map(|w| w.upgrade())
        .map(|s| s.snapshot())
        .fold(FrontendSnapshot::default(), |acc, s| acc.merge(&s))
}

/// JSON form of [`frontend_snapshot`] for `stats_json`.
pub fn frontend_json() -> Json {
    frontend_snapshot().to_json()
}

// ---------------------------------------------------------------------------
// Admission queue

/// One queued request awaiting dispatch to a shard pool.
pub(crate) struct LaneEntry {
    pub image: Vec<i32>,
    pub trace_id: u64,
    pub lane: Lane,
    pub admitted: Instant,
    /// When this entry sheds instead of dispatching.
    pub deadline: Instant,
    /// `Expired` when the bound came from an explicit/default deadline,
    /// `Overload` when it is only the `max_wait` backstop.
    pub expire_kind: InferErrorKind,
    /// Completion callback (exactly-once reply delivery).
    pub reply: Arc<dyn Fn(InferReply) + Send + Sync>,
    /// The shard pool this request targets (per-model under the registry).
    pub client: Client,
    /// Last dispatch attempt saw `ShardDown` (colors the shed message).
    pub saw_down: bool,
}

struct Inner {
    queues: [VecDeque<LaneEntry>; Lane::COUNT],
    deficit: [u64; Lane::COUNT],
}

/// Weighted-deficit two-lane scheduler.  `admit` enqueues (or sheds on a
/// full lane); `pump` — called from every reactor loop iteration — drains
/// by DRR with head-of-line expiry sheds.
pub struct QosAdmission {
    cfg: QosConfig,
    stats: Arc<FrontendStats>,
    inner: Mutex<Inner>,
    ring: Arc<SpanRing>,
}

/// Cap on hoarded deficit: an idle lane may burst at most this many
/// quanta's worth of dispatches when traffic returns.
const DEFICIT_BURST_QUANTA: u64 = 4;

impl QosAdmission {
    pub fn new(cfg: QosConfig, stats: Arc<FrontendStats>) -> Arc<QosAdmission> {
        let instance = obs::next_instance_id();
        Arc::new(QosAdmission {
            cfg,
            stats,
            inner: Mutex::new(Inner {
                queues: [VecDeque::new(), VecDeque::new()],
                deficit: [0; Lane::COUNT],
            }),
            ring: SpanRing::new(format!("frontend{instance}/qos"), obs::DEFAULT_RING_CAPACITY),
        })
    }

    pub fn config(&self) -> &QosConfig {
        &self.cfg
    }

    /// Enqueue a request for dispatch; sheds immediately (typed reply via
    /// the callback) when the lane is at capacity.
    pub(crate) fn admit(
        &self,
        image: Vec<i32>,
        trace_id: u64,
        lane: Lane,
        explicit_deadline: Option<Duration>,
        client: Client,
        reply: Arc<dyn Fn(InferReply) + Send + Sync>,
    ) {
        let now = Instant::now();
        let online_default =
            if lane == Lane::Online { self.cfg.default_deadline } else { None };
        let (deadline, expire_kind) = match explicit_deadline.or(online_default) {
            Some(d) => (now + d.min(self.cfg.max_wait), InferErrorKind::Expired),
            None => (now + self.cfg.max_wait, InferErrorKind::Overload),
        };
        let entry = LaneEntry {
            image,
            trace_id,
            lane,
            admitted: now,
            deadline,
            expire_kind,
            reply,
            client,
            saw_down: false,
        };
        let li = lane.index();
        self.stats.lanes[li].admitted.fetch_add(1, Ordering::Relaxed);
        self.stats.lanes[li].depth.fetch_add(1, Ordering::Relaxed);
        let full = {
            let mut inner = lock_recover(&self.inner);
            if inner.queues[li].len() >= self.cfg.lane_capacity {
                Some(entry)
            } else {
                inner.queues[li].push_back(entry);
                None
            }
        };
        if let Some(entry) = full {
            self.shed(
                entry,
                InferErrorKind::Overload,
                format!("server overloaded: {} lane at capacity", lane.label()),
            );
        }
    }

    /// One DRR round: replenish deficits, then alternate lanes dispatching
    /// up to each lane's deficit, shedding expired heads for free.  Returns
    /// `true` while work remains queued (callers shorten their poll
    /// timeout to keep the scheduler hot).
    pub fn pump(&self) -> bool {
        let mut inner = lock_recover(&self.inner);
        if inner.queues.iter().all(|q| q.is_empty()) {
            inner.deficit = [0; Lane::COUNT];
            return false;
        }
        let now = Instant::now();
        let weights =
            [u64::from(self.cfg.online_weight.max(1)), u64::from(self.cfg.offline_weight.max(1))];
        for i in 0..Lane::COUNT {
            if inner.queues[i].is_empty() {
                inner.deficit[i] = 0; // no hoarding while idle
            } else {
                let cap = weights[i] * DEFICIT_BURST_QUANTA;
                inner.deficit[i] = (inner.deficit[i] + weights[i]).min(cap);
            }
        }
        let mut progressed = true;
        while progressed {
            progressed = false;
            for i in 0..Lane::COUNT {
                while inner.deficit[i] > 0 {
                    let Some(entry) = inner.queues[i].pop_front() else {
                        break;
                    };
                    if now >= entry.deadline {
                        // expiry sheds are free: they consume no deficit and
                        // never reach a shard queue
                        let kind = entry.expire_kind;
                        let msg = self.expiry_message(&entry, now);
                        self.shed(entry, kind, msg);
                        progressed = true;
                        continue;
                    }
                    match self.dispatch(entry) {
                        Dispatch::Done => {
                            inner.deficit[i] -= 1;
                            progressed = true;
                        }
                        Dispatch::Blocked(entry) => {
                            // head-of-line: the target pool is saturated;
                            // retry this entry on the next pump
                            inner.queues[i].push_front(entry);
                            inner.deficit[i] = 0;
                            break;
                        }
                    }
                }
            }
        }
        inner.queues.iter().any(|q| !q.is_empty())
    }

    fn dispatch(&self, entry: LaneEntry) -> Dispatch {
        let LaneEntry {
            image,
            trace_id,
            lane,
            admitted,
            deadline,
            expire_kind,
            reply,
            client,
            ..
        } = entry;
        match client.submit_with(image, trace_id, ReplyTo::Callback(Arc::clone(&reply))) {
            Ok(()) => {
                let li = lane.index();
                self.stats.lanes[li].dispatched.fetch_add(1, Ordering::Relaxed);
                self.stats.lanes[li].depth.fetch_sub(1, Ordering::Relaxed);
                if obs::enabled() {
                    // serialized: pump holds the admission lock
                    let t_end = obs::now_ns();
                    let waited = admitted.elapsed().as_nanos() as u64;
                    self.ring.record(&SpanEvent {
                        trace_id,
                        kind: SpanKind::Dispatch,
                        t_start_ns: t_end.saturating_sub(waited),
                        t_end_ns: t_end,
                        shard: lane.index() as u32,
                        layer: None,
                        batch: 1,
                    });
                }
                Dispatch::Done
            }
            Err(SubmitError::QueueFull { image }) => Dispatch::Blocked(LaneEntry {
                image,
                trace_id,
                lane,
                admitted,
                deadline,
                expire_kind,
                reply,
                client,
                saw_down: false,
            }),
            Err(SubmitError::ShardDown { image }) => Dispatch::Blocked(LaneEntry {
                image,
                trace_id,
                lane,
                admitted,
                deadline,
                expire_kind,
                reply,
                client,
                saw_down: true,
            }),
            Err(SubmitError::Shutdown) => {
                self.shed(
                    LaneEntry {
                        image: Vec::new(),
                        trace_id,
                        lane,
                        admitted,
                        deadline,
                        expire_kind,
                        reply,
                        client,
                        saw_down: false,
                    },
                    InferErrorKind::Overload,
                    "pool shut down before dispatch".to_string(),
                );
                Dispatch::Done
            }
        }
    }

    fn expiry_message(&self, entry: &LaneEntry, now: Instant) -> String {
        let waited_ms = now.duration_since(entry.admitted).as_millis();
        match entry.expire_kind {
            InferErrorKind::Expired => format!(
                "deadline expired after {waited_ms}ms in the {} lane",
                entry.lane.label()
            ),
            _ if entry.saw_down => format!(
                "service degraded: all shards down ({waited_ms}ms in the {} lane)",
                entry.lane.label()
            ),
            _ => format!(
                "server overloaded: shed after {waited_ms}ms in the {} lane \
                 (all shard queues full)",
                entry.lane.label()
            ),
        }
    }

    /// Deliver a typed shed reply and account it.
    fn shed(&self, entry: LaneEntry, kind: InferErrorKind, message: String) {
        let li = entry.lane.index();
        let s = &self.stats.lanes[li];
        s.depth.fetch_sub(1, Ordering::Relaxed);
        match kind {
            InferErrorKind::Expired => s.shed_expired.fetch_add(1, Ordering::Relaxed),
            _ => s.shed_overload.fetch_add(1, Ordering::Relaxed),
        };
        let err = InferError { message, kind };
        (entry.reply)(InferReply {
            id: 0,
            trace_id: entry.trace_id,
            scores: Err(err),
            queue_time: entry.admitted.elapsed(),
            service_time: Duration::ZERO,
            batch_size: 0,
            shard: 0,
            modeled_device_time: None,
        });
    }

    /// Fail everything still queued with a typed reply (server shutdown:
    /// conservation holds even for requests that never dispatched).
    pub fn drain_shutdown(&self) {
        let entries: Vec<LaneEntry> = {
            let mut inner = lock_recover(&self.inner);
            inner.queues.iter_mut().flat_map(|q| q.drain(..)).collect()
        };
        for entry in entries {
            self.shed(
                entry,
                InferErrorKind::Overload,
                "server shutting down before dispatch".to_string(),
            );
        }
    }

    /// Queued entries across both lanes (tests/shutdown bookkeeping).
    pub fn depth(&self) -> usize {
        let inner = lock_recover(&self.inner);
        inner.queues.iter().map(|q| q.len()).sum()
    }
}

enum Dispatch {
    Done,
    Blocked(LaneEntry),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, BatchResult};
    use crate::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
    use std::sync::atomic::AtomicBool;
    use std::sync::mpsc;

    struct EchoBackend;
    impl Backend for EchoBackend {
        fn name(&self) -> &str {
            "echo"
        }
        fn infer_batch(&mut self, images: &[&[i32]]) -> anyhow::Result<BatchResult> {
            Ok(BatchResult {
                scores: images
                    .iter()
                    .map(|img| vec![img.first().copied().unwrap_or(0) as f32])
                    .collect(),
                modeled_device_time: None,
            })
        }
    }

    /// Backend that parks until released — lets tests saturate queues.
    struct GateBackend(Arc<AtomicBool>);
    impl Backend for GateBackend {
        fn name(&self) -> &str {
            "gate"
        }
        fn infer_batch(&mut self, images: &[&[i32]]) -> anyhow::Result<BatchResult> {
            while !self.0.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(1));
            }
            Ok(BatchResult {
                scores: images.iter().map(|_| vec![0.0]).collect(),
                modeled_device_time: None,
            })
        }
    }

    fn pool(factory: impl Fn() -> Box<dyn Backend> + Send + Sync + 'static) -> Coordinator {
        Coordinator::start_sharded(
            Arc::new(move || Ok(factory())),
            CoordinatorConfig {
                workers: 1,
                queue_depth: 1,
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::ZERO },
                ..Default::default()
            },
        )
        .unwrap()
    }

    fn collector() -> (Arc<dyn Fn(InferReply) + Send + Sync>, mpsc::Receiver<InferReply>) {
        let (tx, rx) = mpsc::channel();
        let tx = Mutex::new(tx);
        (
            Arc::new(move |r: InferReply| {
                let _ = lock_recover(&tx).send(r);
            }),
            rx,
        )
    }

    #[test]
    fn admit_pump_dispatches_and_replies() {
        let pool = pool(|| Box::new(EchoBackend));
        let stats = FrontendStats::new_registered();
        let qos = QosAdmission::new(QosConfig::default(), Arc::clone(&stats));
        let (cb, rx) = collector();
        qos.admit(vec![7], 1, Lane::Online, None, pool.client(), cb);
        assert!(!qos.pump() || qos.depth() == 0);
        let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(reply.scores.unwrap(), vec![7.0]);
        let snap = stats.snapshot();
        assert_eq!(snap.lane(Lane::Online).admitted, 1);
        assert_eq!(snap.lane(Lane::Online).dispatched, 1);
        pool.shutdown();
    }

    #[test]
    fn expired_entry_sheds_typed() {
        // gate closed: the worker parks on the first request, the depth-1
        // queue holds the second, so a third with a tiny deadline must shed
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let pool = pool(move || Box::new(GateBackend(Arc::clone(&g))));
        let stats = FrontendStats::new_registered();
        let qos = QosAdmission::new(QosConfig::default(), Arc::clone(&stats));
        let (cb, rx) = collector();
        for _ in 0..2 {
            qos.admit(vec![1], 0, Lane::Online, None, pool.client(), Arc::clone(&cb));
        }
        qos.pump(); // first dispatches (parks), second blocks on full queue
        qos.admit(vec![2], 9, Lane::Online, Some(Duration::from_millis(5)), pool.client(), cb);
        std::thread::sleep(Duration::from_millis(20));
        // the deadlined entry is behind the blocked head; pump sheds it only
        // once it reaches the head — but expiry also fires when the blocked
        // head itself expires, so drive pumps until the shed lands
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut expired = None;
        while Instant::now() < deadline {
            qos.pump();
            match rx.recv_timeout(Duration::from_millis(10)) {
                Ok(r) => {
                    if let Err(e) = &r.scores {
                        if e.kind == InferErrorKind::Expired {
                            expired = Some(e.clone());
                            break;
                        }
                    }
                }
                Err(_) => continue,
            }
        }
        let e = expired.expect("typed Expired shed");
        assert!(e.message.contains("deadline expired"), "{}", e.message);
        assert!(stats.snapshot().lane(Lane::Online).shed_expired >= 1);
        gate.store(true, Ordering::Relaxed);
        pool.shutdown();
    }

    #[test]
    fn lane_capacity_sheds_overload() {
        let pool = pool(|| Box::new(EchoBackend));
        let stats = FrontendStats::new_registered();
        let cfg = QosConfig { lane_capacity: 2, ..Default::default() };
        let qos = QosAdmission::new(cfg, Arc::clone(&stats));
        let (cb, rx) = collector();
        for _ in 0..3 {
            qos.admit(vec![0], 0, Lane::Offline, None, pool.client(), Arc::clone(&cb));
        }
        // third admit overflowed capacity 2 and shed inline
        let r = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        let e = r.scores.unwrap_err();
        assert_eq!(e.kind, InferErrorKind::Overload);
        assert!(e.message.contains("overloaded"), "{}", e.message);
        assert_eq!(stats.snapshot().lane(Lane::Offline).shed_overload, 1);
        qos.drain_shutdown();
        pool.shutdown();
    }

    #[test]
    fn drr_prefers_online_lane() {
        // gated pool with queue_depth 1: each pump dispatches at most one
        // entry; with 8:1 weights the online lane must drain first
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let pool = pool(move || Box::new(GateBackend(Arc::clone(&g))));
        let stats = FrontendStats::new_registered();
        let qos = QosAdmission::new(QosConfig::default(), Arc::clone(&stats));
        let (cb, _rx) = collector();
        for _ in 0..4 {
            qos.admit(vec![0], 0, Lane::Offline, None, pool.client(), Arc::clone(&cb));
        }
        for _ in 0..4 {
            qos.admit(vec![0], 0, Lane::Online, None, pool.client(), Arc::clone(&cb));
        }
        qos.pump();
        let snap = stats.snapshot();
        // exactly one dispatch landed (worker parked + depth-1 queue =
        // at most 2 in flight) and it came from the online lane
        assert!(snap.lane(Lane::Online).dispatched >= 1);
        assert_eq!(snap.lane(Lane::Offline).dispatched, 0);
        gate.store(true, Ordering::Relaxed);
        qos.drain_shutdown();
        pool.shutdown();
    }

    #[test]
    fn drain_shutdown_replies_to_everything() {
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let pool = pool(move || Box::new(GateBackend(Arc::clone(&g))));
        let stats = FrontendStats::new_registered();
        let qos = QosAdmission::new(QosConfig::default(), Arc::clone(&stats));
        let (cb, rx) = collector();
        for _ in 0..5 {
            qos.admit(vec![0], 0, Lane::Offline, None, pool.client(), Arc::clone(&cb));
        }
        qos.drain_shutdown();
        let mut replies = 0;
        while rx.recv_timeout(Duration::from_millis(200)).is_ok() {
            replies += 1;
        }
        assert_eq!(replies, 5, "every queued request gets a typed reply");
        assert_eq!(qos.depth(), 0);
        gate.store(true, Ordering::Relaxed);
        pool.shutdown();
    }

    #[test]
    fn weight_spec_parses() {
        assert_eq!(parse_qos_weights("8:1").unwrap(), (8, 1));
        assert_eq!(parse_qos_weights(" 3 : 2 ").unwrap(), (3, 2));
        assert!(parse_qos_weights("8").is_err());
        assert!(parse_qos_weights("0:1").is_err());
        assert!(parse_qos_weights("a:b").is_err());
    }

    #[test]
    fn frontend_json_always_has_lane_keys() {
        let j = frontend_json();
        let obj = j.as_obj().unwrap();
        let keys: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
        assert_eq!(keys, ["connections", "lanes", "paused_reads", "reactor_threads"]);
        let lanes = obj.get("lanes").unwrap().as_obj().unwrap();
        let lane_keys: Vec<&str> = lanes.keys().map(|k| k.as_str()).collect();
        assert_eq!(lane_keys, ["offline", "online"]);
    }
}
